PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test serve-bench bench serve example

# Tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# Engine vs. naive-loop serving benchmark (QPS, p99, retrace count)
serve-bench:
	$(PYTHON) -m benchmarks.serve_bench --fast

# Full benchmark sweep (kernels, plan executor, serving)
bench:
	$(PYTHON) -m benchmarks.run

serve:
	$(PYTHON) -m repro.launch.serve --batches 4 --batch 64

example:
	$(PYTHON) examples/serve_retrieval.py
