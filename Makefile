PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: check compile test serve-bench bench serve example

# CI gate: byte-compile everything, then the tier-1 suite
check: compile test

compile:
	$(PYTHON) -m compileall -q src benchmarks examples tests

# Tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# Engine vs. naive-loop serving benchmark (QPS, p99, retrace count)
serve-bench:
	$(PYTHON) -m benchmarks.serve_bench --fast

# Full benchmark sweep (kernels, plan executor, serving)
bench:
	$(PYTHON) -m benchmarks.run

serve:
	$(PYTHON) -m repro.launch.serve --batches 4 --batch 64

example:
	$(PYTHON) examples/serve_retrieval.py
