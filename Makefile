PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: check compile test serve-bench cluster-bench proc-bench cluster-smoke proc-smoke trace-smoke index-smoke index-bench degrade-bench hotpath-bench bench-diff bench serve example

# CI gate: byte-compile everything, then the tier-1 suite
check: compile test

compile:
	$(PYTHON) -m compileall -q src benchmarks examples tests

# Tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# Engine vs. naive-loop serving benchmark (QPS, p99, retrace count)
serve-bench:
	$(PYTHON) -m benchmarks.serve_bench --fast

# Replica scaling / routing / shedding benchmark (docs/cluster.md)
cluster-bench:
	$(PYTHON) -m benchmarks.cluster_bench --fast --replicas 1,2

# Thread-vs-process replica backend sweep: fleet QPS / p99 / worker
# RSS per replica count, the smaps proof of one shared index mapping,
# and a FULL bit-parity check between backends (docs/cluster.md)
proc-bench:
	$(PYTHON) -m benchmarks.cluster_bench --fast --replicas 1,2,4 \
		--backend-sweep

# CI smoke: 2 replicas, tiny corpus, 2 publish cycles, zero dropped,
# trainer fed from the served-traffic tap, and a burst the ladder must
# absorb with SHALLOW service instead of hard SHEDs
cluster-smoke:
	$(PYTHON) -m repro.launch.cluster --smoke

# CI smoke for the multi-process serving cell (docs/cluster.md):
# worker processes over shm rings serve a LIVE system while documents
# commit and the trainer publishes mid-stream.  Asserts zero dropped
# tickets, >= 3 policy versions and >= 2 index epochs applied inside
# the workers (control-pipe acks), and — from /proc/<pid>/smaps — that
# the workers' index mappings hold zero private-dirty pages: the fleet
# shares ONE physical copy of the base generation.
proc-smoke:
	$(PYTHON) -m repro.launch.cluster --smoke --replica-backend process \
		--out results/proc_smoke.json

# cluster-smoke with the observability plane on, BOTH backends.
# Thread: emits a Chrome trace (Perfetto-loadable) + merged fleet
# metrics snapshot, then validates both — well-formed events, matched
# B/E pairs, monotone ts, a full admit->queue->batch->execute->respond
# ticket chain, a trainer publish span, and per-(level,category)
# latency histograms.  Process: the same run through worker processes,
# additionally asserting the CROSS-PROCESS chain — at least one ticket
# whose merged track carries admit->ring->worker->execute->respond
# with worker spans tagged by pid, across >= 2 distinct worker pids —
# plus a statusz dump (docs/observability.md).
trace-smoke:
	$(PYTHON) -m repro.launch.cluster --smoke \
		--trace-out results/trace_smoke.json \
		--metrics-json results/metrics_smoke.json \
		--out results/cluster_smoke.json
	$(PYTHON) tools/check_trace.py results/trace_smoke.json \
		--require-chain --metrics results/metrics_smoke.json
	$(PYTHON) -m repro.launch.cluster --smoke --replica-backend process \
		--trace-out results/trace_smoke_proc.json \
		--metrics-json results/metrics_smoke_proc.json \
		--statusz-out results/statusz_smoke.json \
		--out results/proc_trace_smoke.json
	$(PYTHON) tools/check_trace.py results/trace_smoke_proc.json \
		--require-proc-chain --metrics results/metrics_smoke_proc.json
	$(PYTHON) tools/obsctl.py statusz results/statusz_smoke.json

# CI smoke for the tiered live index (docs/index.md): serve a
# freshness workload through the replica set while documents are
# added, epochs hot-swap, and the MergeDaemon compacts delta segments
# into new mmapped base generations underneath.  Asserts zero
# dropped/shed across >= 2 merges and >= 2 served epochs, and that the
# live (base + delta) view is bit-identical to a from-scratch rebuild
# at every published epoch, on both scan backends.
index-smoke:
	$(PYTHON) -m repro.launch.live_index --smoke \
		--out results/index_smoke.json

# Live-index scale benchmark: build/ingest/merge throughput and
# bytes-per-query (xla vs pallas_block_scan) at >= 1M docs
index-bench:
	$(PYTHON) -m benchmarks.run --index-bench

# Graceful-degradation sweep: ladder vs binary shedding across offered
# loads (p99 / served fraction / recall incl. SHALLOW / level mix)
degrade-bench:
	$(PYTHON) -m benchmarks.cluster_bench --fast --replicas 2 --degradation-only

# Batched data-plane microbenchmarks: per-stage ns/op (admission,
# cache probe, ring hop, batcher) for the per-ticket oracle vs the
# slab path, plus end-to-end QPS on both cluster backends
# (docs/benchmarks.md)
hotpath-bench:
	$(PYTHON) -m benchmarks.hotpath_bench --fast

# Perf-regression gate: coarse machine-independent invariants over
# results/*.json checked against the committed results/baselines/
# rows (slab >= per-ticket QPS, zero steady-state retraces, obs plane
# under its 5% budget, no silently dropped metrics)
bench-diff:
	$(PYTHON) tools/bench_compare.py

# Full benchmark sweep (kernels, plan executor, serving)
bench:
	$(PYTHON) -m benchmarks.run

serve:
	$(PYTHON) -m repro.launch.serve --batches 4 --batch 64

example:
	$(PYTHON) examples/serve_retrieval.py
