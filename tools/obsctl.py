#!/usr/bin/env python
"""obsctl: render the observability plane's JSON artifacts.

The cell writes everything as plain JSON (``repro.launch.cluster
--statusz-out / --metrics-json / --trace-out``, postmortem bundles
under ``<cell_dir>/postmortem/``); this tool is the read side — a
human-oriented formatter with no repro imports, so it runs anywhere a
bundle landed.

    python tools/obsctl.py statusz results/statusz.json
    python tools/obsctl.py metrics results/metrics.json --prefix serve.
    python tools/obsctl.py slo results/metrics.json --target 0.999
    python tools/obsctl.py bundle /tmp/cell/postmortem/postmortem-r0-001.json
    python tools/obsctl.py trace results/trace.json

Field reference: docs/observability.md.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter


def _load(path: str) -> dict:
    try:
        return json.loads(open(path).read())
    except (OSError, json.JSONDecodeError) as e:
        print(f"[obsctl] cannot read {path}: {e}")
        sys.exit(1)


def cmd_statusz(args) -> None:
    doc = _load(args.path)
    print(f"cell: backend={doc.get('backend')} "
          f"replicas={doc.get('n_replicas')} "
          f"state={doc.get('state', '?').upper()}")
    print(f"head: policy v{doc.get('head_policy_version')} "
          f"index epoch {doc.get('head_index_epoch')}")
    wd = doc.get("watchdog", {})
    print(f"watchdog: stale_after={wd.get('stale_after_s')}s "
          f"wedge_after={wd.get('wedge_after_s')}s")
    for r in doc.get("replicas", []):
        hb = r.get("heartbeat_age_s")
        hb_s = f"{hb * 1e3:.0f}ms" if hb is not None else "-"
        print(f"  r{r.get('replica')} [{r.get('state', '?'):>11s}] "
              f"pid={r.get('worker_pid') or '-'} hb={hb_s} "
              f"pending={r.get('pending')} "
              f"lag=v{r.get('policy_lag')}/e{r.get('epoch_lag')} "
              f"restarts={r.get('n_restarts')}")
    adm = doc.get("admission", {})
    if adm:
        print(f"admission: {json.dumps(adm)[:200]}")
    kinds = doc.get("events_tail_kinds", [])
    if kinds:
        print(f"events: {doc.get('events_recorded')} recorded, "
              f"tail: {' '.join(kinds)}")


def cmd_metrics(args) -> None:
    snap = _load(args.path)
    for key in sorted(snap):
        if args.prefix and not key.startswith(args.prefix):
            continue
        m = snap[key]
        t = m.get("type")
        if t == "counter":
            print(f"{key}  {m['value']}")
        elif t == "gauge":
            print(f"{key}  {m['value']:g} (max {m.get('max', 0):g}, "
                  f"agg={m.get('agg', 'max')})")
        elif t == "histogram":
            print(f"{key}  n={m['count']} sum={m.get('sum', 0):g}")
            if args.buckets:
                edges, counts = m["edges"], m["counts"]
                for i, c in enumerate(counts):
                    if not c:
                        continue
                    lo = edges[i - 1] if i else 0
                    hi = edges[i] if i < len(edges) else "inf"
                    print(f"    ({lo}, {hi}]: {c}")


def cmd_slo(args) -> None:
    """One-shot burn arithmetic over a single snapshot (cumulative
    rates, not windowed — the in-process SLOMonitor owns windows)."""
    sys.path.insert(0, str(__import__("pathlib").Path(
        __file__).resolve().parent.parent / "src"))
    from repro.obs import fold_snapshot

    snap = _load(args.path)
    fold = fold_snapshot(snap, args.latency_ms)
    budget = 1.0 - args.target
    rate = fold["bad"] / fold["total"] if fold["total"] else 0.0
    print(f"target={args.target} latency<={fold['effective_latency_slo_ms']:g}ms "
          f"(asked {args.latency_ms:g})")
    print(f"total={fold['total']} good={fold['good']} bad={fold['bad']} "
          f"(slow={fold['slow']} shed={fold['shed']})")
    print(f"error_rate={rate:.6f} budget={budget:.6f} "
          f"burn={rate / budget:.2f}" if budget else "degenerate target")


def cmd_bundle(args) -> None:
    doc = _load(args.path)
    print(f"bundle: {doc.get('bundle')} seq={doc.get('seq')} "
          f"reason={doc.get('reason')}")
    print(f"worker: replica={doc.get('replica')} "
          f"pid={doc.get('worker_pid')} restarts={doc.get('n_restarts')} "
          f"outstanding={doc.get('n_outstanding')}")
    print(f"config: {json.dumps(doc.get('config', {}))}")
    tb = doc.get("death_traceback")
    print(f"traceback: {'yes, ' + tb.strip().splitlines()[-1] if tb else 'none (SIGKILL leaves no traceback)'}")
    events = doc.get("events_tail", [])
    kinds = Counter(e.get("kind") for e in events)
    print(f"events_tail: {len(events)} "
          f"({', '.join(f'{k}={n}' for k, n in kinds.most_common())})")
    trace = doc.get("trace_tail", [])
    names = Counter(e.get("name") for e in trace)
    print(f"trace_tail: {len(trace)} spans "
          f"({', '.join(f'{k}={n}' for k, n in names.most_common(6))})")
    metrics = doc.get("metrics", {})
    print(f"metrics: {len(metrics)} keys")
    if args.verbose:
        print(json.dumps(doc.get("summary", {}), indent=1))


def cmd_trace(args) -> None:
    doc = _load(args.path)
    events = doc.get("traceEvents", [])
    phases = Counter(e.get("ph") for e in events)
    pids = sorted({e.get("pid") for e in events})
    names = Counter(e["name"] for e in events if e.get("ph") == "B")
    print(f"{len(events)} events across pids {pids} "
          f"({', '.join(f'{k}={n}' for k, n in sorted(phases.items()))})")
    print(f"top spans: {', '.join(f'{k}={n}' for k, n in names.most_common(8))}")
    print("open at ui.perfetto.dev")


def main() -> None:
    ap = argparse.ArgumentParser(prog="obsctl")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("statusz", help="render a statusz JSON")
    p.add_argument("path")
    p.set_defaults(fn=cmd_statusz)

    p = sub.add_parser("metrics", help="render a metrics snapshot")
    p.add_argument("path")
    p.add_argument("--prefix", default=None)
    p.add_argument("--buckets", action="store_true")
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser("slo", help="cumulative burn over one snapshot")
    p.add_argument("path")
    p.add_argument("--target", type=float, default=0.999)
    p.add_argument("--latency-ms", type=float, default=50.0)
    p.set_defaults(fn=cmd_slo)

    p = sub.add_parser("bundle", help="summarize a postmortem bundle")
    p.add_argument("path")
    p.add_argument("--verbose", action="store_true")
    p.set_defaults(fn=cmd_bundle)

    p = sub.add_parser("trace", help="summarize a Chrome trace export")
    p.add_argument("path")
    p.set_defaults(fn=cmd_trace)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
