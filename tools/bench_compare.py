"""Perf-regression gate over the shared ``results/*.json`` schema.

``results/`` holds the latest local run of every benchmark entrypoint
(benchmarks/_results.py); ``results/baselines/`` holds the committed
reference rows.  This tool turns the pair into a CI gate:

- **invariant rules** — coarse, machine-independent predicates on the
  CURRENT row (the slab front door must not serve slower than the
  per-ticket path; steady-state retraces must be zero; the obs plane
  must stay under its 5% QPS budget).  Wall-clock absolutes are NOT
  gated: CI runners and dev laptops differ by 10x and every row stamps
  ``n_cpus`` for exactly that reason.
- **schema drift** — every metric key the committed baseline row has
  must still exist in the current row (a silently dropped metric is a
  regression in coverage, not a win).

Benchmarks with a baseline but no fresh local row are skipped (CI only
re-runs the fast subset), so the gate never fails on coverage it did
not ask for.

Usage::

    python tools/bench_compare.py                 # gate, exit 1 on fail
    python tools/bench_compare.py --results results --baselines results/baselines
    make bench-diff
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Rule:
    """One invariant over a dotted metric path of a result row."""
    path: str                          # e.g. "metrics.thread_qps_ratio_b64"
    min: Optional[float] = None
    max: Optional[float] = None
    required: bool = True              # missing path is itself a violation?

    def check(self, row: dict) -> Optional[str]:
        v = lookup(row, self.path)
        if v is None:
            if self.required:
                return f"{self.path}: metric missing"
            return None
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            return f"{self.path}: not numeric ({v!r})"
        if self.min is not None and v < self.min:
            return f"{self.path}: {v:.4f} < min {self.min:.4f}"
        if self.max is not None and v > self.max:
            return f"{self.path}: {v:.4f} > max {self.max:.4f}"
        return None


def lookup(row: dict, dotted: str):
    """Walk a dotted path through nested dicts; None when absent."""
    node = row
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def metric_paths(node, prefix: str = "metrics") -> List[str]:
    """Flatten a row's metrics tree into dotted leaf paths."""
    out = []
    for k, v in node.items():
        p = f"{prefix}.{k}"
        if isinstance(v, dict):
            out.extend(metric_paths(v, p))
        else:
            out.append(p)
    return out


#: The coarse gates.  Ratios compare two numbers from the SAME run on
#: the SAME machine, so they hold anywhere; absolutes are deliberately
#: absent.  serve_bench's obs penalties are also hard-asserted inside
#: the bench — repeating them here keeps the gate meaningful when the
#: committed row predates a code change.
RULES = {
    "hotpath_bench": [
        Rule("metrics.engine_qps_ratio_b64", min=1.0),
        Rule("metrics.thread_qps_ratio_b64", min=1.0),
        Rule("metrics.process_qps_ratio_b32", min=1.0),
    ],
    "serve_bench": [
        Rule("metrics.engine_steady_state_retraces", max=0.0),
        Rule("metrics.speedup", min=1.0),
        Rule("metrics.obs.qps_penalty_frac", max=0.05),
        Rule("metrics.proc_obs.qps_penalty_frac", max=0.05),
    ],
    "cluster_bench": [],
    "index_bench": [],
    "kernel_bench": [],
}


def compare_row(name: str, current: Optional[dict],
                baseline: Optional[dict]) -> List[str]:
    """All violations for one benchmark.  ``current is None`` (bench
    not re-run locally) is a skip, not a failure."""
    if current is None:
        return []
    out = []
    for rule in RULES.get(name, []):
        err = rule.check(current)
        if err is not None:
            out.append(f"{name}: {err}")
    if baseline is not None:
        have = set(metric_paths(current.get("metrics", {})))
        for path in metric_paths(baseline.get("metrics", {})):
            if path not in have:
                out.append(f"{name}: {path} present in baseline but "
                           "missing from the current row")
    return out


def load_row(path: Path) -> Optional[dict]:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def run(results_dir: Path, baselines_dir: Path) -> List[str]:
    names = set(RULES)
    if baselines_dir.exists():
        names |= {p.stem for p in baselines_dir.glob("*.json")}
    violations = []
    for name in sorted(names):
        current = load_row(results_dir / f"{name}.json")
        baseline = load_row(baselines_dir / f"{name}.json")
        if current is None:
            status = "skip (no local row)"
        else:
            errs = compare_row(name, current, baseline)
            violations.extend(errs)
            status = f"FAIL ({len(errs)})" if errs else "ok"
        print(f"bench-diff  {name:<16} {status}")
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results", type=Path)
    ap.add_argument("--baselines", default=Path("results") / "baselines",
                    type=Path)
    a = ap.parse_args(argv)
    violations = run(a.results, a.baselines)
    if violations:
        print("\nbench-diff violations:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print("bench-diff: all gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
