#!/usr/bin/env python
"""Validate a Chrome trace-event JSON file emitted by repro.obs.

Checks (the `make trace-smoke` gate):

1. Shape — top-level ``traceEvents`` list; every event has name/ph/ts/
   pid/tid; only known phases (M, B, E, i).
2. Monotone timestamps — non-metadata events appear in non-decreasing
   ``ts`` order (Perfetto tolerates disorder; our exporter sorts, so
   disorder means the exporter broke).
3. Matched B/E pairs — per (pid, tid), B/E events nest like
   parentheses with matching names and nothing left open at EOF.
4. ``--require-chain`` — at least one ticket track carries the full
   admit → queue → batch → execute → respond span chain, and a
   trainer-side ``publish`` span exists (the smoke's acceptance
   criterion).
5. ``--require-proc-chain`` — the cross-PROCESS version: at least one
   ticket track carries admit → ring → worker → execute → respond,
   where the worker-side spans are tagged with the worker pid
   (``args.wpid``, stamped at merge time), and the trace as a whole
   saw spans from at least two distinct worker pids — proof that one
   merged timeline covers the parent and a multi-worker cell.
6. ``--metrics`` — the metrics snapshot JSON contains at least one
   per-(level, category) ``serve.latency_ms`` histogram.

Exit code 0 on success; prints the first failure and exits 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

KNOWN_PHASES = {"M", "B", "E", "i"}
REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")
TICKET_CHAIN = ("admit", "queue", "batch", "execute", "respond")
# Cross-process ticket chain: the parent records admit + ring (the
# worker round trip), the worker contributes worker/execute/respond on
# the same merged track.
PROC_CHAIN = ("admit", "ring", "worker", "execute", "respond")


def fail(msg: str) -> "None":
    print(f"[check_trace] FAIL: {msg}")
    sys.exit(1)


def check_trace(path: str, require_chain: bool,
                require_proc_chain: bool = False) -> dict:
    try:
        doc = json.loads(open(path).read())
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: not readable JSON ({e})")
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"),
                                                   list):
        fail(f"{path}: missing top-level traceEvents list")
    events = doc["traceEvents"]
    if not events:
        fail(f"{path}: traceEvents is empty")

    track_names = {}                      # (pid, tid) -> thread_name
    stacks = defaultdict(list)            # (pid, tid) -> open B names
    span_names = defaultdict(set)         # (pid, tid) -> completed spans
    track_wpids = defaultdict(set)        # (pid, tid) -> worker pids seen
    last_ts = None
    n_spans = 0
    for i, ev in enumerate(events):
        for k in REQUIRED_KEYS:
            if k not in ev:
                fail(f"event {i} missing key {k!r}: {ev}")
        ph = ev["ph"]
        if ph not in KNOWN_PHASES:
            fail(f"event {i}: unknown phase {ph!r}")
        if ph == "M":
            if ev["name"] == "thread_name":
                track_names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"event {i}: bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            fail(f"event {i}: ts went backwards ({ts} < {last_ts})")
        last_ts = ts
        key = (ev["pid"], ev["tid"])
        if ph == "B":
            stacks[key].append(ev["name"])
            wpid = (ev.get("args") or {}).get("wpid")
            if wpid is not None:
                track_wpids[key].add(wpid)
        elif ph == "E":
            if not stacks[key]:
                fail(f"event {i}: E {ev['name']!r} with no open B on "
                     f"tid {ev['tid']}")
            opened = stacks[key].pop()
            if opened != ev["name"]:
                fail(f"event {i}: E {ev['name']!r} closes B {opened!r} "
                     f"on tid {ev['tid']} (bad nesting)")
            span_names[key].add(ev["name"])
            n_spans += 1
    leftovers = {k: v for k, v in stacks.items() if v}
    if leftovers:
        fail(f"unclosed B events at EOF: {leftovers}")

    summary = {"n_events": len(events), "n_spans": n_spans,
               "n_tracks": len(track_names)}
    if require_chain:
        chained = [track_names.get(k, str(k)) for k, names in
                   span_names.items()
                   if all(step in names for step in TICKET_CHAIN)]
        if not chained:
            fail("no ticket track carries the full "
                 f"{' -> '.join(TICKET_CHAIN)} chain")
        published = [k for k, names in span_names.items()
                     if "publish" in names]
        if not published:
            fail("no trainer publish span found")
        summary["n_full_chain_tickets"] = len(chained)
        summary["example_chain_track"] = chained[0]
    if require_proc_chain:
        proc_chained = [k for k, names in span_names.items()
                        if all(step in names for step in PROC_CHAIN)
                        and track_wpids.get(k)]
        if not proc_chained:
            fail("no ticket track carries the full cross-process "
                 f"{' -> '.join(PROC_CHAIN)} chain with a wpid tag")
        all_wpids = set().union(*track_wpids.values()) if track_wpids \
            else set()
        if len(all_wpids) < 2:
            fail("merged trace covers worker pids "
                 f"{sorted(all_wpids)} — need spans from >= 2 workers")
        summary["n_proc_chain_tickets"] = len(proc_chained)
        summary["example_proc_chain_track"] = track_names.get(
            proc_chained[0], str(proc_chained[0]))
        summary["worker_pids"] = sorted(all_wpids)
    return summary


def check_metrics(path: str) -> dict:
    try:
        snap = json.loads(open(path).read())
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: not readable JSON ({e})")
    lat = {k: v for k, v in snap.items()
           if k.startswith("serve.latency_ms{") and "level=" in k
           and "category=" in k and v.get("type") == "histogram"}
    if not lat:
        fail(f"{path}: no per-(level, category) serve.latency_ms "
             "histograms in snapshot")
    recorded = sum(v["count"] for v in lat.values())
    if recorded <= 0:
        fail(f"{path}: latency histograms exist but hold no samples")
    return {"n_latency_histograms": len(lat), "n_samples": recorded}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--require-chain", action="store_true",
                    help="require a full ticket span chain + a trainer "
                         "publish span")
    ap.add_argument("--require-proc-chain", action="store_true",
                    help="require a cross-process ticket chain "
                         "(admit -> ring -> worker -> execute -> "
                         "respond) spanning >= 2 worker pids")
    ap.add_argument("--metrics", default=None,
                    help="also validate a metrics snapshot JSON")
    args = ap.parse_args()

    summary = check_trace(args.trace, require_chain=args.require_chain,
                          require_proc_chain=args.require_proc_chain)
    if args.metrics:
        summary.update(check_metrics(args.metrics))
    print(f"[check_trace] OK: {json.dumps(summary)}")


if __name__ == "__main__":
    main()
