"""Online learning: candidate quality improves across policy snapshot
versions while a replica set serves without interruption.

The paper's deployment story in one process (docs/cluster.md): a
`TrainerLoop` Q-learns per-category match policies on a background
thread and publishes eval-gated snapshots into a `PolicyStore`; a
2-replica `ReplicaSet` keeps serving throughout, hot-swapping each new
version at its next drain.  The demo tracks a recall proxy (fraction of
positively judged docs retrieved, `cluster.candidate_recall`) per
served policy version and checks the three properties the subsystem
promises:

  1. >= 3 snapshot versions published while serving never stops,
  2. every non-shed response comes from a version within the store's
     staleness bound,
  3. per-version candidate quality is monotone non-decreasing (the
     trainer's eval gate never promotes a regression).

    PYTHONPATH=src python examples/online_learning.py
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.cluster import (ClusterConfig, ReplicaSet, Shed, TrainerConfig,
                           TrainerLoop, candidate_recall)
from repro.data.querylog import QueryLogConfig
from repro.index.corpus import CorpusConfig
from repro.policies import PolicyStore
from repro.serving import EngineConfig
from repro.system import RetrievalSystem, SystemConfig

STALENESS_BOUND = 2


def probe_pass(cluster, probe_qids, log):
    """Serve the probe set once; returns (version, mean recall) if every
    response came from one snapshot version, else None (a publish landed
    mid-pass — the caller just retries; the cache makes retries cheap)."""
    responses = cluster.serve(probe_qids)
    served = [r for r in responses if not isinstance(r, Shed)]
    versions = {r.policy_version for r in served}
    if len(versions) != 1:
        return None
    ids = np.stack([r.doc_ids for r in served])
    qids = np.asarray([r.qid for r in served])
    recall = candidate_recall(ids, log.judged_ids[qids],
                              log.judged_gains[qids]).mean()
    return versions.pop(), float(recall)


def main() -> None:
    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=4096, vocab_size=1024, seed=0),
        querylog=QueryLogConfig(n_queries=400, seed=0),
        block_docs=256, p_bins=256, u_budget=1024, l1_steps=150,
    ))
    sys_.fit_l1(n_queries=96)
    sys_.fit_state_bins(n_queries=64)

    store = PolicyStore(staleness_bound=STALENESS_BOUND)
    trainer = TrainerLoop(sys_, store, cfg=TrainerConfig(
        iters=45, publish_every=15, batch=32, probe_queries=24,
        publish_initial=False))
    trainer.publish_now()                       # v1: untrained tables
    probe_qids = np.concatenate(list(trainer.probe_qids.values()))

    cluster = ReplicaSet(
        sys_, store, ClusterConfig(n_replicas=2, routing="queue_aware"),
        EngineConfig(min_bucket=8, max_bucket=32, cache_capacity=512))
    trainer.source = cluster.tap      # train from served traffic, not the log
    cluster.warmup()

    rng = np.random.default_rng(0)
    quality = {}                                # version -> mean recall
    n_background = 0
    t0 = time.time()
    with cluster:
        trainer.start()
        while True:
            head = store.version
            if head not in quality:
                got = probe_pass(cluster, probe_qids, sys_.log)
                if got is not None and got[0] not in quality:
                    quality[got[0]] = got[1]
                    print(f"[v{got[0]}] probe recall {got[1]:.4f} "
                          f"(t={time.time() - t0:.0f}s, "
                          f"background={n_background})")
            if not trainer.alive and store.version in quality:
                break
            # serving never stops: background traffic between probes
            cluster.serve(rng.integers(0, sys_.log.n_queries, size=16))
            n_background += 16
        trainer.join()
    stats = cluster.stats()

    versions = sorted(quality)
    recalls = [quality[v] for v in versions]
    print(json.dumps({
        "versions": versions,
        "recall_per_version": recalls,
        "gate_history": trainer.history,
        "background_queries": n_background,
        "shed_rate": stats["shed_rate"],
        "version_lag_observed_max": stats["version_lag_observed_max"],
        "latency_p99_ms": round(stats["latency_p99_ms"], 2),
    }, indent=1))

    assert len(versions) >= 3, f"expected >= 3 versions, saw {versions}"
    assert stats["n_submitted"] == stats["n_responses"] + stats["n_shed"], \
        "dropped queries"
    assert trainer.tap_batches > 0 and trainer.log_batches == 0, \
        "trainer must train from served traffic only"
    assert stats["version_lag_observed_max"] <= STALENESS_BOUND, \
        "served beyond the staleness bound"
    for a, b in zip(recalls, recalls[1:]):
        assert b >= a - 1e-9, f"quality regressed across versions: {recalls}"
    print(f"OK: {len(versions)} versions, recall "
          f"{recalls[0]:.4f} -> {recalls[-1]:.4f}, serving never stopped")


if __name__ == "__main__":
    main()
