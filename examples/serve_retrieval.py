"""Serve queries through the online engine: L0 policy → shard merge →
L1 prune, with admission, result caching and shape-bucketed
micro-batching (docs/serving.md).

Demonstrates the unified Policy API (docs/policies.md): trained
Q-table policies are published to a versioned PolicyStore, the engine
serves snapshot v1, and publishing the hand-tuned static plans as v2
hot-swaps the serving policy — no engine restart, result cache
flushed, new executables compiled for the new policy structure.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import json

import numpy as np

from repro.data.querylog import CAT1, CAT2, QueryLogConfig
from repro.index.corpus import CorpusConfig
from repro.serving import EngineConfig, ServeEngine
from repro.system import RetrievalSystem, SystemConfig


def main() -> None:
    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=4096, vocab_size=1024, seed=0),
        querylog=QueryLogConfig(n_queries=400, seed=0),
        block_docs=256, p_bins=256, u_budget=1024, l1_steps=100,
    ))
    sys_.fit_l1(n_queries=96)
    sys_.fit_state_bins(n_queries=64)
    store = sys_.train_policy_store(cats=(CAT1, CAT2), iters=60, batch=32)

    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=8, max_bucket=32, cache_capacity=512, n_shards=2))
    engine.warmup()

    rng = np.random.default_rng(0)
    qids = rng.integers(0, sys_.log.n_queries, size=96)
    learned = engine.serve(qids)

    r0 = learned[0]
    print(f"query {r0.qid} (cat {r0.category}): u={r0.u} "
          f"top doc ids {r0.doc_ids[:5].tolist()} "
          f"[policy snapshot v{engine.policy_version}]")

    # Hot-swap: publish the hand-tuned production plans as snapshot v2.
    # The same engine serves them on the next drain — the baseline is
    # just another Policy.
    store.publish(sys_.baseline_policies((CAT1, CAT2)))
    baseline = engine.serve(qids)
    u_learned = np.mean([r.u for r in learned])
    u_baseline = np.mean([r.u for r in baseline])
    print(f"hot-swapped to v{engine.policy_version}: "
          f"mean u learned={u_learned:.0f} vs static plan={u_baseline:.0f} "
          f"({100 * (u_learned - u_baseline) / u_baseline:+.1f}%)")

    print("engine summary:", json.dumps(engine.summary(), indent=1))


if __name__ == "__main__":
    main()
