"""Serve batched queries through the full telescope: L0 learned policy
→ L1 prune → ranked results, with block-accounting per query.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import subprocess
import sys

# The serving driver is a first-class launcher; this example just runs a
# small configuration of it.
subprocess.run([
    sys.executable, "-m", "repro.launch.serve",
    "--n-docs", "4096", "--n-queries", "400",
    "--batch", "32", "--batches", "2", "--iters", "60",
], check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
