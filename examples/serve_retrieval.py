"""Serve queries through the online engine: L0 learned policy → shard
merge → L1 prune, with admission, result caching and shape-bucketed
micro-batching (docs/serving.md).

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import json

import numpy as np

from repro.data.querylog import CAT1, CAT2, QueryLogConfig
from repro.index.corpus import CorpusConfig
from repro.serving import EngineConfig, ServeEngine
from repro.system import RetrievalSystem, SystemConfig


def main() -> None:
    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=4096, vocab_size=1024, seed=0),
        querylog=QueryLogConfig(n_queries=400, seed=0),
        block_docs=256, p_bins=256, u_budget=1024, l1_steps=100,
    ))
    sys_.fit_l1(n_queries=96)
    sys_.fit_state_bins(n_queries=64)
    policies = {cat: sys_.train_policy(cat, iters=60, batch=32)[0]
                for cat in (CAT1, CAT2)}

    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=32, cache_capacity=512, n_shards=2))
    engine.warmup()

    rng = np.random.default_rng(0)
    qids = rng.integers(0, sys_.log.n_queries, size=96)
    responses = engine.serve(qids)

    r0 = responses[0]
    print(f"query {r0.qid} (cat {r0.category}): u={r0.u} "
          f"top doc ids {r0.doc_ids[:5].tolist()}")
    print("engine summary:", json.dumps(engine.summary(), indent=1))


if __name__ == "__main__":
    main()
