"""Train the paper's RL match-planning policy end to end and reproduce
the Table-1-style result (blocks accessed down, NCG ~flat).

    PYTHONPATH=src python examples/train_policy.py
"""
import numpy as np

from repro.data.querylog import CAT1, CAT2, QueryLogConfig
from repro.index.corpus import CorpusConfig
from repro.ranking.metrics import relative_delta
from repro.system import RetrievalSystem, SystemConfig

sys_ = RetrievalSystem(SystemConfig(
    corpus=CorpusConfig(n_docs=4096, vocab_size=2048, seed=0),
    querylog=QueryLogConfig(n_queries=1000, seed=0),
    block_docs=256, p_bins=1024, u_budget=1024, l1_steps=300,
))
print("L1 ranker ...")
sys_.fit_l1(n_queries=128, batch=16)
print("state bins (harvesting baseline (u,v) trajectories) ...")
sys_.fit_state_bins(n_queries=96, batch=32)

for cat, name in ((CAT2, "CAT2"), (CAT1, "CAT1")):
    q, hist = sys_.train_policy(cat, iters=150, batch=48, log_every=30)
    qids = np.where(sys_.log.category == cat)[0][:192]
    res = sys_.evaluate(q, qids, cat)
    print(f"[{name}] blocks accessed {relative_delta(res['policy_u'], res['baseline_u']):+.1f}%  "
          f"NCG@100 {relative_delta(res['policy_ncg'], res['baseline_ncg']):+.1f}%  "
          f"(paper: CAT2 −22.7%/+0.2%, CAT1 −17.5%/−1.8%)")
