"""Train a reduced LM (same code path the dry-run lowers at 12B-314B
scale) for a few hundred steps on CPU, with an injected mid-run failure
to demonstrate checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py
"""
import subprocess
import sys

subprocess.run([
    sys.executable, "-m", "repro.launch.train", "lm",
    "--arch", "starcoder2-3b", "--steps", "60", "--inject-failure",
    "--ckpt-dir", "results/ckpt_lm_example",
], check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
