"""Quickstart: build a tiny web index, run the production match plans,
inspect candidates + NCG — the paper's L0 stage in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data.querylog import CAT1, CAT2, QueryLogConfig
from repro.index.corpus import CorpusConfig
from repro.ranking.metrics import batched_ncg
from repro.system import RetrievalSystem, SystemConfig

sys_ = RetrievalSystem(SystemConfig(
    corpus=CorpusConfig(n_docs=2048, vocab_size=1024, seed=0),
    querylog=QueryLogConfig(n_queries=200, seed=0),
    block_docs=256, p_bins=256, l1_steps=100,
))
sys_.fit_l1(n_queries=48, batch=16)

for cat, name in ((CAT1, "CAT1 (rare multi-term)"), (CAT2, "CAT2 (navigational)")):
    qids = np.where(sys_.log.category == cat)[0][:32]
    final, traj, _ = sys_.run_baseline(qids, cat)
    judged_ids, judged_gains = sys_.judged(qids)
    ncg = batched_ncg(final.cand, judged_ids, judged_gains)
    print(f"{name}: mean u={np.asarray(final.u).mean():.1f} blocks, "
          f"candidates={np.asarray(final.cand_cnt).mean():.1f}, "
          f"NCG@100={np.asarray(ncg).mean():.3f}")

q = qids[0]
terms = sys_.log.terms[q][sys_.log.terms[q] >= 0]
print(f"\nexample query {q}: terms={terms.tolist()} "
      f"(df={sys_.index.df[terms, 2].tolist()} in body)")
