from .ops import *
