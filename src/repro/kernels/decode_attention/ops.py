"""Jitted entry points for decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import cdiv
from .decode_attention import decode_attention_pallas
from .ref import decode_attention_ref, merge_partials_ref

__all__ = ["decode_attention", "decode_attention_reference", "merge_partials"]


@partial(jax.jit, static_argnames=("block_k", "return_partial", "interpret"))
def decode_attention(q, k, v, block_k: int = 512, return_partial: bool = False,
                     interpret=None):
    """Pads the KV length to a block multiple and runs the kernel."""
    s = k.shape[2]
    bk = min(block_k, max(128, 1 << (s - 1).bit_length()))
    s_p = cdiv(s, bk) * bk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_p - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_p - s), (0, 0)))
    return decode_attention_pallas(
        q, kp, vp, block_k=bk, kv_len=s, return_partial=return_partial,
        interpret=interpret,
    )


decode_attention_reference = jax.jit(
    decode_attention_ref, static_argnames=("return_partial",)
)
merge_partials = jax.jit(merge_partials_ref)
