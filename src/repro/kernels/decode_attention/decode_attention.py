"""Pallas TPU kernel: single-token decode attention over a long KV cache
(flash-decoding style).

Decode shapes (decode_32k / long_500k) are memory-bound: one query row
attends over S cached keys — arithmetic intensity ≈ 1 FLOP/byte, so the
kernel's job is to stream KV at full HBM bandwidth.  The KV sequence is
tiled; a VMEM scratch keeps the running (m, l, acc) and the output is
written on the last tile (same online-softmax recurrence as prefill
flash, with bq=8 query rows — the minimum sublane tile — of which only
the real rows are used).

For sequence-sharded KV (long_500k), each shard runs this kernel over
its local S/shards slice and the partial (m, l, acc) are LSE-merged
across the `model` axis (models/attention.py::merge_partial_attention).
Hence the kernel optionally RETURNS the partials instead of the
normalized output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, NEG_INF, cdiv, tpu_compiler_params

__all__ = ["decode_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr,
            *, scale, bk, n_kv_blocks, kv_len, return_partial):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, d) — bq=8 sublane pad
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        if return_partial:
            o_ref[0] = acc_scr[...].astype(o_ref.dtype)
            m_ref[0] = m_scr[...].astype(m_ref.dtype)
            l_ref[0] = l.astype(l_ref.dtype)
        else:
            o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
            m_ref[0] = m_scr[...].astype(m_ref.dtype)
            l_ref[0] = l.astype(l_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,        # (B, Hq, D) one new token per sequence
    k: jnp.ndarray,        # (B, Hkv, S, D)
    v: jnp.ndarray,        # (B, Hkv, S, D)
    *,
    scale: float | None = None,
    block_k: int = 512,
    kv_len: int | None = None,
    return_partial: bool = False,
    interpret: bool | None = None,
):
    """Returns (out (B, Hq, D), m (B, Hq, 1), l (B, Hq, 1)); if
    return_partial, ``out`` is the unnormalized accumulator for cross-
    shard LSE merging."""
    interpret = INTERPRET if interpret is None else interpret
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kv_len = s if kv_len is None else kv_len

    bk = min(block_k, s)
    assert s % bk == 0, "pad KV length to block multiple"
    nk = s // bk

    # Tile q by KV-head group: every row of a (group_p, d) tile shares the
    # same kv head, so the kv BlockSpec is exact for any GQA ratio.
    bq = cdiv(group, 8) * 8                    # sublane-pad the group
    qp = q.reshape(b, hkv, group, d)
    qp = jnp.pad(qp, ((0, 0), (0, 0), (0, bq - group), (0, 0)))
    qp = qp.reshape(b * hkv, bq, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    kernel = functools.partial(
        _kernel, scale=scale, bk=bk, n_kv_blocks=nk, kv_len=kv_len,
        return_partial=return_partial,
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda t, ki: (t, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda t, ki: (t, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda t, ki: (t, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda t, ki: (t, 0, 0)),
            pl.BlockSpec((1, bq, 1), lambda t, ki: (t, 0, 0)),
            pl.BlockSpec((1, bq, 1), lambda t, ki: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, bq, d), q.dtype),
            jax.ShapeDtypeStruct((b * hkv, bq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, bq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(qp, kf, vf)

    def unpack(x):
        x = x.reshape(b, hkv, bq, x.shape[-1])[:, :, :group]
        return x.reshape(b, hq, x.shape[-1])

    return unpack(out), unpack(m), unpack(l)
