"""Pure-jnp oracle for decode attention, including the partial
(m, l, acc) form used for sequence-sharded LSE merging."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["decode_attention_ref", "merge_partials_ref"]


def decode_attention_ref(q, k, v, *, scale=None, kv_len=None, return_partial=False):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D)."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kv_len = s if kv_len is None else kv_len

    kx = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kx) * scale
    mask = jnp.arange(s) < kv_len
    sc = jnp.where(mask[None, None], sc, -jnp.inf)

    m = jnp.max(sc, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(sc), jnp.exp(sc - m_safe), 0.0)
    l = p.sum(-1, keepdims=True)
    acc = jnp.einsum("bhk,bhkd->bhd", p, vx)
    if return_partial:
        return acc.astype(q.dtype), m, l
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype), m, l


def merge_partials_ref(accs, ms, ls):
    """Merge per-shard partials: lists of (B, H, D), (B, H, 1), (B, H, 1)."""
    m_all = jnp.max(jnp.stack(ms), axis=0)
    m_safe = jnp.where(jnp.isfinite(m_all), m_all, 0.0)
    l_tot = sum(l * jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0) for m, l in zip(ms, ls))
    acc_tot = sum(
        a.astype(jnp.float32) * jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        for a, m in zip(accs, ms)
    )
    return (acc_tot / jnp.maximum(l_tot, 1e-30)).astype(accs[0].dtype)
