"""Jitted entry points for flash attention, with sequence padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import cdiv
from .flash_attention import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_reference"]


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret=None):
    """Pads Sq/Skv to block multiples, runs the kernel, slices back.
    Padding keys are masked out by the causal structure for causal=True;
    for bidirectional attention we mask via an explicit -inf key pad."""
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (skv - 1).bit_length()))
    sq_p = cdiv(sq, bq) * bq
    skv_p = cdiv(skv, bk) * bk

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, block_q=bq, block_k=bk, kv_len=skv, q_len=sq,
        interpret=interpret,
    )
    return out[:, :, :sq, :]


flash_attention_reference = jax.jit(attention_ref, static_argnames=("causal",))
