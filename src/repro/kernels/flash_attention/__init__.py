from .ops import *
