"""Pure-jnp oracle for flash attention (GQA + causal)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) → (B, Hq, Sq, D), fp32 math."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    kx = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / denom, vx)
    return o.astype(q.dtype)
