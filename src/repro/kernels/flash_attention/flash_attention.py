"""Pallas TPU flash attention (forward), causal + GQA.

Online-softmax over KV tiles with VMEM scratch accumulators — the
standard TPU formulation: grid (batch·q_heads, q_blocks, kv_blocks)
with the kv dimension 'arbitrary' (sequential) so the running max/sum/
accumulator live in VMEM scratch across kv steps.  GQA is free: the
kv BlockSpec index-maps a group of q heads onto their shared kv head,
so KV is never materialized per-q-head.

Causal masking skips fully-masked kv blocks via @pl.when (no FLOPs, no
HBM reads are wasted on them — the Pallas pipeline still fetches the
block, which the hillclimb log discusses) and applies a triangular mask
on the diagonal blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, NEG_INF, cdiv, tpu_compiler_params

__all__ = ["flash_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, bq, bk,
            n_kv_blocks, kv_len, causal_offset):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # For causal attention, blocks strictly above the diagonal contribute
    # nothing: q_pos_max = (qi+1)*bq - 1 < ki*bk = k_pos_min.
    run = True
    if causal:
        # query row i attends to keys <= i + causal_offset (offset = kv_len - sq)
        run = (qi + 1) * bq - 1 + causal_offset >= ki * bk

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (bq, bk)

        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = k_pos < kv_len                          # mask padded keys
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = valid & (q_pos + causal_offset >= k_pos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (all -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)

        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)

        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,        # (B, Hq, Sq, D)
    k: jnp.ndarray,        # (B, Hkv, Skv, D)
    v: jnp.ndarray,        # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    kv_len: int | None = None,   # true (unpadded) kv length
    q_len: int | None = None,    # true (unpadded) q length (for the causal offset)
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = INTERPRET if interpret is None else interpret
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires q_heads % kv_heads == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad seq lens to block multiples"
    nq, nk = sq // bq, skv // bk

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_index(h, qi, ki):
        # fold q head -> kv head: global q-head index h = b*hq + i
        return (h // (group * hkv) * hkv + (h % hq) // group, ki, 0)

    kv_len_eff = kv_len if kv_len is not None else skv
    q_len_eff = q_len if q_len is not None else sq
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_kv_blocks=nk,
        kv_len=kv_len_eff, causal_offset=kv_len_eff - q_len_eff,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_fwd",
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
