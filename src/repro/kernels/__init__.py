from .common import INTERPRET
