"""Shared kernel utilities.

Kernels TARGET TPU (BlockSpec/VMEM tiling, MXU-aligned shapes) and are
VALIDATED on CPU via ``interpret=True`` — the kernel body executes in
Python with the same block/grid semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["INTERPRET", "pad_axis_to", "cdiv", "NEG_INF"]

INTERPRET = jax.default_backend() != "tpu"
NEG_INF = float("-inf")


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_axis_to(x: jnp.ndarray, axis: int, multiple: int, value=0):
    """Pad ``axis`` of x up to the next multiple. Returns (padded, orig_len)."""
    n = x.shape[axis]
    target = cdiv(n, multiple) * multiple
    if target == n:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=value), n
