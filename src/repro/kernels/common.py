"""Shared kernel utilities.

Kernels TARGET TPU (BlockSpec/VMEM tiling, MXU-aligned shapes) and are
VALIDATED on CPU via ``interpret=True`` — the kernel body executes in
Python with the same block/grid semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

__all__ = ["INTERPRET", "pad_axis_to", "cdiv", "NEG_INF", "tpu_compiler_params",
           "reduce_or", "reduce_and"]

INTERPRET = jax.default_backend() != "tpu"
NEG_INF = float("-inf")

# Renamed TPUCompilerParams -> CompilerParams across jax releases.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    return _COMPILER_PARAMS_CLS(**kwargs)


# lax.reduce_or / lax.reduce_and sugar is missing from some jax releases;
# the generic lax.reduce lowers identically (and runs in pallas interpret).
def reduce_or(x: jnp.ndarray, axes) -> jnp.ndarray:
    if hasattr(jax.lax, "reduce_or"):
        return jax.lax.reduce_or(x, axes=tuple(axes))
    return jax.lax.reduce(x, jnp.zeros((), x.dtype), jax.lax.bitwise_or,
                          tuple(axes))


def reduce_and(x: jnp.ndarray, axes) -> jnp.ndarray:
    if hasattr(jax.lax, "reduce_and"):
        return jax.lax.reduce_and(x, axes=tuple(axes))
    ones = jnp.array(~jnp.zeros((), x.dtype))
    return jax.lax.reduce(x, ones, jax.lax.bitwise_and, tuple(axes))


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_axis_to(x: jnp.ndarray, axis: int, multiple: int, value=0):
    """Pad ``axis`` of x up to the next multiple. Returns (padded, orig_len)."""
    n = x.shape[axis]
    target = cdiv(n, multiple) * multiple
    if target == n:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=value), n
