"""Plane-pruned block_scan: stream ONLY the match rule's active
(term, field) posting planes HBM→VMEM.

The baseline kernel (and the XLA executor path) DMAs the full
(T·F, W) occupancy tile per block and masks in VMEM — for a shallow
rule like mr_B (2 active planes of 16) that wastes 8× HBM bandwidth,
and the paper's whole point is that shallow rules are CHEAP.  Here the
active-plane list is a scalar-prefetch operand driving the occupancy
BlockSpec index_map, so the DMA engine fetches exactly
``n_active × W`` words per block: bytes streamed = u (the paper's cost
accumulator), not T·F·W.

Grid: (n_blocks, n_active).  The per-term OR is accumulated in VMEM
scratch across the plane steps of one block; conjunction + popcounts
happen on the last plane.  n_active is static (the rule is known at
trace time); planes are (term, field) pairs flattened to t*F+f.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, cdiv, reduce_and, tpu_compiler_params

__all__ = ["block_scan_pruned_pallas"]


def _kernel(meta_ref, occ_ref, match_ref, counts_ref, tf_scr,
            *, t: int, n_active: int):
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        tf_scr[...] = jnp.zeros_like(tf_scr)

    # meta row 0: plane ids (t*F+f); row 1: term id per active plane;
    # row 2: required-mask per term (length-t prefix).
    term = meta_ref[1, pi]
    plane = occ_ref[0]                                  # (1, W) active plane
    # OR this plane into its term's running bitmap.
    row = tf_scr[term]
    tf_scr[term] = row | plane[0]

    @pl.when(pi == n_active - 1)
    def _finalize():
        tf = tf_scr[...]                                # (t, W)
        full = jnp.uint32(0xFFFFFFFF)
        req = meta_ref[2, :t].astype(jnp.uint32)        # (t,) 0/1
        conj = tf | (full * (jnp.uint32(1) - req))[:, None]
        match = reduce_and(conj, (0,))
        any_req = (jnp.sum(req) > 0).astype(jnp.uint32)
        match = match * any_req
        match_ref[0] = match
        v_inc = jnp.sum(jax.lax.population_count(tf).astype(jnp.int32))
        n_match = jnp.sum(jax.lax.population_count(match).astype(jnp.int32))
        counts_ref[0, 0] = v_inc
        counts_ref[0, 1] = n_match


def block_scan_pruned_pallas(
    occ: jnp.ndarray,            # (n_blocks, T, F, W) uint32
    allowed: np.ndarray,         # (T, F) bool — STATIC (host) rule mask
    required: np.ndarray,        # (T,) bool — static
    term_present: np.ndarray,    # (T,) bool — static
    *,
    interpret: bool | None = None,
):
    """Returns (match (nb, W) u32, v_inc (nb,) i32, n_match (nb,) i32).
    The rule is static: only its active planes are ever read from HBM."""
    interpret = INTERPRET if interpret is None else interpret
    nb, t, f, w = occ.shape
    amask = np.asarray(allowed) & np.asarray(term_present)[:, None]
    planes = np.argwhere(amask.reshape(-1)).ravel()       # active plane ids
    n_active = max(len(planes), 1)
    if len(planes) == 0:
        planes = np.array([0])

    meta = np.zeros((3, max(t * f, t)), np.int32)
    meta[0, :n_active] = planes
    meta[1, :n_active] = planes // f                      # term of each plane
    meta[2, :t] = (np.asarray(required) & np.asarray(term_present)).astype(np.int32)

    occ2 = occ.reshape(nb, t * f, w)

    kernel = functools.partial(_kernel, t=t, n_active=n_active)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n_active),
        in_specs=[
            # stream exactly the active plane for this grid step
            pl.BlockSpec((1, 1, w), lambda b, p, m: (b, m[0, p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w), lambda b, p, m: (b, 0)),
            pl.BlockSpec((1, 8), lambda b, p, m: (b, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((t, w), jnp.uint32)],
    )
    match, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb, w), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 8), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="block_scan_pruned",
    )(jnp.asarray(meta), occ2)
    return match, counts[:, 0], counts[:, 1]
