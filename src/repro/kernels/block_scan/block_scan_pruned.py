"""Plane-pruned block_scan: stream ONLY the match rule's active
(term, field) posting planes HBM→VMEM.

The baseline kernel (and the XLA executor path) DMAs the full
(T·F, W) occupancy tile per block and masks in VMEM — for a shallow
rule like mr_B (2 active planes of 16) that wastes 8× HBM bandwidth,
and the paper's whole point is that shallow rules are CHEAP.  Here the
active-plane list is a scalar-prefetch operand driving the occupancy
BlockSpec index_map, so the DMA engine fetches exactly
``n_active × W`` words per block: bytes streamed = u (the paper's cost
accumulator), not T·F·W.

Two entry points:

``block_scan_pruned_pallas``
    The original static-rule kernel: the rule masks are host (numpy)
    values, the active-plane list is computed at trace time, and the
    grid covers exactly the active planes.  Grid: (n_blocks, n_active).

``block_scan_pruned_chunk``
    The serving/rollout variant behind the ``pallas_block_scan`` scan
    backend (core/scan_backends.py): rule masks are TRACED (chosen by
    the policy at runtime), so the plane-step count is the static
    worst case P = T·F and a per-step validity flag in the prefetched
    meta masks the padding steps.  Padding steps map to the last
    active plane, so the Pallas pipeline's revisiting-block elision
    skips their DMA — bytes streamed stays ∝ n_active, not P.  The
    kernel processes a static chunk of C consecutive blocks per launch
    for a whole query batch: grid (B, C, P), block start per lane read
    from the meta.  Inactive lanes / out-of-range blocks are clamped
    to block n_blocks-1 and masked by the caller.

Semantics are pinned against ``block_scan_reference``
(kernels/block_scan/ref.py → core.match_rules.scan_block) for every
edge, including rules with ZERO active planes (the occupancy read is
fully masked: match = 0, v_inc = 0) and rules with no required terms
(match = 0 but v_inc still counts term hits among the planes the rule
paid to inspect — u is charged, so v is too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, cdiv, reduce_and, tpu_compiler_params

__all__ = [
    "block_scan_pruned_pallas", "block_scan_pruned_chunk", "build_rule_meta",
    "META_ROWS", "META_BP_COL",
]


def _kernel(meta_ref, occ_ref, match_ref, counts_ref, tf_scr,
            *, t: int, n_active: int):
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        tf_scr[...] = jnp.zeros_like(tf_scr)

    # meta row 0: plane ids (t*F+f); row 1: term id per active plane;
    # row 2: step-valid flag (0 for the padding plane when the rule has
    # no active planes); row 3: required-mask per term (length-t prefix).
    term = meta_ref[1, pi]
    valid = meta_ref[2, pi].astype(jnp.uint32)
    plane = occ_ref[0]                                  # (1, W) active plane
    # OR this plane into its term's running bitmap (masked when padding).
    row = tf_scr[term]
    tf_scr[term] = row | (plane[0] * valid)

    @pl.when(pi == n_active - 1)
    def _finalize():
        tf = tf_scr[...]                                # (t, W)
        full = jnp.uint32(0xFFFFFFFF)
        req = meta_ref[3, :t].astype(jnp.uint32)        # (t,) 0/1
        conj = tf | (full * (jnp.uint32(1) - req))[:, None]
        match = reduce_and(conj, (0,))
        any_req = (jnp.sum(req) > 0).astype(jnp.uint32)
        match = match * any_req
        match_ref[0] = match
        v_inc = jnp.sum(jax.lax.population_count(tf).astype(jnp.int32))
        n_match = jnp.sum(jax.lax.population_count(match).astype(jnp.int32))
        counts_ref[0, 0] = v_inc
        counts_ref[0, 1] = n_match


def block_scan_pruned_pallas(
    occ: jnp.ndarray,            # (n_blocks, T, F, W) uint32
    allowed: np.ndarray,         # (T, F) bool — STATIC (host) rule mask
    required: np.ndarray,        # (T,) bool — static
    term_present: np.ndarray,    # (T,) bool — static
    *,
    interpret: bool | None = None,
):
    """Returns (match (nb, W) u32, v_inc (nb,) i32, n_match (nb,) i32).
    The rule is static: only its active planes are ever read from HBM."""
    interpret = INTERPRET if interpret is None else interpret
    nb, t, f, w = occ.shape
    amask = np.asarray(allowed) & np.asarray(term_present)[:, None]
    planes = np.argwhere(amask.reshape(-1)).ravel()       # active plane ids
    n_steps = max(len(planes), 1)
    # A rule with zero active planes still launches one (masked) step so
    # the grid is non-empty; the valid flag keeps its occupancy read out
    # of tf (v_inc = 0, match = 0 — pinned against block_scan_reference).
    step_valid = np.ones(n_steps, np.int32)
    if len(planes) == 0:
        planes = np.array([0])
        step_valid[0] = 0

    meta = np.zeros((4, max(t * f, t)), np.int32)
    meta[0, :n_steps] = planes
    meta[1, :n_steps] = planes // f                       # term of each plane
    meta[2, :n_steps] = step_valid
    meta[3, :t] = (np.asarray(required) & np.asarray(term_present)).astype(np.int32)

    occ2 = occ.reshape(nb, t * f, w)

    kernel = functools.partial(_kernel, t=t, n_active=n_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n_steps),
        in_specs=[
            # stream exactly the active plane for this grid step
            pl.BlockSpec((1, 1, w), lambda b, p, m: (b, m[0, p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w), lambda b, p, m: (b, 0)),
            pl.BlockSpec((1, 8), lambda b, p, m: (b, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((t, w), jnp.uint32)],
    )
    match, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb, w), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 8), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="block_scan_pruned",
    )(jnp.asarray(meta), occ2)
    return match, counts[:, 0], counts[:, 1]


# --------------------------------------------------------- chunked variant
META_ROWS = 4          # plane id / term id / step valid / required per term
META_BP_COL = -1       # meta[:, 0, -1] holds the lane's block start


def build_rule_meta(
    allowed: jnp.ndarray,       # (B, T, F) bool — TRACED rule mask
    required: jnp.ndarray,      # (B, T) bool
    term_present: jnp.ndarray,  # (B, T) bool
    block_start: jnp.ndarray,   # (B,) int32 — first block of the chunk
) -> jnp.ndarray:
    """Scalar-prefetch meta for ``block_scan_pruned_chunk`` (traced).

    Active planes (allowed ∧ present, flattened t*F+f) are listed first
    via a stable argsort; the P - n_active padding steps repeat the LAST
    active plane with valid = 0, so the pipeline re-uses the resident
    VMEM buffer instead of issuing fresh DMAs for them.
    """
    b, t, f = allowed.shape
    p_steps = t * f
    act = (allowed & term_present[:, :, None]).reshape(b, p_steps)
    order = jnp.argsort(~act, axis=1, stable=True).astype(jnp.int32)
    n_active = jnp.sum(act, axis=1).astype(jnp.int32)
    last = jnp.take_along_axis(order, jnp.maximum(n_active - 1, 0)[:, None], axis=1)
    steps = jnp.arange(p_steps, dtype=jnp.int32)[None, :]
    valid = (steps < n_active[:, None]).astype(jnp.int32)
    plane_ids = jnp.where(valid == 1, order, last)

    ncols = max(p_steps + 1, t + 1, 8)
    meta = jnp.zeros((b, META_ROWS, ncols), jnp.int32)
    meta = meta.at[:, 0, :p_steps].set(plane_ids)
    meta = meta.at[:, 0, ncols - 1].set(block_start.astype(jnp.int32))
    meta = meta.at[:, 1, :p_steps].set(plane_ids // f)
    meta = meta.at[:, 2, :p_steps].set(valid)
    meta = meta.at[:, 3, :t].set((required & term_present).astype(jnp.int32))
    return meta


def _chunk_kernel(meta_ref, occ_ref, match_ref, counts_ref, tf_scr,
                  *, t: int, p_steps: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        tf_scr[...] = jnp.zeros_like(tf_scr)

    term = meta_ref[bi, 1, pi]
    valid = meta_ref[bi, 2, pi].astype(jnp.uint32)
    plane = occ_ref[0, 0]                               # (1, W) current plane
    row = tf_scr[term]
    tf_scr[term] = row | (plane[0] * valid)

    @pl.when(pi == p_steps - 1)
    def _finalize():
        tf = tf_scr[...]                                # (t, W)
        full = jnp.uint32(0xFFFFFFFF)
        req = meta_ref[bi, 3, :t].astype(jnp.uint32)
        conj = tf | (full * (jnp.uint32(1) - req))[:, None]
        match = reduce_and(conj, (0,))
        any_req = (jnp.sum(req) > 0).astype(jnp.uint32)
        match = match * any_req
        match_ref[0, 0] = match
        v_inc = jnp.sum(jax.lax.population_count(tf).astype(jnp.int32))
        n_match = jnp.sum(jax.lax.population_count(match).astype(jnp.int32))
        counts_ref[0, 0, 0] = v_inc
        counts_ref[0, 0, 1] = n_match


def block_scan_pruned_chunk(
    occ: jnp.ndarray,            # (B, n_blocks, T*F, W) uint32
    meta: jnp.ndarray,           # (B, META_ROWS, ncols) int32 — build_rule_meta
    *,
    chunk: int,
    n_terms: int,
    interpret: bool | None = None,
):
    """Evaluate each lane's (traced) rule over ``chunk`` consecutive
    blocks starting at the lane's block start.

    Returns (match (B, chunk, W) uint32, v_inc (B, chunk) int32,
    n_match (B, chunk) int32).  Blocks past n_blocks-1 are clamped to
    the last block — callers mask them (core/scan_backends.py masks by
    the stopping condition, which includes block_ptr < n_blocks).
    """
    interpret = INTERPRET if interpret is None else interpret
    b, nb, tf, w = occ.shape
    t = n_terms
    p_steps = tf
    ncols = meta.shape[-1]

    def occ_map(bi, c, p, m):
        blk = jnp.minimum(m[bi, 0, ncols - 1] + c, nb - 1)
        return (bi, blk, m[bi, 0, p], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, chunk, p_steps),
        in_specs=[pl.BlockSpec((1, 1, 1, w), occ_map)],
        out_specs=[
            pl.BlockSpec((1, 1, w), lambda bi, c, p, m: (bi, c, 0)),
            pl.BlockSpec((1, 1, 8), lambda bi, c, p, m: (bi, c, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((t, w), jnp.uint32)],
    )
    kernel = functools.partial(_chunk_kernel, t=t, p_steps=p_steps)
    match, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, chunk, w), jnp.uint32),
            jax.ShapeDtypeStruct((b, chunk, 8), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="block_scan_pruned_chunk",
    )(meta, occ)
    return match, counts[..., 0], counts[..., 1]
