"""Pallas TPU kernel: match-rule evaluation over bitpacked index blocks.

The paper's hot loop — "documents are scanned based on the chosen match
plan" — adapted to TPU (DESIGN.md §3): posting occupancy is streamed
HBM→VMEM in bitpacked tiles and evaluated with VPU bitwise ops +
population counts.  Deliberately MXU-free and memory-bound: its cost is
exactly the paper's ``u`` (bytes of index read).

Layout:
    occ      (n_blocks, T*F, W) uint32    one W-word plane per (term, field)
    masks    (8, T*F)           uint32    row 0: allowed∧present, row 1:
                                          required∧present per TERM group
                                          (padded to 8 rows for tiling)
Outputs per index block:
    match    (n_blocks, W)      uint32    docs satisfying ∧_t ∨_f occ
    counts   (n_blocks, 8)      int32     col 0: v increment (term matches),
                                          col 1: matched-doc count

Grid tiles BB index blocks per step; each VMEM tile is
BB × T·F × W × 4 B (e.g. 8 × 16 × 128 × 4 = 64 KiB), well inside the
~16 MiB VMEM budget, with double-buffered HBM streaming handled by the
Pallas pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, cdiv, reduce_and, reduce_or, tpu_compiler_params

__all__ = ["block_scan_pallas"]


def _kernel(occ_ref, masks_ref, match_ref, counts_ref, *, t: int, f: int):
    occ = occ_ref[...]                     # (BB, T*F, W) uint32
    masks = masks_ref[...]                 # (8, T*F)    uint32
    bb, tf, w = occ.shape

    allowed = masks[0]                     # (T*F,) 0/1  (already ∧ present)
    required = masks[1]                    # (T*F,) 0/1  (per-term, replicated over F)

    planes = occ * allowed[None, :, None]                 # (BB, T*F, W)
    grouped = planes.reshape(bb, t, f, w)
    tf_or = reduce_or(grouped, (2,))         # (BB, T, W)

    req = required.reshape(t, f)[:, 0]                    # (T,)
    full = jnp.uint32(0xFFFFFFFF)
    conj_in = tf_or | (full * (jnp.uint32(1) - req))[None, :, None]
    match = reduce_and(conj_in, (1,))        # (BB, W)
    any_req = (jnp.sum(req) > 0).astype(jnp.uint32)
    match = match * any_req

    v_inc = jnp.sum(jax.lax.population_count(tf_or).astype(jnp.int32), axis=(1, 2))
    n_match = jnp.sum(jax.lax.population_count(match).astype(jnp.int32), axis=1)

    match_ref[...] = match
    zeros = jnp.zeros((bb, 6), jnp.int32)
    counts_ref[...] = jnp.concatenate([v_inc[:, None], n_match[:, None], zeros], axis=1)


def block_scan_pallas(
    occ: jnp.ndarray,        # (n_blocks, T, F, W) uint32
    allowed: jnp.ndarray,    # (T, F) bool
    required: jnp.ndarray,   # (T,) bool
    term_present: jnp.ndarray,  # (T,) bool
    *,
    block_bb: int = 8,
    interpret: bool | None = None,
):
    """Evaluate one match rule over every index block.

    Returns (match_words (n_blocks, W) uint32, v_inc (n_blocks,) int32,
    n_match (n_blocks,) int32).
    """
    interpret = INTERPRET if interpret is None else interpret
    nb, t, f, w = occ.shape
    occ2 = occ.reshape(nb, t * f, w)
    pad = cdiv(nb, block_bb) * block_bb - nb
    if pad:
        occ2 = jnp.pad(occ2, ((0, pad), (0, 0), (0, 0)))

    amask = (allowed & term_present[:, None]).astype(jnp.uint32).reshape(t * f)
    rmask = jnp.broadcast_to(
        (required & term_present).astype(jnp.uint32)[:, None], (t, f)
    ).reshape(t * f)
    masks = jnp.zeros((8, t * f), jnp.uint32).at[0].set(amask).at[1].set(rmask)

    grid = (cdiv(nb, block_bb),)
    kernel = functools.partial(_kernel, t=t, f=f)
    match, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_bb, t * f, w), lambda b: (b, 0, 0)),
            pl.BlockSpec((8, t * f), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_bb, w), lambda b: (b, 0)),
            pl.BlockSpec((block_bb, 8), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid[0] * block_bb, w), jnp.uint32),
            jax.ShapeDtypeStruct((grid[0] * block_bb, 8), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="block_scan",
    )(occ2, masks)
    return match[:nb], counts[:nb, 0], counts[:nb, 1]
