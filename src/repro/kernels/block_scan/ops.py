"""Jitted public entry points for block_scan."""
from __future__ import annotations

from functools import partial

import jax

from .block_scan import block_scan_pallas
from .ref import block_scan_ref

__all__ = ["block_scan", "block_scan_batched", "block_scan_reference"]


@partial(jax.jit, static_argnames=("block_bb", "interpret"))
def block_scan(occ, allowed, required, term_present, block_bb: int = 8, interpret=None):
    return block_scan_pallas(
        occ, allowed, required, term_present, block_bb=block_bb, interpret=interpret
    )


@partial(jax.jit, static_argnames=("block_bb", "interpret"))
def block_scan_batched(occ, allowed, required, term_present, block_bb: int = 8, interpret=None):
    """vmap over a query batch: occ (Q, nb, T, F, W), masks (Q, ...)."""
    return jax.vmap(
        lambda o, a, r, t: block_scan_pallas(o, a, r, t, block_bb=block_bb, interpret=interpret)
    )(occ, allowed, required, term_present)


block_scan_reference = jax.jit(block_scan_ref)
