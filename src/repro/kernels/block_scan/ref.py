"""Pure-jnp oracle for the block_scan kernel: vmap of the single-block
evaluation the match engine itself uses (core.match_rules.scan_block)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.match_rules import scan_block

__all__ = ["block_scan_ref"]


def block_scan_ref(occ, allowed, required, term_present):
    """occ: (n_blocks, T, F, W) uint32 → (match (nb, W), v_inc (nb,), n_match (nb,))."""
    match, v_inc = jax.vmap(lambda o: scan_block(o, allowed, required, term_present))(occ)
    n_match = jnp.sum(jax.lax.population_count(match).astype(jnp.int32), axis=1)
    return match, v_inc, n_match
