from .ops import *
