from .ops import *
