"""Pure-jnp EmbeddingBag oracle (take + masked reduce)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_ref"]


def embedding_bag_ref(table, indices, weights=None, *, mode: str = "sum"):
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe, axis=0).astype(jnp.float32)      # (B, L, E)
    if weights is None:
        w = valid.astype(jnp.float32)
    else:
        w = jnp.where(valid, weights, 0.0).astype(jnp.float32)
    out = jnp.sum(rows * w[..., None], axis=1)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(1, keepdims=True), 1)
    return out.astype(table.dtype)
