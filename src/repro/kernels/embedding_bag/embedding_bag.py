"""Pallas TPU kernel: EmbeddingBag (ragged gather + segment-reduce).

JAX has no native EmbeddingBag; the recsys hot path (huge sparse tables
→ per-bag sum/mean) is built here as a first-class op.  TPU adaptation:
dynamic row gathers are expressed with a *scalar-prefetch* grid spec —
the bag indices are prefetched into SMEM and drive the table BlockSpec's
index_map, so each grid step DMAs exactly the (1, E) table row it needs
from HBM into VMEM (the TPU-idiomatic sparse gather; there is no
warp-level shuffle to port).  The output block is revisited across the
L steps of a bag and accumulated in place.

Grid: (n_bags, bag_len).  Padding slots use index 0 with weight 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, tpu_compiler_params

__all__ = ["embedding_bag_pallas"]


def _kernel(idx_ref, table_ref, w_ref, o_ref, *, bag_len, mode):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = table_ref[...].astype(jnp.float32)          # (1, E)
    w = w_ref[...].astype(jnp.float32)                # (1, 1)
    o_ref[...] += (row * w).astype(o_ref.dtype)

    if mode == "mean":
        count = jnp.maximum(idx_ref[b, bag_len], 1).astype(jnp.float32)

        @pl.when(l == bag_len - 1)
        def _norm():
            o_ref[...] = (o_ref[...].astype(jnp.float32) / count).astype(o_ref.dtype)


def embedding_bag_pallas(
    table: jnp.ndarray,      # (V, E) float
    indices: jnp.ndarray,    # (B, L) int32, -1 padding
    weights: jnp.ndarray | None = None,   # (B, L) float32 per-sample weights
    *,
    mode: str = "sum",       # sum | mean
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (B, E) pooled embeddings."""
    interpret = INTERPRET if interpret is None else interpret
    assert mode in ("sum", "mean")
    b, l = indices.shape
    v, e = table.shape

    valid = indices >= 0
    safe_idx = jnp.where(valid, indices, 0).astype(jnp.int32)
    if weights is None:
        w = valid.astype(jnp.float32)
    else:
        w = jnp.where(valid, weights, 0.0).astype(jnp.float32)
    # Scalar-prefetch operand: per-bag indices plus a trailing column with
    # the bag's valid count (used by mean normalization).
    counts = jnp.sum(valid, axis=1, dtype=jnp.int32)
    idx_sp = jnp.concatenate([safe_idx, counts[:, None]], axis=1)

    kernel = functools.partial(_kernel, bag_len=l, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, l),
        in_specs=[
            pl.BlockSpec((1, e), lambda bi, li, idx_ref: (idx_ref[bi, li], 0)),
            pl.BlockSpec((1, 1), lambda bi, li, idx_ref: (bi, li)),
        ],
        out_specs=pl.BlockSpec((1, e), lambda bi, li, idx_ref: (bi, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, e), table.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
        name="embedding_bag",
    )(idx_sp, table, w)
