"""EmbeddingBag entry points.

``embedding_bag`` — pure-JAX path used inside the recsys models (XLA
fuses gather+reduce well, and it shards cleanly with shard_map row
sharding; see distributed/sharding_rules.py).
``embedding_bag_kernel`` — the Pallas TPU hot path, validated against
the same oracle.
"""
from __future__ import annotations

from functools import partial

import jax

from .embedding_bag import embedding_bag_pallas
from .ref import embedding_bag_ref

__all__ = ["embedding_bag", "embedding_bag_kernel"]

embedding_bag = jax.jit(embedding_bag_ref, static_argnames=("mode",))


@partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_kernel(table, indices, weights=None, mode: str = "sum", interpret=None):
    return embedding_bag_pallas(table, indices, weights, mode=mode, interpret=interpret)
