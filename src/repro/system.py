"""Top-level orchestrator: the full retrieval system of the paper.

Wires corpus → inverted index → query log → L1 ranker → state bins →
production plans → Q-learning, and exposes train/evaluate entry points
used by examples, tests and benchmarks.  This is the single-host (one
index shard) path; `repro.launch.serve` distributes it over the mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.environment import EnvConfig
from repro.core.match_plan import MatchPlan, plan_rollout, production_plans
from repro.core.match_rules import RuleSet, default_rule_library
from repro.core.qlearning import QConfig, init_q, linear_epsilon, train_batch
from repro.core.reward import r_agent
from repro.core.rollout import unified_rollout
from repro.core.state_bins import StateBins, fit_bins
from repro.policies import PolicyStore, StaticPlanPolicy, TabularQPolicy
from repro.data.querylog import CAT1, CAT2, QueryLog, QueryLogConfig, generate_querylog
from repro.index.builder import InvertedIndex, batch_query_occupancy, build_index
from repro.index.corpus import Corpus, CorpusConfig, generate_corpus
from repro.ranking.features import doc_features
from repro.ranking.l1_ranker import idf_for_terms, init_l1, score_all_docs, train_l1
from repro.ranking.metrics import batched_ncg

__all__ = ["SystemConfig", "RetrievalSystem"]


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    corpus: CorpusConfig = CorpusConfig()
    querylog: QueryLogConfig = QueryLogConfig()
    block_docs: int = 512
    max_candidates: int = 512
    n_top: int = 5                      # paper: n = 5
    p_bins: int = 1024                  # paper: 10K (scaled to corpus size)
    u_budget: int = 2048
    t_max: int = 8
    rule_du_scale: int = 1
    rule_dv_scale: int = 1
    l1_hidden: int = 32
    l1_steps: int = 300
    gamma: float = 1.0              # paper: 0 < γ ≤ 1 (undiscounted default)
    seed: int = 0
    # Index-scan strategy for every rollout this system runs (training,
    # baselines, evaluation) — a core/scan_backends.py registry name.
    backend: str = "xla"


class RetrievalSystem:
    # The static system has no live index: one immutable "epoch 0"
    # forever.  `repro.index.live.LiveRetrievalSystem` overrides both
    # with the real IndexEpochStore; serving layers probe these via
    # getattr so they work against either system.
    index_epoch_store = None

    @property
    def index_epoch(self) -> int:
        return 0

    def __init__(self, cfg: SystemConfig,
                 index: Optional[InvertedIndex] = None):
        self.cfg = cfg
        t0 = time.time()
        self.corpus: Corpus = generate_corpus(cfg.corpus)
        # ``index`` injects a pre-built index instead of building one —
        # the process cell hands each worker the parent's saved base
        # generation (np.memmap'd read-only), so N worker processes map
        # ONE physical copy of the postings and skip the build entirely.
        self.index: InvertedIndex = (
            index if index is not None
            else build_index(self.corpus, block_docs=cfg.block_docs))
        self.log: QueryLog = generate_querylog(self.corpus, self.index, cfg.querylog)
        self.ruleset: RuleSet = default_rule_library(cfg.rule_du_scale, cfg.rule_dv_scale)
        self.plans: Dict[str, MatchPlan] = production_plans(self.ruleset)
        self.env_cfg = EnvConfig(
            n_blocks=self.index.n_blocks,
            block_docs=cfg.block_docs,
            k_rules=self.ruleset.k,
            max_candidates=cfg.max_candidates,
            n_top=cfg.n_top,
            u_budget=cfg.u_budget,
        )

        # Device-side per-document side data (padded to block boundary).
        n_pad = self.index.padded_docs
        sr = np.zeros(n_pad, np.float32)
        sr[: self.index.n_docs] = self.index.static_rank
        dl = np.zeros((n_pad, self.index.doc_len.shape[1]), np.float32)
        dl[: self.index.n_docs] = np.log1p(self.index.doc_len) / np.log(256.0)
        self.static_rank = jnp.asarray(sr)
        self.doc_len = jnp.asarray(dl)
        self.idf_all = idf_for_terms(
            self.index.df[:, 2].astype(np.float64), self.index.n_docs, self.log.terms
        )  # body-field df

        self.l1_params = init_l1(jax.random.key(cfg.seed), hidden=cfg.l1_hidden)
        self.bins: Optional[StateBins] = None
        self.qcfg: Optional[QConfig] = None
        self.build_time = time.time() - t0

    # ---------------------------------------------------------------- batches
    def batch_inputs(self, query_ids: Sequence[int], epoch=None):
        """Occupancy + L1 scores + masks for a set of query ids.

        ``epoch`` exists for signature parity with the live system's
        epoch-pinned batches; the static index ignores it."""
        qids = np.asarray(query_ids)
        term_lists = [self.log.terms[q, : self.log.n_terms[q]] for q in qids]
        occ = jnp.asarray(batch_query_occupancy(self.index, term_lists))
        term_present = jnp.asarray(self.log.terms[qids] >= 0)
        idf = jnp.asarray(self.idf_all[qids])
        scores = jax.vmap(
            lambda o, i, t: score_all_docs(
                self.l1_params, o, i, t, self.static_rank, self.doc_len
            )
        )(occ, idf, term_present)
        return occ, scores, term_present

    def judged(self, query_ids: Sequence[int]):
        qids = np.asarray(query_ids)
        return (
            jnp.asarray(self.log.judged_ids[qids]),
            jnp.asarray(self.log.judged_gains[qids]),
        )

    # ------------------------------------------------------------------- L1
    def fit_l1(self, n_queries: int = 256, batch: int = 32):
        """Train the L1 ranker on judged (query, doc) pairs."""
        rng = np.random.default_rng(self.cfg.seed + 1)
        qids = rng.choice(self.log.n_queries, size=min(n_queries, self.log.n_queries), replace=False)
        feats_l, gains_l = [], []
        for i in range(0, len(qids), batch):
            chunk = qids[i : i + batch]
            occ, _, term_present = self.batch_inputs(chunk)
            idf = jnp.asarray(self.idf_all[chunk])
            feats = jax.vmap(
                lambda o, i_, t: doc_features(o, i_, t, self.static_rank, self.doc_len)
            )(occ, idf, term_present)
            jids = self.log.judged_ids[chunk]
            for row, q in enumerate(chunk):
                mask = jids[row] >= 0
                ids = np.clip(jids[row], 0, None)
                feats_l.append(np.asarray(feats[row])[ids][mask])
                gains_l.append(self.log.judged_gains[q][mask])
        feats = np.concatenate(feats_l)
        gains = np.concatenate(gains_l)
        weights = 1.0 + gains.astype(np.float32)  # emphasize relevant docs
        self.l1_params, losses = train_l1(
            self.l1_params, feats, gains, weights, steps=self.cfg.l1_steps, seed=self.cfg.seed
        )
        return losses

    # ------------------------------------------------------------- baselines
    def plan_for_category(self, cat: int) -> MatchPlan:
        return self.plans["CAT2" if cat == CAT2 else "CAT1"]

    def plan_policy(self, cat: int) -> StaticPlanPolicy:
        """The hand-tuned production plan as a first-class Policy."""
        return StaticPlanPolicy(self.plan_for_category(cat), self.env_cfg.n_actions)

    def shallow_plan(self, cat: int, length: int = 2) -> MatchPlan:
        """Truncated production plan served at ServiceLevel.SHALLOW —
        u bounded by the prefix's summed Δu quotas."""
        return self.plan_for_category(cat).prefix(length)

    def shallow_u_cap(self, cat: int, length: int = 2) -> int:
        """Worst-case u of ONE single-shard shallow-plan execution:
        summed Δu quotas plus one block's planes of quota overshoot per
        entry.  The honest per-query bound degraded serving promises."""
        from repro.index.builder import MAX_QUERY_TERMS
        from repro.index.corpus import N_FIELDS
        return self.shallow_plan(cat, length).u_cap(
            per_entry_overshoot=MAX_QUERY_TERMS * N_FIELDS)

    def fallback_policies(self, cats: Sequence[int] = (CAT1, CAT2),
                          length: int = 2) -> Dict[int, StaticPlanPolicy]:
        """Degraded-service fallbacks published alongside live snapshots
        (PolicyStore.publish(policies, fallbacks=...))."""
        return {cat: StaticPlanPolicy(self.shallow_plan(cat, length),
                                      self.env_cfg.n_actions)
                for cat in cats}

    def _run_plan_batch(self, plan: MatchPlan, occ, scores, term_present):
        """Batched static-plan execution via the unified rollout; returns
        (final_state, trajectory with (B, L) leaves)."""
        return plan_rollout(self.env_cfg, self.ruleset, plan,
                            occ, scores, term_present,
                            backend=self.cfg.backend)

    def run_baseline(self, query_ids: Sequence[int], cat: int):
        occ, scores, term_present = self.batch_inputs(query_ids)
        plan = self.plan_for_category(cat)
        final, traj = self._run_plan_batch(plan, occ, scores, term_present)
        return final, traj, (occ, scores, term_present)

    def production_step_rewards(self, traj) -> jnp.ndarray:
        """Per-step r_agent of the production plan (Eq. 4's subtrahend)."""
        u = jnp.maximum(traj["u"], 1).astype(jnp.float32)          # (B?, L) — scan stacks on axis 0
        # plan_rollout vmaps over queries: traj leaves are (B, L)
        v = traj["v"].astype(jnp.float32)
        m = jnp.clip(jnp.minimum(v, self.env_cfg.n_top), 1, self.env_cfg.n_top)
        return traj["topn_sum"] / (m * u)

    # ------------------------------------------------------------------ bins
    def fit_state_bins(self, n_queries: int = 256, batch: int = 64):
        """Harvest (u, v) from baseline runs; fit equal-mass bins."""
        rng = np.random.default_rng(self.cfg.seed + 2)
        us, vs = [], []
        for cat in (CAT1, CAT2):
            qids_all = np.where(self.log.category == cat)[0]
            qids = rng.choice(qids_all, size=min(n_queries, len(qids_all)), replace=False)
            for i in range(0, len(qids), batch):
                _, traj, _ = self.run_baseline(qids[i : i + batch], cat)
                us.append(np.asarray(traj["u"]).ravel())
                vs.append(np.asarray(traj["v"]).ravel())
        self.bins = fit_bins(np.concatenate(us), np.concatenate(vs), p=self.cfg.p_bins)
        self.qcfg = QConfig(
            p=self.bins.p, n_actions=self.env_cfg.n_actions, t_max=self.cfg.t_max,
            gamma=self.cfg.gamma,
        )
        return self.bins

    # -------------------------------------------------------------- training
    def sample_train_qids(self, cat: int, batch: int,
                          rng: np.random.Generator) -> np.ndarray:
        """One training batch of query ids for a category (with
        replacement — shared by the offline and online trainers)."""
        qids_all = np.where(self.log.category == cat)[0]
        return rng.choice(qids_all, size=min(batch, len(qids_all)),
                          replace=True)

    def policy_train_step(self, cat: int, q: jnp.ndarray, key, eps: float,
                          qids: Sequence[int]):
        """One ε-greedy Q-learning iteration on a batch of query ids:
        production-plan rollout for Eq. 4's reward baseline, then
        ``train_batch``.  Returns (q, metrics).  This is the unit an
        online trainer loop (src/repro/cluster/trainer.py) interleaves
        with snapshot publishes."""
        assert self.bins is not None, "fit_state_bins() first"
        occ, scores, term_present = self.batch_inputs(qids)
        plan = self.plan_for_category(cat)
        _, traj = self._run_plan_batch(plan, occ, scores, term_present)
        prod_r = self.production_step_rewards(traj)
        return train_batch(
            self.env_cfg, self.qcfg, self.ruleset, self.bins, q,
            occ, scores, term_present, prod_r, jnp.float32(eps), key,
            backend=self.cfg.backend,
        )

    def train_policy(
        self,
        cat: int,
        iters: int = 150,
        batch: int = 64,
        eps_start: float = 0.5,
        eps_end: float = 0.05,
        seed: int = 0,
        log_every: int = 0,
    ):
        """Tabular Q-learning for one query category (paper trains separate
        policies per category)."""
        assert self.bins is not None, "fit_state_bins() first"
        rng_np = np.random.default_rng(seed)
        q = init_q(self.qcfg)
        key = jax.random.key(seed)
        history = []
        for it in range(iters):
            qids = self.sample_train_qids(cat, batch, rng_np)
            eps = linear_epsilon(it, iters, eps_start, eps_end)
            key, sub = jax.random.split(key)
            q, metrics = self.policy_train_step(cat, q, sub, eps, qids)
            history.append({k: float(v) for k, v in metrics.items()})
            if log_every and (it % log_every == 0):
                print(f"[cat{cat}] iter {it:4d} eps {eps:.2f} " +
                      " ".join(f"{k}={v:.4f}" for k, v in history[-1].items()))
        return q, history

    # ------------------------------------------------------------ policies
    def train_policy_store(self, cats: Sequence[int] = (CAT1, CAT2),
                           store: Optional[PolicyStore] = None,
                           staleness_bound: int = 1,
                           **train_kwargs) -> PolicyStore:
        """Train per-category tabular policies and publish one snapshot.
        Pass an existing ``store`` to publish a fresh version into it
        (the serve-while-training loop)."""
        policies = {cat: TabularQPolicy(self.train_policy(cat, **train_kwargs)[0])
                    for cat in cats}
        if store is None:
            store = PolicyStore(staleness_bound=staleness_bound)
        store.publish(policies)
        return store

    def baseline_policies(self, cats: Sequence[int] = (CAT1, CAT2)):
        """The hand-tuned production plans as a {category: Policy} dict."""
        return {cat: self.plan_policy(cat) for cat in cats}

    # ------------------------------------------------------------ evaluation
    def evaluate(self, q: jnp.ndarray, query_ids: Sequence[int], cat: int):
        """Learned policy vs production plan on the same queries.
        Returns per-query arrays for NCG@100 and blocks accessed u."""
        occ, scores, term_present = self.batch_inputs(query_ids)
        judged_ids, judged_gains = self.judged(query_ids)

        plan = self.plan_for_category(cat)
        base_final, _ = self._run_plan_batch(plan, occ, scores, term_present)
        pol_res = unified_rollout(
            self.env_cfg, self.ruleset, self.bins, TabularQPolicy(q),
            self.qcfg.t_max, occ, scores, term_present,
            backend=self.cfg.backend,
        )
        pol_final, actions = pol_res.final_state, pol_res.transitions["a"]

        out = {}
        for name, fin in (("baseline", base_final), ("policy", pol_final)):
            out[f"{name}_ncg"] = np.asarray(batched_ncg(fin.cand, judged_ids, judged_gains))
            out[f"{name}_u"] = np.asarray(fin.u)
            out[f"{name}_cand"] = np.asarray(fin.cand_cnt)
        out["actions"] = np.asarray(actions)
        return out
