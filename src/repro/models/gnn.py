"""GraphSAGE (mean aggregator) — full-graph, sampled-minibatch, and
batched-small-graph execution.

Message passing is built on ``jax.ops.segment_sum`` over an edge index
(JAX sparse is BCOO-only — the scatter formulation IS the system here):
    agg[dst] = Σ_{(src,dst)∈E} h[src] / deg[dst]
    h'       = ReLU(h · W_self + agg · W_neigh + b)

Distribution: edges sharded over the data axes, node states replicated
per device (ogb_products: 2.45M × 128 fp32 ≈ 1.25 GB); each shard
scatters its partial aggregate and a psum combines — GSPMD emits that
automatically from the sharding constraints set in launch/dryrun.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init

__all__ = ["SAGEConfig", "sage_init", "sage_full_forward", "sage_block_forward",
           "sage_graph_forward", "sample_blocks", "Block"]


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    d_in: int
    d_hidden: int
    n_classes: int
    n_layers: int = 2
    aggregator: str = "mean"
    normalize: bool = True        # L2-normalize layer outputs (paper §3.1)


def sage_init(rng, cfg: SAGEConfig, dtype=jnp.float32) -> Dict:
    params = {}
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(rng, cfg.n_layers * 2)
    for l in range(cfg.n_layers):
        params[f"layer_{l}"] = {
            "w_self": dense_init(keys[2 * l], (dims[l], dims[l + 1]), dtype=dtype),
            "w_neigh": dense_init(keys[2 * l + 1], (dims[l], dims[l + 1]), dtype=dtype),
            "b": jnp.zeros((dims[l + 1],), dtype),
        }
    return params


def _aggregate(h: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, n_dst: int,
               aggregator: str) -> jnp.ndarray:
    """Padding convention: src == h.shape[0] is a zero dummy row; dst ==
    n_dst is a dummy segment — both let edge arrays pad to fixed/shardable
    lengths without distorting the mean."""
    hd = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)], axis=0)
    msgs = jnp.take(hd, src, axis=0)
    if aggregator == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_dst + 1)[:n_dst]
        ones = jnp.where(dst < n_dst, 1.0, 0.0)
        deg = jax.ops.segment_sum(ones, dst, num_segments=n_dst + 1)[:n_dst]
        return s / jnp.maximum(deg, 1.0)[:, None]
    if aggregator == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=n_dst + 1)[:n_dst]
    raise ValueError(aggregator)


def _layer(lp: Dict, h_self: jnp.ndarray, agg: jnp.ndarray, last: bool,
           normalize: bool) -> jnp.ndarray:
    out = h_self @ lp["w_self"] + agg @ lp["w_neigh"] + lp["b"]
    if not last:
        out = jax.nn.relu(out)
        if normalize:
            out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)
    return out


def sage_full_forward(params: Dict, cfg: SAGEConfig, feats: jnp.ndarray,
                      edges: jnp.ndarray) -> jnp.ndarray:
    """Full-batch: feats (N, d_in), edges (2, E) src→dst. Returns logits (N, C)."""
    h = feats
    n = feats.shape[0]
    for l in range(cfg.n_layers):
        agg = _aggregate(h, edges[0], edges[1], n, cfg.aggregator)
        h = _layer(params[f"layer_{l}"], h, agg, last=(l == cfg.n_layers - 1),
                   normalize=cfg.normalize)
    return h


# -------------------------------------------------------- sampled minibatch
@dataclasses.dataclass
class Block:
    """One bipartite sampled layer: frontier srcs → the first n_dst
    nodes of the frontier (standard DGL-style layout)."""
    src: np.ndarray   # (E,) indices into the current frontier
    dst: np.ndarray   # (E,) in [0, n_dst)
    n_dst: int


def sample_blocks(indptr: np.ndarray, nbrs: np.ndarray, seeds: np.ndarray,
                  fanouts: Sequence[int], rng: np.random.Generator
                  ) -> Tuple[np.ndarray, List[Block]]:
    """Real neighbor sampler (host-side, CSR graph).

    Returns (input_node_ids, blocks outer→inner ... ordered for forward:
    blocks[l] consumed by layer l).  Frontier layout: frontier of layer l
    = [dst nodes (=next frontier)] ++ [sampled neighbors].
    """
    blocks: List[Block] = []
    frontier = np.asarray(seeds, np.int64)
    for fanout in reversed(fanouts):
        srcs, dsts = [], []
        extra: List[int] = []
        seen = {int(n): i for i, n in enumerate(frontier)}
        for di, node in enumerate(frontier):
            lo, hi = indptr[node], indptr[node + 1]
            if hi == lo:
                continue
            cand = nbrs[lo:hi]
            pick = cand if len(cand) <= fanout else rng.choice(cand, fanout, replace=False)
            for p in pick:
                p = int(p)
                if p not in seen:
                    seen[p] = len(frontier) + len(extra)
                    extra.append(p)
                srcs.append(seen[p])
                dsts.append(di)
        blocks.append(Block(np.array(srcs, np.int32), np.array(dsts, np.int32),
                            n_dst=len(frontier)))
        frontier = np.concatenate([frontier, np.array(extra, np.int64)]) if extra else frontier
    blocks.reverse()  # now blocks[0] is the innermost (first layer applied)
    return frontier, blocks


def sage_block_forward(params: Dict, cfg: SAGEConfig, feats_frontier: jnp.ndarray,
                       blocks_arrays) -> jnp.ndarray:
    """Minibatch forward. feats_frontier: features of the full sampled
    frontier (layer-0 input); blocks_arrays: list (outer→inner reversed by
    sampler) of (src, dst, n_dst) triples, innermost first."""
    h = feats_frontier
    for l in range(cfg.n_layers):
        src, dst, n_dst = blocks_arrays[l]
        agg = _aggregate(h, src, dst, n_dst, cfg.aggregator)
        h_self = h[:n_dst]
        h = _layer(params[f"layer_{l}"], h_self, agg, last=(l == cfg.n_layers - 1),
                   normalize=cfg.normalize)
    return h


# ------------------------------------------------------ batched small graphs
def sage_graph_forward(params: Dict, cfg: SAGEConfig, feats: jnp.ndarray,
                       edges: jnp.ndarray, graph_id: jnp.ndarray,
                       n_graphs: int, readout: Dict) -> jnp.ndarray:
    """Molecule-style: many small graphs block-diagonally batched.
    Node logits → segment-mean per graph → linear readout."""
    h = sage_full_forward(params, cfg, feats, edges)
    pooled = jax.ops.segment_sum(h, graph_id, num_segments=n_graphs)
    counts = jax.ops.segment_sum(jnp.ones_like(graph_id, jnp.float32), graph_id,
                                 num_segments=n_graphs)
    pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
    return pooled @ readout["w"] + readout["b"]
