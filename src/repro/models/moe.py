"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Routing: softmax top-k (grok-1: 8e top-2; DeepSeek-V2-Lite: 64e top-6
+ 2 shared experts).  Dispatch builds an (E, C, d) buffer with a sort-
free rank-within-expert computation (cumulative count per expert) —
O(T·k·E) bitwork + O(T·k·d) gathers, never the GShard O(T²) dispatch
einsum.

Expert parallelism: activations in a Megatron-TP transformer are
replicated across the `model` axis between blocks, so each model shard
dispatches its local tokens to its LOCAL experts only and a single psum
over `model` combines expert outputs — EP without all-to-all
(DESIGN.md §7).  `moe_ffn` is the per-shard math; `moe_ffn_sharded`
wraps it in shard_map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_apply, mlp_init

__all__ = ["MoEConfig", "moe_init", "moe_ffn", "moe_ffn_sharded", "router_topk", "build_dispatch"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                   # per-expert hidden
    n_shared: int = 0           # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    mlp_kind: str = "swiglu"


def moe_init(rng, cfg: MoEConfig, dtype=jnp.float32) -> Dict:
    k_r, k_e, k_s = jax.random.split(rng, 3)
    e = cfg.n_experts
    ke = jax.random.split(k_e, 3)
    params = {
        "router": dense_init(k_r, (cfg.d_model, e), dtype=jnp.float32),
        "experts": {
            "w_gate": dense_init(ke[0], (e, cfg.d_model, cfg.d_ff), dtype=dtype),
            "w_up": dense_init(ke[1], (e, cfg.d_model, cfg.d_ff), dtype=dtype),
            "w_down": dense_init(ke[2], (e, cfg.d_ff, cfg.d_model), dtype=dtype),
        },
    }
    if cfg.n_shared:
        params["shared"] = mlp_init(
            k_s, cfg.d_model, cfg.d_ff * cfg.n_shared, cfg.mlp_kind, dtype=dtype
        )
    return params


def router_topk(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (T, d) -> (weights (T, k) f32, experts (T, k) i32, aux_loss ())."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    w, idx = jax.lax.top_k(gates, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style): E * Σ_e f_e · p_e
    e = router_w.shape[1]
    me = gates.mean(0)
    f = jnp.zeros((e,)).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = e * jnp.sum(f * me)
    return w, idx, aux


def build_dispatch(idx: jnp.ndarray, n_experts: int, capacity: int):
    """Rank each (token, slot) assignment within its expert.

    Returns (positions (T, k) int32 — rank within expert, clipped
    assignments marked by keep mask, counts (E,)).
    Rank computed with a cumulative one-hot sum — deterministic,
    sort-free, O(T·k·E) int adds (E is small relative to T).
    """
    t, k = idx.shape
    flat = idx.reshape(-1)                                       # (T*k,)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)    # (T*k, E)
    ranks_all = jnp.cumsum(onehot, axis=0) - onehot              # rank before self
    pos = jnp.take_along_axis(ranks_all, flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    counts = onehot.sum(0)
    return pos.reshape(t, k), keep.reshape(t, k), counts


def moe_ffn(params: Dict, x: jnp.ndarray, cfg: MoEConfig,
            capacity: Optional[int] = None):
    """Per-shard MoE FFN. x: (T, d). Returns (out (T, d), aux_loss)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity or max(8, int(cfg.capacity_factor * t * k / e))

    w, idx, aux = router_topk(params["router"], x, k)
    pos, keep, _ = build_dispatch(idx, e, cap)

    # Scatter tokens into the (E, C, d) buffer.
    buf = jnp.zeros((e, cap, d), x.dtype)
    flat_idx = idx.reshape(-1)
    flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)  # cap = drop
    tok = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[flat_idx, flat_pos].set(x[tok], mode="drop")

    # Expert GEMMs (E, C, d) -> (E, C, d).
    ex = params["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, ex["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, ex["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, ex["w_down"])

    # Gather back with gate weights.
    out_flat = y[flat_idx, jnp.clip(flat_pos, 0, cap - 1)]        # (T*k, d)
    wflat = (w.reshape(-1) * keep.reshape(-1)).astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok].add(out_flat * wflat[:, None])

    if cfg.n_shared:
        out = out + mlp_apply(params["shared"], x, cfg.mlp_kind)
    return out, aux


def moe_ffn_sharded(params: Dict, x: jnp.ndarray, cfg: MoEConfig, mesh,
                    model_axis: str = "model", data_axes=("data",),
                    fsdp: bool = False):
    """shard_map MoE: tokens sharded over the data axes and replicated
    along `model` (they are, between Megatron blocks); one psum over
    `model` combines expert outputs — no all-to-all (DESIGN.md §7).

    Two regimes on the `model` axis:
      EP  (E % M == 0): each shard owns E/M whole experts.
      TP  (M % E == 0, e.g. grok-1's 8e on a 16-way axis): every shard
          owns a 1/(M) slice of every expert's d_ff; the same psum that
          combines experts also combines the ff partial sums.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[model_axis]
    fsdp = fsdp and "data" in mesh.shape
    if cfg.n_experts % n_shards != 0:
        assert cfg.d_ff % n_shards == 0, "need E%M==0 or d_ff%M==0"
        return _moe_ffn_sharded_tp(params, x, cfg, mesh, model_axis, data_axes, fsdp)
    e_local = cfg.n_experts // n_shards

    def local_fn(p_local, x_local):
        if fsdp:
            # ZeRO-3 for the expert bulk: gather the `data`-sharded slice
            # HERE, inside the remat region, so backward RE-GATHERS
            # instead of stashing 64 layers of gathered weights
            # (grok-1: 37.7 GiB/device saved; EXPERIMENTS.md §Perf).
            ex = p_local["experts"]
            p_local = dict(p_local)
            p_local["experts"] = {
                "w_gate": jax.lax.all_gather(ex["w_gate"], "data", axis=1, tiled=True),
                "w_up": jax.lax.all_gather(ex["w_up"], "data", axis=1, tiled=True),
                "w_down": jax.lax.all_gather(ex["w_down"], "data", axis=2, tiled=True),
            }
        # Global top-k routing (router replicated), then keep only the
        # assignments that land on this shard's experts.
        w, idx, aux = router_topk(p_local["router"], x_local, cfg.top_k)
        shard = jax.lax.axis_index(model_axis)
        lo = shard * e_local
        local = (idx >= lo) & (idx < lo + e_local)
        idx_l = jnp.where(local, idx - lo, e_local)               # e_local = drop bucket
        t = x_local.shape[0]
        cap = max(8, int(cfg.capacity_factor * t * cfg.top_k / cfg.n_experts))
        pos, keep, _ = build_dispatch(idx_l, e_local + 1, cap)
        keep = keep & local

        buf = jnp.zeros((e_local + 1, cap, x_local.shape[1]), x_local.dtype)
        flat_idx = idx_l.reshape(-1)
        flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)
        tok = jnp.repeat(jnp.arange(t), cfg.top_k)
        buf = buf.at[flat_idx, flat_pos].set(x_local[tok], mode="drop")
        buf = buf[:e_local]

        ex = p_local["experts"]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, ex["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, ex["w_up"])
        y = jnp.einsum("ecf,efd->ecd", h, ex["w_down"])

        safe_idx = jnp.minimum(flat_idx, e_local - 1)
        out_flat = y[safe_idx, jnp.clip(flat_pos, 0, cap - 1)]
        wflat = (w.reshape(-1) * keep.reshape(-1)).astype(x_local.dtype)
        out = jnp.zeros_like(x_local).at[tok].add(out_flat * wflat[:, None])
        out = jax.lax.psum(out, model_axis)
        if cfg.n_shared:
            out = out + mlp_apply(p_local["shared"], x_local, cfg.mlp_kind)
        return out, jax.lax.pmean(aux, model_axis)

    pspec_params = jax.tree_util.tree_map(lambda _: P(), params)
    if fsdp:
        pspec_params["experts"] = {
            "w_gate": P(model_axis, "data", None),
            "w_up": P(model_axis, "data", None),
            "w_down": P(model_axis, None, "data"),
        }
    else:
        pspec_params["experts"] = jax.tree_util.tree_map(
            lambda _: P(model_axis), params["experts"]
        )
    xspec = P(data_axes) if data_axes else P()
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec_params, xspec),
        out_specs=(xspec, P()),
        check_rep=False,
    )(params, x)


def _moe_ffn_sharded_tp(params: Dict, x: jnp.ndarray, cfg: MoEConfig, mesh,
                        model_axis: str, data_axes, fsdp: bool = False):
    """TP regime: every shard holds (E, d, d_ff/M) slices; capacity
    dispatch is identical on all shards, the psum combines ff partials."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def local_fn(p_local, x_local):
        if fsdp:
            ex = p_local["experts"]
            p_local = dict(p_local)
            p_local["experts"] = {
                "w_gate": jax.lax.all_gather(ex["w_gate"], "data", axis=1, tiled=True),
                "w_up": jax.lax.all_gather(ex["w_up"], "data", axis=1, tiled=True),
                "w_down": jax.lax.all_gather(ex["w_down"], "data", axis=2, tiled=True),
            }
        w, idx, aux = router_topk(p_local["router"], x_local, cfg.top_k)
        t = x_local.shape[0]
        e = cfg.n_experts
        cap = max(8, int(cfg.capacity_factor * t * cfg.top_k / e))
        pos, keep, _ = build_dispatch(idx, e, cap)

        buf = jnp.zeros((e, cap, x_local.shape[1]), x_local.dtype)
        flat_idx = idx.reshape(-1)
        flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)
        tok = jnp.repeat(jnp.arange(t), cfg.top_k)
        buf = buf.at[flat_idx, flat_pos].set(x_local[tok], mode="drop")

        ex = p_local["experts"]                       # ff-sliced locally
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, ex["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, ex["w_up"])
        y = jnp.einsum("ecf,efd->ecd", h, ex["w_down"])   # partial over ff

        out_flat = y[flat_idx, jnp.clip(flat_pos, 0, cap - 1)]
        wflat = (w.reshape(-1) * keep.reshape(-1)).astype(x_local.dtype)
        out = jnp.zeros_like(x_local).at[tok].add(out_flat * wflat[:, None])
        out = jax.lax.psum(out, model_axis)
        if cfg.n_shared:
            out = out + mlp_apply(p_local["shared"], x_local, cfg.mlp_kind)
        return out, jax.lax.pmean(aux, model_axis)

    pspec_params = jax.tree_util.tree_map(lambda _: P(), params)
    d_ax = "data" if fsdp else None
    pspec_params["experts"] = {
        "w_gate": P(None, d_ax, model_axis),
        "w_up": P(None, d_ax, model_axis),
        "w_down": P(None, model_axis, d_ax),
    }
    xspec = P(data_axes) if data_axes else P()
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec_params, xspec),
        out_specs=(xspec, P()),
        check_rep=False,
    )(params, x)
