"""RecSys ranking models: Wide&Deep, DeepFM, DCN-v2, BERT4Rec.

All sparse features go through one unified embedding surface: a single
(n_fields · vocab_per_field, dim) table indexed with per-field offsets
(quotient layout), looked up via the EmbeddingBag op (kernels/
embedding_bag) — JAX has no native EmbeddingBag, so this IS part of the
system.  Tables row-shard over `model`; batch shards over the data
axes (distributed/sharding_rules.py).

`retrieval_cand` (1 query × 1M candidates) is a batched-dot scoring
pass: CTR models score candidate feature rows in one forward; BERT4Rec
encodes the history once and dots with the (sharded) item table +
per-shard top-k merge.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ops import embedding_bag
from .layers import dense_init, layer_norm

__all__ = ["RecsysConfig", "B4RConfig", "wide_deep_init", "wide_deep_forward",
           "deepfm_init", "deepfm_forward", "dcn_init", "dcn_forward",
           "bert4rec_init", "bert4rec_forward", "bert4rec_score_items",
           "bce_loss", "retrieval_topk"]


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    n_sparse: int                 # number of categorical fields
    vocab_per_field: int
    embed_dim: int
    mlp_dims: Tuple[int, ...]
    n_dense: int = 0              # continuous features (dcn-v2: 13)
    n_cross_layers: int = 0       # dcn-v2
    interaction: str = "concat"   # concat | fm | cross | bidir-seq
    param_dtype: object = jnp.float32
    batch_over_model: bool = False  # reduce-scatter lookup + model-sharded tower

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field


def _field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    return (jnp.arange(cfg.n_sparse) * cfg.vocab_per_field).astype(jnp.int32)


def _lookup(table: jnp.ndarray, sparse_ids: jnp.ndarray, cfg: RecsysConfig,
            mesh=None) -> jnp.ndarray:
    """sparse_ids (B, n_sparse) per-field local ids → (B, n_sparse, dim).
    With a mesh, uses the shard_map row-sharded lookup (no table gather)."""
    idx = sparse_ids + _field_offsets(cfg)[None, :]
    if mesh is not None:
        from repro.distributed.embedding_ops import sharded_lookup, sharded_lookup_rs
        from repro.distributed.sharding_rules import data_axes
        if getattr(cfg, "batch_over_model", False):
            return sharded_lookup_rs(table, idx, mesh, data_axes=data_axes(mesh))
        return sharded_lookup(table, idx, mesh, data_axes=data_axes(mesh))
    return jnp.take(table, idx, axis=0)


def _bag_sum(table: jnp.ndarray, idx: jnp.ndarray, mesh=None) -> jnp.ndarray:
    if mesh is not None:
        from repro.distributed.embedding_ops import sharded_bag_sum
        from repro.distributed.sharding_rules import data_axes
        return sharded_bag_sum(table, idx, mesh, data_axes=data_axes(mesh))
    return embedding_bag(table, idx, mode="sum")


def _mlp_init(rng, dims, dtype):
    params = {}
    keys = jax.random.split(rng, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = dense_init(keys[i], (a, b), dtype=dtype)
        params[f"b{i}"] = jnp.zeros((b,), dtype)
    return params


def _mlp_apply(params, x, n, final_relu=False):
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_relu:
            x = jax.nn.relu(x)
    return x


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# ------------------------------------------------------------- Wide & Deep
def wide_deep_init(rng, cfg: RecsysConfig) -> Dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    dt = cfg.param_dtype
    mlp_dims = (cfg.n_sparse * cfg.embed_dim + cfg.n_dense,) + cfg.mlp_dims + (1,)
    return {
        "wide": dense_init(k1, (cfg.total_vocab, 1), scale=0.01, dtype=dt),
        "embed": dense_init(k2, (cfg.total_vocab, cfg.embed_dim), scale=0.02, dtype=dt),
        "mlp": _mlp_init(k3, mlp_dims, dt),
        "wide_dense": dense_init(k4, (max(cfg.n_dense, 1), 1), scale=0.01, dtype=dt),
        "bias": jnp.zeros((), dt),
    }


def wide_deep_forward(params: Dict, sparse_ids: jnp.ndarray, cfg: RecsysConfig,
                      dense: Optional[jnp.ndarray] = None, mesh=None) -> jnp.ndarray:
    idx = sparse_ids + _field_offsets(cfg)[None, :]
    wide = _bag_sum(params["wide"], idx, mesh)[:, 0]                    # (B,)
    emb = _lookup(params["embed"], sparse_ids, cfg, mesh)               # (B, F, E)
    deep_in = emb.reshape(emb.shape[0], -1)
    if cfg.n_dense:
        deep_in = jnp.concatenate([dense, deep_in], axis=1)
        wide = wide + (dense @ params["wide_dense"])[:, 0]
    deep = _mlp_apply(params["mlp"], deep_in, len(cfg.mlp_dims) + 1)[:, 0]
    return wide + deep + params["bias"]


# ------------------------------------------------------------------ DeepFM
def deepfm_init(rng, cfg: RecsysConfig) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    dt = cfg.param_dtype
    mlp_dims = (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims + (1,)
    return {
        "first_order": dense_init(k1, (cfg.total_vocab, 1), scale=0.01, dtype=dt),
        "embed": dense_init(k2, (cfg.total_vocab, cfg.embed_dim), scale=0.02, dtype=dt),
        "mlp": _mlp_init(k3, mlp_dims, dt),
        "bias": jnp.zeros((), dt),
    }


def deepfm_forward(params: Dict, sparse_ids: jnp.ndarray, cfg: RecsysConfig,
                   dense: Optional[jnp.ndarray] = None, mesh=None) -> jnp.ndarray:
    idx = sparse_ids + _field_offsets(cfg)[None, :]
    first = _bag_sum(params["first_order"], idx, mesh)[:, 0]
    emb = _lookup(params["embed"], sparse_ids, cfg, mesh)               # (B, F, E)
    # FM second order: ½((Σv)² − Σv²) summed over dims
    s = emb.sum(1)
    fm = 0.5 * (s * s - (emb * emb).sum(1)).sum(-1)
    deep = _mlp_apply(params["mlp"], emb.reshape(emb.shape[0], -1),
                      len(cfg.mlp_dims) + 1)[:, 0]
    return first + fm + deep + params["bias"]


# ------------------------------------------------------------------ DCN-v2
def dcn_init(rng, cfg: RecsysConfig) -> Dict:
    ks = jax.random.split(rng, 4 + cfg.n_cross_layers)
    dt = cfg.param_dtype
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = {}
    for l in range(cfg.n_cross_layers):
        cross[f"w{l}"] = dense_init(ks[l], (d0, d0), scale=0.02, dtype=dt)
        cross[f"b{l}"] = jnp.zeros((d0,), dt)
    mlp_dims = (d0,) + cfg.mlp_dims
    return {
        "embed": dense_init(ks[-3], (cfg.total_vocab, cfg.embed_dim), scale=0.02, dtype=dt),
        "cross": cross,
        "mlp": _mlp_init(ks[-2], mlp_dims, dt),
        "head": dense_init(ks[-1], (d0 + cfg.mlp_dims[-1], 1), dtype=dt),
    }


def dcn_forward(params: Dict, sparse_ids: jnp.ndarray, cfg: RecsysConfig,
                dense: jnp.ndarray, mesh=None) -> jnp.ndarray:
    emb = _lookup(params["embed"], sparse_ids, cfg, mesh).reshape(sparse_ids.shape[0], -1)
    x0 = jnp.concatenate([dense, emb], axis=1)                          # (B, d0)
    x = x0
    for l in range(cfg.n_cross_layers):
        x = x0 * (x @ params["cross"][f"w{l}"] + params["cross"][f"b{l}"]) + x
    deep = _mlp_apply(params["mlp"], x0, len(cfg.mlp_dims), final_relu=True)
    out = jnp.concatenate([x, deep], axis=1) @ params["head"]
    return out[:, 0]


# ---------------------------------------------------------------- BERT4Rec
@dataclasses.dataclass(frozen=True)
class B4RConfig:
    n_items: int
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    param_dtype: object = jnp.float32


def bert4rec_init(rng, cfg: "B4RConfig") -> Dict:
    ks = jax.random.split(rng, 2 + cfg.n_blocks)
    dt = cfg.param_dtype
    e = cfg.embed_dim
    blocks = {}
    for b in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + b], 5)
        blocks[f"block_{b}"] = {
            "wq": dense_init(kb[0], (e, e), dtype=dt),
            "wk": dense_init(kb[1], (e, e), dtype=dt),
            "wv": dense_init(kb[2], (e, e), dtype=dt),
            "wo": dense_init(kb[3], (e, e), dtype=dt),
            "mlp": _mlp_init(kb[4], (e, 4 * e, e), dt),
            "ln1_w": jnp.ones((e,), dt), "ln1_b": jnp.zeros((e,), dt),
            "ln2_w": jnp.ones((e,), dt), "ln2_b": jnp.zeros((e,), dt),
        }
    # +2 for [PAD]=n_items, [MASK]=n_items+1; rows padded to a multiple of
    # 256 so the table row-shards on any mesh
    n_rows = ((cfg.n_items + 2 + 255) // 256) * 256
    return {
        "item_embed": dense_init(ks[0], (n_rows, e), scale=0.02, dtype=dt),
        "pos_embed": dense_init(ks[1], (cfg.seq_len, e), scale=0.02, dtype=dt),
        "blocks": blocks,
        "ln_f_w": jnp.ones((e,), dt), "ln_f_b": jnp.zeros((e,), dt),
    }


def bert4rec_forward(params: Dict, item_seq: jnp.ndarray, cfg: "B4RConfig",
                     mesh=None) -> jnp.ndarray:
    """Bidirectional encoder. item_seq (B, S) int32 → hidden (B, S, E)."""
    b, s = item_seq.shape
    e, h = cfg.embed_dim, cfg.n_heads
    dh = e // h
    if mesh is not None:
        import numpy as _np
        from repro.distributed.embedding_ops import sharded_lookup
        from repro.distributed.sharding_rules import data_axes
        da = data_axes(mesh)
        dp_size = int(_np.prod([mesh.shape[a] for a in da])) if da else 1
        if b % dp_size != 0 or b < dp_size:
            da = ()          # B=1 retrieval: replicate rows, keep table sharded
        emb = sharded_lookup(params["item_embed"], item_seq, mesh, data_axes=da)
    else:
        emb = jnp.take(params["item_embed"], item_seq, axis=0)
    x = emb + params["pos_embed"][None, :s]
    pad_mask = item_seq != cfg.n_items                                  # PAD id

    for bi in range(cfg.n_blocks):
        bp = params["blocks"][f"block_{bi}"]
        hx = layer_norm(x, bp["ln1_w"], bp["ln1_b"])
        q = (hx @ bp["wq"]).reshape(b, s, h, dh)
        k = (hx @ bp["wk"]).reshape(b, s, h, dh)
        v = (hx @ bp["wv"]).reshape(b, s, h, dh)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * dh ** -0.5
        sc = jnp.where(pad_mask[:, None, None, :], sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
        x = x + o.reshape(b, s, e) @ bp["wo"]
        hx = layer_norm(x, bp["ln2_w"], bp["ln2_b"])
        x = x + _mlp_apply(bp["mlp"], hx, 2)
    return layer_norm(x, params["ln_f_w"], params["ln_f_b"])


def bert4rec_score_items(params: Dict, hidden_at_mask: jnp.ndarray,
                         cfg: "B4RConfig") -> jnp.ndarray:
    """Tied-weight output: (B, E) → (B, n_items) scores."""
    return hidden_at_mask @ params["item_embed"][: cfg.n_items].T


# -------------------------------------------------------------- retrieval
def retrieval_topk(query_vec: jnp.ndarray, cand_emb: jnp.ndarray, k: int = 100):
    """Score 1×N candidates with a batched dot and take top-k.  With
    cand_emb sharded over `model`, GSPMD computes per-shard partial
    scores; top-k over the gathered score vector."""
    scores = (cand_emb @ query_vec[:, None])[:, 0]                      # (N,)
    return jax.lax.top_k(scores, k)
