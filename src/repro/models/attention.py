"""Attention modules: GQA (dense LMs) and MLA (DeepSeek-V2-Lite).

Two execution paths per module:
- XLA path (default): chunked causal attention (lax.scan over query
  chunks) so the materialized score tile stays O(chunk × S) — this is
  what the multi-pod dry-run lowers, and what GSPMD partitions (heads
  over `model`, batch over `data`(×`pod`), KV sequence over `model` for
  long-context decode with the LSE merge happening inside the softmax
  reduction XLA emits).
- Pallas path (TPU): kernels/flash_attention + kernels/decode_attention.

Decode keeps a (layers-stacked) KV cache pytree and supports GQA and
MLA's compressed-KV cache with the absorbed-matmul formulation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import apply_rope, dense_init, rope_angles

__all__ = ["AttnConfig", "gqa_init", "gqa_forward", "gqa_decode", "MLAConfig",
           "mla_init", "mla_forward", "mla_decode", "chunked_causal_attention"]


# --------------------------------------------------------------------- GQA
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    rope_theta: float = 10000.0
    q_chunk: int = 512           # XLA-path query chunk
    use_flash: bool = False      # Pallas kernel path


def gqa_init(rng, cfg: AttnConfig, dtype=jnp.float32) -> Dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    return {
        "wq": dense_init(k1, (d, h * dh), dtype=dtype),
        "wk": dense_init(k2, (d, kv * dh), dtype=dtype),
        "wv": dense_init(k3, (d, kv * dh), dtype=dtype),
        "wo": dense_init(k4, (h * dh, d), scale=(h * dh) ** -0.5, dtype=dtype),
    }


def chunked_causal_attention(q, k, v, q_chunk: int, causal_offset: int = 0):
    """q: (B, S, H, D); k, v: (B, Skv, Hkv, D). Scan over q chunks keeps the
    score tile at (B, H, q_chunk, Skv) — the XLA analogue of flash tiling."""
    b, s, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = d ** -0.5
    nchunks = max(s // q_chunk, 1)
    assert s % nchunks == 0
    qc = q.reshape(b, nchunks, s // nchunks, h, d)

    kg = k.astype(jnp.float32)
    vg = v.astype(jnp.float32)

    def chunk(ci):
        qi = qc[:, ci].astype(jnp.float32)                       # (B, cq, H, D)
        qi4 = qi.reshape(b, -1, hkv, group, d)
        sc = jnp.einsum("bqkgd,bskd->bkgqs", qi4, kg) * scale    # (B,hkv,g,cq,S)
        q_pos = ci * (s // nchunks) + jnp.arange(s // nchunks) + causal_offset
        mask = q_pos[:, None] >= jnp.arange(skv)[None, :]
        sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, vg)
        return o.reshape(b, -1, h, dv)

    out = lax.map(chunk, jnp.arange(nchunks))                    # (nc, B, cq, H, Dv)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, dv)


def gqa_forward(params: Dict, x: jnp.ndarray, cfg: AttnConfig,
                positions: Optional[jnp.ndarray] = None,
                return_cache: bool = False):
    """Training / prefill. x: (B, S, d_model)."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kv, dh)
    v = (x @ params["wv"]).reshape(b, s, kv, dh)

    pos = jnp.arange(s)[None] if positions is None else positions
    cos, sin = rope_angles(pos, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cfg.use_flash:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True,
        ).transpose(0, 2, 1, 3)
    else:
        o = chunked_causal_attention(q, k, v, cfg.q_chunk)

    out = o.astype(x.dtype).reshape(b, s, h * dh) @ params["wo"]
    if return_cache:
        return out, {"k": k, "v": v}
    return out


def gqa_decode(params: Dict, x_tok: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               cfg: AttnConfig) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x_tok: (B, d_model); cache k/v: (B, S, Hkv, D);
    pos: (B,) current position (number of tokens already cached)."""
    b, d = x_tok.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    s_max = cache["k"].shape[1]

    q = (x_tok @ params["wq"]).reshape(b, 1, h, dh)
    k_new = (x_tok @ params["wk"]).reshape(b, 1, kv, dh)
    v_new = (x_tok @ params["wv"]).reshape(b, 1, kv, dh)

    cos, sin = rope_angles(pos[:, None], dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)[:, 0]                            # (B, h, dh)
    k_new = apply_rope(k_new, cos, sin)

    # In-place cache update at position `pos`: boolean select (NOT
    # one-hot arithmetic — the f32 multiply upcasts and forces SPMD
    # "involuntary full rematerialization" resharding copies of the
    # whole cache; EXPERIMENTS.md §Perf).
    at_pos = (jnp.arange(s_max)[None, :] == pos[:, None])          # (B, S) bool
    k_cache = jnp.where(at_pos[..., None, None], k_new, cache["k"])
    v_cache = jnp.where(at_pos[..., None, None], v_new, cache["v"])

    group = h // kv
    q4 = q.reshape(b, kv, group, dh).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", q4, kf) * (dh ** -0.5)
    valid = jnp.arange(s_max)[None] <= pos[:, None]               # (B, S)
    sc = jnp.where(valid[:, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf).reshape(b, h * dh)

    out = o.astype(x_tok.dtype) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------- MLA
@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 10000.0
    q_chunk: int = 512


def mla_init(rng, cfg: MLAConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(rng, 5)
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq": dense_init(ks[0], (d, h * (cfg.d_nope + cfg.d_rope)), dtype=dtype),
        "w_dkv": dense_init(ks[1], (d, cfg.kv_lora_rank + cfg.d_rope), dtype=dtype),
        "w_uk": dense_init(ks[2], (cfg.kv_lora_rank, h * cfg.d_nope), dtype=dtype),
        "w_uv": dense_init(ks[3], (cfg.kv_lora_rank, h * cfg.d_v), dtype=dtype),
        "wo": dense_init(ks[4], (h * cfg.d_v, d), scale=(h * cfg.d_v) ** -0.5, dtype=dtype),
    }


def mla_forward(params: Dict, x: jnp.ndarray, cfg: MLAConfig,
                return_cache: bool = False):
    """Training / prefill with materialized per-head K/V (cheap at train
    time); the cache stores only (c_kv, k_rope) — MLA's point."""
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank

    q = (x @ params["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = x @ params["w_dkv"]                                    # (B, S, r + dr)
    c, k_rope = ckv[..., :r], ckv[..., r:]

    pos = jnp.arange(s)[None]
    cos, sin = rope_angles(pos, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)         # (B, S, 1, dr)

    k_nope = (c @ params["w_uk"]).reshape(b, s, h, dn)
    v = (c @ params["w_uv"]).reshape(b, s, h, dv)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)

    o = chunked_causal_attention(q_full, k_full, v, cfg.q_chunk)
    out = o.astype(x.dtype).reshape(b, s, h * dv) @ params["wo"]
    if return_cache:
        return out, {"c": c, "k_rope": k_rope[:, :, 0, :]}
    return out


def mla_decode(params: Dict, x_tok: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
               cfg: MLAConfig) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-matmul MLA decode: scores are taken directly against the
    compressed cache — q_nope is mapped into c-space through W_uk and the
    value side stays compressed until the output projection.  Per-token
    cache traffic is (r + d_rope) instead of 2·h·d_head."""
    b, d = x_tok.shape
    h = cfg.n_heads
    dn, dr, dv, r = cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank
    s_max = cache["c"].shape[1]

    q = (x_tok @ params["wq"]).reshape(b, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(pos[:, None], dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope[:, None], cos, sin)[:, 0]          # (B, h, dr)

    ckv = x_tok @ params["w_dkv"]
    c_new, k_rope_new = ckv[..., :r], ckv[..., r:]
    k_rope_new = apply_rope(k_rope_new[:, None, None, :], cos, sin)[:, 0, 0]

    at_pos = (jnp.arange(s_max)[None, :] == pos[:, None])          # (B, S) bool
    c_cache = jnp.where(at_pos[..., None], c_new[:, None], cache["c"])
    kr_cache = jnp.where(at_pos[..., None], k_rope_new[:, None], cache["k_rope"])

    # absorb W_uk: q_c (B, h, r) = q_nope @ W_uk per head
    w_uk = params["w_uk"].reshape(r, h, dn)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    sc = jnp.einsum("bhr,bsr->bhs", q_c, c_cache.astype(jnp.float32))
    sc = sc + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32), kr_cache.astype(jnp.float32))
    sc = sc * ((dn + dr) ** -0.5)
    valid = jnp.arange(s_max)[None] <= pos[:, None]
    sc = jnp.where(valid[:, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)

    # weighted compressed values, then decompress once per head
    ctx = jnp.einsum("bhs,bsr->bhr", p, c_cache.astype(jnp.float32))   # (B, h, r)
    w_uv = params["w_uv"].reshape(r, h, dv)
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv.astype(jnp.float32)).reshape(b, h * dv)
    out = o.astype(x_tok.dtype) @ params["wo"]
    return out, {"c": c_cache, "k_rope": kr_cache}
