"""Decoder-only transformer LM family (dense GQA / MoE / MLA).

Covers mistral-nemo-12b, starcoder2-3b, phi4-mini-3.8b,
deepseek-v2-lite-16b and grok-1-314b from one config surface.

Compile-time discipline (one CPU core compiles 80 dry-run cells):
- `lax.scan` over layers with stacked parameters — HLO size is O(1) in
  depth.
- optional `jax.checkpoint` (full remat) around the layer body.
- chunked causal attention (models/attention.py) and a chunked
  softmax-xent so no (tokens × vocab) or (S × S) tensor is ever
  materialized whole.

Decode carries a stacked KV cache pytree (L leading dim); MLA caches
the compressed (c_kv, k_rope) pair only.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (
    AttnConfig, MLAConfig, gqa_decode, gqa_forward, gqa_init, mla_decode,
    mla_forward, mla_init,
)
from .layers import dense_init, mlp_apply, mlp_init, rms_norm
from .moe import MoEConfig, moe_ffn, moe_ffn_sharded, moe_init

__all__ = ["TransformerConfig", "init_params", "forward", "lm_loss", "prefill",
           "decode_step", "init_kv_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    mlp_kind: str = "swiglu"          # swiglu | gelu
    attn_kind: str = "gqa"            # gqa | mla
    moe: Optional[MoEConfig] = None   # None = dense FFN
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10000.0
    max_seq: int = 4096
    q_chunk: int = 512
    loss_chunk: int = 2048
    remat: bool = True
    param_dtype: Any = jnp.float32
    use_flash: bool = False           # Pallas kernels on TPU
    sp_carry: bool = True             # Megatron-SP: shard residuals over `model`
    microbatch: int = 1               # gradient-accumulation microbatches
    fsdp: bool = False                # also shard expert weights over `data`
                                      # (grok-1: params don't fit TP-only)
    grad_accum_dtype: Any = jnp.float32   # bf16 halves accumulation HBM
    zero3: bool = False               # dense layers: weights fully sharded,
                                      # gathered per layer; activations local
                                      # (no TP collectives) — §Perf hillclimb #1

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            d_head=self.d_head, rope_theta=self.rope_theta,
            q_chunk=self.q_chunk, use_flash=self.use_flash,
        )


# ---------------------------------------------------------------- params
def _layer_init(rng, cfg: TransformerConfig) -> Dict:
    k_attn, k_ffn = jax.random.split(rng)
    dt = cfg.param_dtype
    if cfg.attn_kind == "mla":
        attn = mla_init(k_attn, cfg.mla, dtype=dt)
    else:
        attn = gqa_init(k_attn, cfg.attn_cfg(), dtype=dt)
    if cfg.moe is not None:
        ffn = moe_init(k_ffn, cfg.moe, dtype=dt)
    else:
        ffn = mlp_init(k_ffn, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype=dt)
    return {
        "attn": attn,
        "ffn": ffn,
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }


def init_params(rng, cfg: TransformerConfig) -> Dict:
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    # Stacked layers: every leaf gets a leading (n_layers,) dim for lax.scan.
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=cfg.param_dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=cfg.param_dtype),
    }


# --------------------------------------------------------------- forward
def _layer_fwd_zero3(cfg: TransformerConfig, mesh, lp: Dict, x: jnp.ndarray):
    """ZeRO-3 dense block: weights stored P(data, model)-sharded, gathered
    HERE (inside the remat region → re-gathered in bwd), all math local
    over the batch shard — zero activation collectives."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def gather2d(w):
        w = jax.lax.all_gather(w, "model", axis=1, tiled=True)
        for ax_name in reversed(dp):
            w = jax.lax.all_gather(w, ax_name, axis=0, tiled=True)
        return w

    def local_fn(lp_local, x_local):
        full = {
            "attn": {k: gather2d(v) for k, v in lp_local["attn"].items()},
            "ffn": {k: (gather2d(v) if v.ndim == 2 else
                        jax.lax.all_gather(v, "model", axis=0, tiled=True))
                    for k, v in lp_local["ffn"].items()},
            "ln1": lp_local["ln1"], "ln2": lp_local["ln2"],
        }
        h = rms_norm(x_local, full["ln1"])
        h = gqa_forward(full["attn"], h, cfg.attn_cfg())
        x2 = x_local + h
        h = rms_norm(x2, full["ln2"])
        h = mlp_apply(full["ffn"], h, cfg.mlp_kind)
        return x2 + h

    w2d = P(dp, "model")
    w1d = P("model")
    lp_specs = {
        "attn": {k: w2d for k in lp["attn"]},
        "ffn": {k: (w2d if lp["ffn"][k].ndim == 2 else w1d) for k in lp["ffn"]},
        "ln1": P(), "ln2": P(),
    }
    # 256-way DP: batch shards over data AND model axes (weights are the
    # only thing living on the model axis in zero3 mode).  Falls back to
    # data-only batch sharding when the (micro)batch is too small —
    # zero3 therefore pairs with microbatch=1.
    import numpy as _np
    bx = x.shape[0]
    axes = dp + ("model",)
    n_ax = int(_np.prod([mesh.shape[a] for a in axes]))
    if bx % n_ax != 0 or bx < n_ax:
        axes = dp
    xspec = P(axes, None, None)
    out = shard_map(local_fn, mesh=mesh, in_specs=(lp_specs, xspec),
                    out_specs=xspec, check_rep=False)(lp, x)
    return out, jnp.float32(0.0)


def _layer_fwd(cfg: TransformerConfig, mesh, lp: Dict, x: jnp.ndarray):
    """One block: pre-norm attn + pre-norm FFN. x: (B, S, d)."""
    if getattr(cfg, "zero3", False) and mesh is not None and cfg.moe is None:
        return _layer_fwd_zero3(cfg, mesh, lp, x)
    h = rms_norm(x, lp["ln1"])
    if cfg.attn_kind == "mla":
        h = mla_forward(lp["attn"], h, cfg.mla)
    else:
        h = gqa_forward(lp["attn"], h, cfg.attn_cfg())
    x = x + h

    h = rms_norm(x, lp["ln2"])
    if cfg.moe is not None:
        b, s, d = h.shape
        out, aux = _apply_moe_ffn(lp["ffn"], h.reshape(b * s, d), cfg, mesh)
        h = out.reshape(b, s, d)
    else:
        h = mlp_apply(lp["ffn"], h, cfg.mlp_kind)
        aux = jnp.float32(0.0)
    out = x + h
    if mesh is not None and cfg.sp_carry and out.shape[1] % mesh.shape["model"] == 0:
        # Megatron sequence parallelism: the saved residual (the scan
        # carry — the dominant activation-memory term under remat) shards
        # its sequence dim over `model`; XLA all-gathers at QKV and
        # reduce-scatters after the FFN.  3.1x activation memory saving
        # measured on starcoder2 train_4k (EXPERIMENTS.md §Perf).
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        out = jax.lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(dp if dp else None, "model", None)))
    return out, aux


def forward(params: Dict, tokens: jnp.ndarray, cfg: TransformerConfig,
            mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (final hidden (B, S, d), aux_loss)."""
    x = jnp.take(params["embed"], tokens, axis=0)

    body = partial(_layer_fwd, cfg, mesh)
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, lp):
        x, aux = body(lp, x)
        return x, aux

    x, auxes = lax.scan(scan_body, x, params["layers"])
    return rms_norm(x, params["ln_f"]), jnp.sum(auxes)


def lm_loss(params: Dict, tokens: jnp.ndarray, targets: jnp.ndarray,
            cfg: TransformerConfig, mesh=None) -> jnp.ndarray:
    """Next-token CE, chunked over tokens so the (chunk, vocab) logits
    tile stays bounded (vocab up to 200K)."""
    h, aux = forward(params, tokens, cfg, mesh)
    b, s, d = h.shape
    flat_h = h.reshape(b * s, d)
    flat_t = targets.reshape(b * s)

    chunk = min(cfg.loss_chunk, b * s)
    n_chunks = (b * s) // chunk
    hc = flat_h[: n_chunks * chunk].reshape(n_chunks, chunk, d)
    tc = flat_t[: n_chunks * chunk].reshape(n_chunks, chunk)
    if getattr(cfg, "zero3", False) and mesh is not None:
        # zero3 replicates lm_head; shard the loss chunk rows over ALL
        # axes so the (chunk, vocab) logits tile stays per-device-small
        from jax.sharding import NamedSharding, PartitionSpec as P
        import numpy as _np
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape) + ("model",)
        n_ax = int(_np.prod([mesh.shape[a] for a in axes]))
        if chunk % n_ax == 0:
            hc = jax.lax.with_sharding_constraint(
                hc, NamedSharding(mesh, P(None, axes, None)))
            tc = jax.lax.with_sharding_constraint(
                tc, NamedSharding(mesh, P(None, axes)))

    def chunk_loss(carry, xs):
        hx, t = xs
        logits = (hx @ params["lm_head"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[:, None], axis=1)[:, 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = lax.scan(chunk_loss, jnp.float32(0.0), (hc, tc))
    loss = total / (n_chunks * chunk)
    return loss + 0.01 * aux


# ----------------------------------------------------------------- decode
def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                  dtype=None) -> Dict:
    dt = dtype or cfg.param_dtype
    if cfg.attn_kind == "mla":
        return {
            "c": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.mla.kv_lora_rank), dt),
            "k_rope": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.mla.d_rope), dt),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.d_head), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.d_head), dt),
    }


def _apply_moe_ffn(ffn_params, flat: jnp.ndarray, cfg: TransformerConfig, mesh):
    """MoE FFN on (T, d) tokens; shard_map path when a mesh is given.
    Tokens shard over the data axes when divisible, else replicate
    (B=1 long-context decode)."""
    if mesh is None:
        return moe_ffn(ffn_params, flat, cfg.moe)
    import numpy as _np
    da = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp_size = int(_np.prod([mesh.shape[a] for a in da])) if da else 1
    if flat.shape[0] % dp_size != 0 or flat.shape[0] < dp_size:
        da = ()
    return moe_ffn_sharded(ffn_params, flat, cfg.moe, mesh, data_axes=da,
                           fsdp=getattr(cfg, "fsdp", False))


def prefill(params: Dict, tokens: jnp.ndarray, cfg: TransformerConfig,
            mesh=None) -> Tuple[jnp.ndarray, Dict]:
    """Run the prompt, returning last-position logits and the KV cache.
    (Cache layout matches init_kv_cache; prompt occupies positions
    [0, S).)"""
    x = jnp.take(params["embed"], tokens, axis=0)

    def scan_body(x, lp):
        h = rms_norm(x, lp["ln1"])
        if cfg.attn_kind == "mla":
            h, cache = mla_forward(lp["attn"], h, cfg.mla, return_cache=True)
        else:
            h, cache = gqa_forward(lp["attn"], h, cfg.attn_cfg(), return_cache=True)
        x = x + h
        h2 = rms_norm(x, lp["ln2"])
        if cfg.moe is not None:
            b, s, d = h2.shape
            out, _ = _apply_moe_ffn(lp["ffn"], h2.reshape(b * s, d), cfg, mesh)
            h2 = out.reshape(b, s, d)
        else:
            h2 = mlp_apply(lp["ffn"], h2, cfg.mlp_kind)
        return x + h2, cache

    x, caches = lax.scan(scan_body, x, params["layers"])
    h_last = rms_norm(x[:, -1], params["ln_f"])
    logits = (h_last @ params["lm_head"]).astype(jnp.float32)
    return logits, caches


def decode_step(params: Dict, token: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
                cfg: TransformerConfig, mesh=None) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. token (B,) int32; pos (B,) current lengths.
    Returns (logits (B, vocab) f32, new cache)."""
    x = jnp.take(params["embed"], token, axis=0)                  # (B, d)

    def scan_body(x, xs):
        lp, layer_cache = xs
        h = rms_norm(x, lp["ln1"])
        if cfg.attn_kind == "mla":
            h, new_cache = mla_decode(lp["attn"], h, layer_cache, pos, cfg.mla)
        else:
            h, new_cache = gqa_decode(lp["attn"], h, layer_cache, pos, cfg.attn_cfg())
        x = x + h
        h2 = rms_norm(x, lp["ln2"])
        if cfg.moe is not None:
            out, _ = _apply_moe_ffn(lp["ffn"], h2, cfg, mesh)
            h2 = out
        else:
            h2 = mlp_apply(lp["ffn"], h2, cfg.mlp_kind)
        return x + h2, new_cache

    x, new_cache = lax.scan(scan_body, x, (params["layers"], cache))
    h = rms_norm(x, params["ln_f"])
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache
