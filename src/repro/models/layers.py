"""Shared model layers: norms, RoPE, MLPs, initializers.

All modules are param-dict + pure-function style (pjit/shard_map
friendly); parameter trees are plain nested dicts so sharding rules can
pattern-match on path names.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "rms_norm", "layer_norm", "apply_rope", "rope_angles",
    "mlp_init", "mlp_apply",
]

PyTree = Any


def dense_init(rng, shape, scale=None, dtype=jnp.float32):
    scale = (shape[0] ** -0.5) if scale is None else scale
    return (jax.random.truncated_normal(rng, -2, 2, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope_angles(positions: jnp.ndarray, dim: int, theta: float = 10000.0):
    """positions (...,) -> (cos, sin) each (..., dim/2), float32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D) with cos/sin (..., S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def mlp_init(rng, d_model: int, d_ff: int, kind: str = "swiglu", dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
        }
    return {  # plain gelu MLP (starcoder2-style)
        "w_up": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype=dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def mlp_apply(params: Dict, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]
