"""Pluggable index-scan backends: HOW a match rule streams the index.

The paper prices a rule execution in ``u`` — posting-plane block reads
— but pricing is only honest if bytes streamed track u.  The rule
EXECUTION semantics (paper §3: scan blocks until Δu ≥ du_quota,
Δv ≥ dv_quota, end of index, or episode budget) are fixed; the SCAN
STRATEGY underneath is not, so it is a backend:

``"xla"``
    The reference: a ``lax.while_loop`` over single blocks, each block
    evaluated on the full (T·F, W) occupancy tile
    (``core.match_rules.scan_block``).  Bit-exact semantics, but bytes
    streamed ∝ T·F·W per block regardless of rule depth.

``"pallas_block_scan"``
    Chunked plane-pruned Pallas execution
    (kernels/block_scan/block_scan_pruned.py): each kernel launch
    SPECULATIVELY evaluates a static chunk of C consecutive blocks for
    the whole batch, streaming only the rule's active (term, field)
    planes — bytes ∝ u.  The quota-crossing block is then located by
    cumulative sums of the per-block (u_inc, v_inc) increments, and
    every update (matched / cand / topn / counters) past it is masked,
    so the final :class:`EnvState` is bit-for-bit identical to the
    ``"xla"`` loop — stopping semantics preserved at chunk granularity,
    with at most C-1 blocks of speculative overshoot in bandwidth.

A backend's ``run_rule`` is BATCHED: every array argument carries a
leading query-batch axis.  ``"xla"`` vmaps the single-query loop;
``"pallas_block_scan"`` folds the batch into the kernel grid and runs
one batch-level ``while_loop`` over chunks (lanes whose stopping
condition already fired are masked to a no-op, so per-lane results
never depend on other lanes).

Registering a new strategy::

    from repro.core.scan_backends import ScanBackend, register_scan_backend

    class MyBackend(ScanBackend):
        name = "my_backend"
        def run_rule(self, cfg, occ, scores, term_present, state,
                     allowed, required, du_quota, dv_quota):
            ...

    register_scan_backend(MyBackend())

The name then works everywhere a backend is selectable:
``unified_rollout(..., backend=...)``, ``EngineConfig.backend``,
``SystemConfig.backend``, and the ``--backend`` launch flags.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.index.blocks import WORD_BITS
from repro.kernels.block_scan.block_scan_pruned import (
    block_scan_pruned_chunk, build_rule_meta,
)

from .environment import EnvConfig, EnvState
from .match_rules import block_cost, scan_block

__all__ = [
    "ScanBackend", "XlaScanBackend", "PallasBlockScanBackend",
    "register_scan_backend", "get_scan_backend", "available_backends",
    "xla_run_rule", "adaptive_chunk_blocks", "DEFAULT_CHUNK_BLOCKS",
    "MAX_ADAPTIVE_CHUNK",
]

DEFAULT_CHUNK_BLOCKS = 4
MAX_ADAPTIVE_CHUNK = 32


class ScanBackend:
    """Protocol: one rule execution over a BATCH of queries.

    ``run_rule(cfg, occ, scores, term_present, state, allowed,
    required, du_quota, dv_quota) -> EnvState`` where every array
    argument has a leading (B,) axis: occ (B, n_blocks, T, F, W)
    uint32, scores (B, n_docs_padded) float32, term_present (B, T)
    bool, state a batched :class:`EnvState`, allowed (B, T, F) bool,
    required (B, T) bool, du_quota / dv_quota (B,) int32.

    Implementations must reproduce the paper's §3 stopping condition
    exactly — scan block j iff, with the state BEFORE block j,
    ``u - u0 < du_quota`` ∧ ``v - v0 < dv_quota`` ∧
    ``block_ptr < n_blocks`` ∧ ``u < u_budget`` ∧ ``¬done`` — and must
    not couple lanes (lane i's output may not depend on lane j's rule
    or state).
    """

    name: str = ""

    def run_rule(self, cfg: EnvConfig, occ, scores, term_present, state,
                 allowed, required, du_quota, dv_quota) -> EnvState:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"name": self.name, "kind": type(self).__name__}


_SCAN_BACKENDS: Dict[str, ScanBackend] = {}


def register_scan_backend(backend: ScanBackend) -> ScanBackend:
    """Register (or replace) a backend under ``backend.name``."""
    if not backend.name:
        raise ValueError(f"{type(backend).__name__} has no name")
    _SCAN_BACKENDS[backend.name] = backend
    return backend


def get_scan_backend(name: str) -> ScanBackend:
    try:
        return _SCAN_BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown scan backend {name!r}; available: "
            f"{available_backends()}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_SCAN_BACKENDS))


# ------------------------------------------------------- "xla" (reference)
def _unpack_words(words: jnp.ndarray) -> jnp.ndarray:
    """(W,) uint32 -> (W*32,) bool, LSB-first (matches blocks.pack_bits)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(-1).astype(bool)


def _scan_one_block(
    cfg: EnvConfig,
    occ: jnp.ndarray,          # (n_blocks, T, F, W) uint32
    scores: jnp.ndarray,       # (n_docs_padded,) float32 — precomputed L1 scores
    term_present: jnp.ndarray, # (T,) bool
    allowed: jnp.ndarray,      # (T, F) bool
    required: jnp.ndarray,     # (T,) bool
    state: EnvState,
) -> EnvState:
    W, D = cfg.words_per_block, cfg.block_docs
    bp = state.block_ptr
    occ_block = lax.dynamic_index_in_dim(occ, bp, axis=0, keepdims=False)

    match_words, v_inc = scan_block(occ_block, allowed, required, term_present)

    # Dedup against docs already selected by earlier rules / passes.
    old = lax.dynamic_slice(state.matched, (bp * W,), (W,))
    new_words = match_words & ~old
    matched = lax.dynamic_update_slice(state.matched, old | match_words, (bp * W,))

    new_bits = _unpack_words(new_words)                       # (D,) bool
    doc_ids = bp * D + jnp.arange(D, dtype=jnp.int32)

    # Append new docs to the fixed-K buffer in scan (static-rank) order.
    pos = state.cand_cnt + jnp.cumsum(new_bits.astype(jnp.int32)) - 1
    write_pos = jnp.where(new_bits & (pos < cfg.max_candidates), pos, cfg.max_candidates)
    cand = state.cand.at[write_pos].set(doc_ids, mode="drop")
    n_new = jnp.sum(new_bits, dtype=jnp.int32)
    cand_cnt = jnp.minimum(state.cand_cnt + n_new, cfg.max_candidates)

    # Update running top-n L1 scores with the block's new docs.
    block_scores = lax.dynamic_slice(scores, (bp * D,), (D,))
    masked = jnp.where(new_bits, block_scores, -jnp.inf)
    topn, _ = lax.top_k(jnp.concatenate([state.topn, masked]), cfg.n_top)

    u_inc = block_cost(allowed, term_present)
    return EnvState(
        block_ptr=bp + 1,
        u=state.u + u_inc,
        v=state.v + v_inc,
        matched=matched,
        cand=cand,
        cand_cnt=cand_cnt,
        topn=topn,
        done=state.done,
    )


def xla_run_rule(
    cfg: EnvConfig,
    occ: jnp.ndarray,
    scores: jnp.ndarray,
    term_present: jnp.ndarray,
    state: EnvState,
    allowed: jnp.ndarray,
    required: jnp.ndarray,
    du_quota: jnp.ndarray,
    dv_quota: jnp.ndarray,
) -> EnvState:
    """SINGLE-QUERY reference loop (the pre-refactor ``execute_rule``
    body): scan one block at a time until the stopping condition."""
    u0, v0 = state.u, state.v

    def cond(s: EnvState):
        return (
            (s.u - u0 < du_quota)
            & (s.v - v0 < dv_quota)
            & (s.block_ptr < cfg.n_blocks)
            & (s.u < cfg.u_budget)
            & ~s.done
        )

    def body(s: EnvState):
        return _scan_one_block(cfg, occ, scores, term_present, allowed, required, s)

    return lax.while_loop(cond, body, state)


class XlaScanBackend(ScanBackend):
    """Block-at-a-time XLA scanning: vmap of the reference while_loop."""

    name = "xla"

    def run_rule(self, cfg, occ, scores, term_present, state,
                 allowed, required, du_quota, dv_quota) -> EnvState:
        return jax.vmap(partial(xla_run_rule, cfg))(
            occ, scores, term_present, state, allowed, required,
            du_quota, dv_quota)


# ------------------------------------------- "pallas_block_scan" (chunked)
def adaptive_chunk_blocks(n_blocks: int, du_quota, u_inc,
                          u_budget: int) -> int:
    """Pick a speculation depth C from the rule's quota and plane count.

    A rule's expected scan length is ``du_quota / planes_read`` blocks:
    deep rules (many active planes) cross their Δu quota in a few
    blocks, so a large C wastes up to C-1 blocks of bandwidth past the
    crossing; shallow sweeps (few planes) run far, so a small C pays
    launch overhead per handful of blocks.  C is sized for the
    longest-running lane of the batch (the lanes that would otherwise
    need the most chunk launches), clamped to [1, min(n_blocks,
    MAX_ADAPTIVE_CHUNK)].

    The estimate needs CONCRETE quota/plane values: under a jit trace
    (where the policy picks rules dynamically and quotas are tracers)
    it falls back to :data:`DEFAULT_CHUNK_BLOCKS` — shapes baked into
    the kernel grid cannot depend on traced values."""
    try:
        du = np.asarray(du_quota, dtype=np.float64)
        planes = np.asarray(u_inc, dtype=np.float64)
    except jax.errors.TracerArrayConversionError:
        return DEFAULT_CHUNK_BLOCKS
    # A lane also stops at the episode budget / end of index, whichever
    # comes first; zero-plane rules cost nothing and sweep to the end.
    blocks = np.where(planes > 0,
                      np.minimum(du, u_budget) / np.maximum(planes, 1.0),
                      n_blocks)
    c = int(np.ceil(np.max(blocks, initial=1.0)))
    return int(np.clip(c, 1, min(n_blocks, MAX_ADAPTIVE_CHUNK)))


def _apply_chunk(
    cfg: EnvConfig,
    chunk: int,
    state: EnvState,           # single lane
    match: jnp.ndarray,        # (chunk, W) uint32 — per-block match words
    v_inc: jnp.ndarray,        # (chunk,) int32
    scan_mask: jnp.ndarray,    # (chunk,) bool — block actually scanned
    u_inc: jnp.ndarray,        # () int32 — planes read per block
    scores: jnp.ndarray,       # (n_docs_padded,) float32
) -> EnvState:
    """Fold one speculative chunk into the state, masking every update
    past the quota-crossing block.  Block-for-block identical to
    iterating ``_scan_one_block`` over the scanned prefix: chunk blocks
    are disjoint word ranges, so dedup only looks at ``state.matched``;
    the candidate cumsum spans the chunk in scan order; and top-n over
    the union equals iterated top-n."""
    W, D, K = cfg.words_per_block, cfg.block_docs, cfg.max_candidates
    bp = state.block_ptr
    n = jnp.sum(scan_mask, dtype=jnp.int32)

    word_mask = jnp.where(jnp.repeat(scan_mask, W),
                          jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    mwords = match.reshape(chunk * W) & word_mask

    # Pad so the chunk slice stays aligned when bp is near the end of
    # the index (dynamic_slice would otherwise clamp and shift blocks).
    total = state.matched.shape[0]
    padded = jnp.concatenate(
        [state.matched, jnp.zeros((chunk * W,), jnp.uint32)])
    old = lax.dynamic_slice(padded, (bp * W,), (chunk * W,))
    new_words = mwords & ~old
    matched = lax.dynamic_update_slice(
        padded, old | mwords, (bp * W,))[:total]

    new_bits = _unpack_words(new_words)                  # (chunk*D,) bool
    doc_ids = bp * D + jnp.arange(chunk * D, dtype=jnp.int32)
    pos = state.cand_cnt + jnp.cumsum(new_bits.astype(jnp.int32)) - 1
    write_pos = jnp.where(new_bits & (pos < K), pos, K)
    cand = state.cand.at[write_pos].set(doc_ids, mode="drop")
    n_new = jnp.sum(new_bits, dtype=jnp.int32)
    cand_cnt = jnp.minimum(state.cand_cnt + n_new, K)

    spad = jnp.concatenate([scores, jnp.zeros((chunk * D,), scores.dtype)])
    block_scores = lax.dynamic_slice(spad, (bp * D,), (chunk * D,))
    masked = jnp.where(new_bits, block_scores, -jnp.inf)
    topn, _ = lax.top_k(jnp.concatenate([state.topn, masked]), cfg.n_top)

    return EnvState(
        block_ptr=bp + n,
        u=state.u + n * u_inc,
        v=state.v + jnp.sum(v_inc * scan_mask, dtype=jnp.int32),
        matched=matched,
        cand=cand,
        cand_cnt=cand_cnt,
        topn=topn,
        done=state.done,
    )


class PallasBlockScanBackend(ScanBackend):
    """Chunked plane-pruned Pallas rule execution (bytes streamed ∝ u).

    ``chunk`` is the speculation depth C: blocks evaluated per kernel
    launch.  Larger C amortizes launch overhead and deepens the DMA
    pipeline but wastes up to C-1 blocks of bandwidth past the quota
    crossing.  ``chunk=None`` picks C adaptively per rule from its
    quota/plane count (:func:`adaptive_chunk_blocks`): deep rules get a
    small C, shallow sweeps a large one — falling back to
    :data:`DEFAULT_CHUNK_BLOCKS` when quotas are traced.  The final
    state is C-invariant either way (pinned by
    ``tests/test_scan_backends.py::test_chunk_size_invariance``).
    ``interpret=None`` follows ``kernels.common.INTERPRET`` (interpret
    mode on CPU, compiled on TPU).
    """

    name = "pallas_block_scan"

    def __init__(self, chunk: int | None = DEFAULT_CHUNK_BLOCKS,
                 interpret: bool | None = None):
        self.chunk = chunk
        self.interpret = interpret
        self.last_chunk: int | None = None   # introspection/tests

    def describe(self) -> dict:
        return dict(super().describe(),
                    chunk="adaptive" if self.chunk is None else self.chunk)

    def run_rule(self, cfg, occ, scores, term_present, state,
                 allowed, required, du_quota, dv_quota) -> EnvState:
        b, nb, t, f, w = occ.shape
        # Batched block_cost: planes the rule reads per block, per lane
        # — also the adaptive chunk heuristic's denominator.
        u_inc = jnp.sum(allowed & term_present[:, :, None], axis=(1, 2),
                        dtype=jnp.int32)                           # (B,)
        if self.chunk is None:
            chunk = adaptive_chunk_blocks(nb, du_quota, u_inc,
                                          cfg.u_budget)
        else:
            chunk = self.chunk
        chunk = max(1, min(chunk, nb))
        self.last_chunk = chunk
        occ2 = occ.reshape(b, nb, t * f, w)
        u0, v0 = state.u, state.v
        # The rule is loop-invariant: build the plane-ordering meta once
        # and only refresh the block-start column per chunk iteration.
        meta0 = build_rule_meta(allowed, required, term_present,
                                jnp.zeros((b,), jnp.int32))

        def lane_cond(s: EnvState):
            return (
                (s.u - u0 < du_quota)
                & (s.v - v0 < dv_quota)
                & (s.block_ptr < nb)
                & (s.u < cfg.u_budget)
                & ~s.done
            )

        def cond(s: EnvState):
            return jnp.any(lane_cond(s))

        def body(s: EnvState):
            meta = meta0.at[:, 0, -1].set(s.block_ptr.astype(jnp.int32))
            match, v_inc, _ = block_scan_pruned_chunk(
                occ2, meta, chunk=chunk, n_terms=t,
                interpret=self.interpret)

            # Locate the stopping block per lane by cumulative sums:
            # block j is scanned iff the §3 condition holds at the
            # state BEFORE block j.  Every term is monotone in j, so
            # the scanned set is a prefix.
            j = jnp.arange(chunk, dtype=jnp.int32)[None, :]
            u_before = s.u[:, None] + j * u_inc[:, None]
            v_prefix = jnp.concatenate(
                [jnp.zeros((b, 1), jnp.int32),
                 jnp.cumsum(v_inc[:, :-1], axis=1)], axis=1)
            v_before = s.v[:, None] + v_prefix
            ok = (
                (u_before - u0[:, None] < du_quota[:, None])
                & (v_before - v0[:, None] < dv_quota[:, None])
                & (s.block_ptr[:, None] + j < nb)
                & (u_before < cfg.u_budget)
                & ~s.done[:, None]
            )
            scan_mask = jnp.cumprod(ok.astype(jnp.int32), axis=1) > 0
            return jax.vmap(partial(_apply_chunk, cfg, chunk))(
                s, match, v_inc, scan_mask, u_inc, scores)

        return lax.while_loop(cond, body, state)


register_scan_backend(XlaScanBackend())
register_scan_backend(PallasBlockScanBackend())
