"""Telescoping cascade (paper Fig. 1): L0 match → L1 rank/prune → L2.

L0 produces an unordered candidate set (static plan or learned policy);
L1 scores candidates with the MLP ranker (or a plugged-in recsys arch)
and prunes to the top-K'; L2 re-scores with a heavier model.  On a
multi-shard index the per-shard candidate buffers are merged by static
rank before L1 — the paper's "results are aggregated across all the
machines, followed by more rank-and-prune stages".
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["l1_prune", "merge_shard_candidates"]


@partial(jax.jit, static_argnames=("keep",))
def l1_prune(
    scores_all: jnp.ndarray,  # (B, n_docs_padded) precomputed L1 scores
    cand: jnp.ndarray,        # (B, K) int32 doc ids, -1 pad
    keep: int = 100,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rank candidates by L1 score, prune to ``keep``. Returns
    (doc_ids (B, keep), scores (B, keep)) sorted descending."""
    safe = jnp.clip(cand, 0, None)
    s = jnp.take_along_axis(scores_all, safe, axis=1)
    s = jnp.where(cand >= 0, s, -jnp.inf)
    top_s, top_i = jax.lax.top_k(s, keep)
    top_ids = jnp.take_along_axis(cand, top_i, axis=1)
    top_ids = jnp.where(jnp.isfinite(top_s), top_ids, -1)
    return top_ids, top_s


@partial(jax.jit, static_argnames=("keep",))
def merge_shard_candidates(
    shard_cand: jnp.ndarray,   # (S, B, K) per-shard candidate buffers (global doc ids)
    keep: int = 512,
) -> jnp.ndarray:
    """Merge per-shard buffers by global static rank (= ascending doc id,
    because documents are laid out in static-rank order)."""
    s, b, k = shard_cand.shape
    flat = shard_cand.transpose(1, 0, 2).reshape(b, s * k)
    key = jnp.where(flat >= 0, flat, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, axis=1)
    merged = jnp.take_along_axis(flat, order[:, :keep], axis=1)
    return merged
