"""The candidate-generation RL environment (paper §3–4).

One environment instance scans ONE index shard for ONE query.  A step
executes a single match rule until its stopping condition (Δu / Δv
quota) fires — the granularity at which the paper records state and
lets the agent act.  Batched over queries with ``vmap``; distributed
over index shards with ``shard_map`` (each shard runs its own rule
sequence, mirroring "the same policy is applied on every machine which
may lead to executing different sequences of match rules").

State per query:
    block_ptr  next block to scan
    u          cumulative (term,field)-plane block reads  (paper's u)
    v          cumulative term matches among inspected docs (paper's v)
    matched    bitmap of docs already selected (dedup across rules/resets)
    cand       fixed-K candidate buffer (doc ids, -1 pad), static-rank order
    cand_cnt   number of valid candidates
    topn       running top-n L1 scores of selected docs (for Eq. 3)
    done       terminal flag
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.index.blocks import WORD_BITS
from .match_rules import RuleSet

__all__ = ["EnvConfig", "EnvState", "env_reset", "env_step", "execute_rule", "batched_env_step"]


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    n_blocks: int                 # blocks in this index shard
    block_docs: int               # docs per block
    k_rules: int                  # rule library size; actions k=reset, k+1=stop
    max_candidates: int = 512     # K
    n_top: int = 5                # paper's n (reward top-n)
    u_budget: int = 4096          # hard episode budget on u
    no_progress_penalty: float = 0.01

    @property
    def words_per_block(self) -> int:
        return self.block_docs // WORD_BITS

    @property
    def n_words_total(self) -> int:
        return self.n_blocks * self.words_per_block

    @property
    def a_reset(self) -> int:
        return self.k_rules

    @property
    def a_stop(self) -> int:
        return self.k_rules + 1

    @property
    def n_actions(self) -> int:
        return self.k_rules + 2


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EnvState:
    block_ptr: jnp.ndarray   # () int32
    u: jnp.ndarray           # () int32
    v: jnp.ndarray           # () int32
    matched: jnp.ndarray     # (n_words_total,) uint32
    cand: jnp.ndarray        # (K,) int32
    cand_cnt: jnp.ndarray    # () int32
    topn: jnp.ndarray        # (n_top,) float32, sorted desc, -inf pad
    done: jnp.ndarray        # () bool

    def tree_flatten(self):
        return (
            (self.block_ptr, self.u, self.v, self.matched, self.cand,
             self.cand_cnt, self.topn, self.done),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def env_reset(cfg: EnvConfig) -> EnvState:
    return EnvState(
        block_ptr=jnp.int32(0),
        u=jnp.int32(0),
        v=jnp.int32(0),
        matched=jnp.zeros((cfg.n_words_total,), jnp.uint32),
        cand=jnp.full((cfg.max_candidates,), -1, jnp.int32),
        cand_cnt=jnp.int32(0),
        topn=jnp.full((cfg.n_top,), -jnp.inf, jnp.float32),
        done=jnp.bool_(False),
    )


def execute_rule(
    cfg: EnvConfig,
    occ: jnp.ndarray,
    scores: jnp.ndarray,
    term_present: jnp.ndarray,
    state: EnvState,
    allowed: jnp.ndarray,
    required: jnp.ndarray,
    du_quota: jnp.ndarray,
    dv_quota: jnp.ndarray,
) -> EnvState:
    """Run one match rule until its stopping condition (paper §3):
    Δu ≥ du_quota, Δv ≥ dv_quota, end of index, or episode budget.

    Single-query REFERENCE path.  The loop body lives in
    ``core/scan_backends.py`` (``xla_run_rule``), where it doubles as
    the ``"xla"`` entry of the pluggable batched scan-backend registry;
    the plane-pruned Pallas strategy registers there as
    ``"pallas_block_scan"``.
    """
    # Local import: scan_backends imports EnvConfig/EnvState from here.
    from .scan_backends import xla_run_rule

    return xla_run_rule(cfg, occ, scores, term_present, state,
                        allowed, required, du_quota, dv_quota)


def env_step(
    cfg: EnvConfig,
    ruleset: RuleSet,
    occ: jnp.ndarray,
    scores: jnp.ndarray,
    term_present: jnp.ndarray,
    state: EnvState,
    action: jnp.ndarray,       # () int32 in [0, k+1]
) -> EnvState:
    """One agent step: a match-rule execution, a_reset, or a_stop."""
    is_rule = action < cfg.k_rules
    is_reset = action == cfg.a_reset
    is_stop = action == cfg.a_stop

    rule_idx = jnp.minimum(action, cfg.k_rules - 1)
    allowed, required, du_q, dv_q = ruleset.gather(rule_idx)
    # Zero quotas make the inner loop a no-op for reset/stop actions.
    du_q = jnp.where(is_rule & ~state.done, du_q, 0)
    dv_q = jnp.where(is_rule & ~state.done, dv_q, 0)

    nstate = execute_rule(cfg, occ, scores, term_present, state, allowed, required, du_q, dv_q)

    block_ptr = jnp.where(is_reset & ~state.done, 0, nstate.block_ptr)
    done = state.done | is_stop | (nstate.u >= cfg.u_budget)
    return EnvState(
        block_ptr=block_ptr,
        u=nstate.u,
        v=nstate.v,
        matched=nstate.matched,
        cand=nstate.cand,
        cand_cnt=nstate.cand_cnt,
        topn=nstate.topn,
        done=done,
    )


@partial(jax.jit, static_argnums=(0,))
def batched_env_step(cfg, ruleset, occ, scores, term_present, state, action):
    """vmap over the query batch (leading axis of occ/scores/term_present/
    state/action)."""
    return jax.vmap(partial(env_step, cfg, ruleset))(occ, scores, term_present, state, action)
