"""Match rules: per-term conjunctions of per-field disjunctions.

A match rule (paper §3) is e.g.::

    mr_A -> (halloween ∈ A|U|B|T) ∧ (costumes ∈ A|U|B|T)
    mr_B -> (facebook  ∈ U|T)                       # 'login' relaxed

We represent a library of ``k`` rules as arrays so the whole engine is
JAX-traceable:

    allowed  (k, T, F) bool   fields a rule inspects per term slot
    required (k, T)    bool   whether the term participates in the conjunction
    du_quota (k,)      int32  stopping condition: max Δu per execution
    dv_quota (k,)      int32  stopping condition: max Δv per execution

``scan_block`` is the pure-jnp evaluation of one rule over one bitpacked
block — the math that the ``block_scan`` Pallas kernel tiles over many
blocks (kernels/block_scan/ref.py delegates here).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.corpus import A, U, B, T, N_FIELDS
from repro.index.builder import MAX_QUERY_TERMS
from repro.kernels.common import reduce_and, reduce_or

__all__ = ["RuleSet", "default_rule_library", "scan_block", "block_cost"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RuleSet:
    allowed: jnp.ndarray    # (k, T, F) bool
    required: jnp.ndarray   # (k, T) bool
    du_quota: jnp.ndarray   # (k,) int32
    dv_quota: jnp.ndarray   # (k,) int32

    @property
    def k(self) -> int:
        return self.allowed.shape[0]

    def tree_flatten(self):
        return (self.allowed, self.required, self.du_quota, self.dv_quota), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def gather(self, a: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        """Rule parameters for action index ``a`` (traced)."""
        return (
            jnp.take(self.allowed, a, axis=0),
            jnp.take(self.required, a, axis=0),
            jnp.take(self.du_quota, a, axis=0),
            jnp.take(self.dv_quota, a, axis=0),
        )


def default_rule_library(
    du_scale: int = 1,
    dv_scale: int = 1,
    t: int = MAX_QUERY_TERMS,
) -> RuleSet:
    """Six hand-designed rules, strict → relaxed, mirroring the paper's
    examples.  Quotas are expressed in plane-blocks (Δu) and term matches
    (Δv); ``*_scale`` lets configs adapt them to corpus size.
    """
    F = N_FIELDS
    k = 6
    allowed = np.zeros((k, t, F), dtype=bool)
    required = np.zeros((k, t), dtype=bool)

    # mr0: every term in any of A|U|B|T  (the expensive, deep rule)
    allowed[0, :, :] = True
    required[0, :] = True
    # mr1: every term in U|T (navigational shallow scan)
    allowed[1, :, [U]] = True
    allowed[1, :, [T]] = True
    required[1, :] = True
    # mr2: every term in A|T (popularity-biased shallow scan)
    allowed[2, :, [A]] = True
    allowed[2, :, [T]] = True
    required[2, :] = True
    # mr3: every term in B|T (topical scan)
    allowed[3, :, [B]] = True
    allowed[3, :, [T]] = True
    required[3, :] = True
    # mr4: first two terms in any field, remaining terms relaxed
    allowed[4, :2, :] = True
    required[4, :2] = True
    # mr5: body-only conjunction (recall backstop)
    allowed[5, :, [B]] = True
    required[5, :] = True

    du = np.array([16, 4, 4, 8, 8, 12], dtype=np.int32) * du_scale
    dv = np.array([512, 64, 64, 256, 256, 384], dtype=np.int32) * dv_scale

    return RuleSet(
        allowed=jnp.asarray(allowed),
        required=jnp.asarray(required),
        du_quota=jnp.asarray(du),
        dv_quota=jnp.asarray(dv),
    )


def block_cost(allowed: jnp.ndarray, term_present: jnp.ndarray) -> jnp.ndarray:
    """Δu for scanning ONE block with a rule: number of (term, field)
    posting planes actually read.  (T, F) bool × (T,) bool → int32."""
    return jnp.sum(allowed & term_present[:, None], dtype=jnp.int32)


def scan_block(
    occ_block: jnp.ndarray,      # (T, F, W) uint32
    allowed: jnp.ndarray,        # (T, F) bool
    required: jnp.ndarray,       # (T,) bool
    term_present: jnp.ndarray,   # (T,) bool
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Evaluate one match rule over one block.

    Returns:
      match_words: (W,) uint32 — bit set iff the doc satisfies the rule
      v_inc:       ()  int32  — term-match count among inspected docs
                    (Σ_t popcount(∨_{f allowed} occ[t,f])), the paper's v.
    """
    mask = (allowed & term_present[:, None]).astype(jnp.uint32)          # (T, F)
    planes = occ_block * mask[..., None]                                 # (T, F, W)
    tf_or = reduce_or(planes, (1,))                                      # (T, W)

    req = (required & term_present).astype(jnp.uint32)[:, None]          # (T, 1)
    # Non-required slots contribute all-ones to the conjunction.
    conj_in = tf_or | (jnp.uint32(0xFFFFFFFF) * (1 - req))
    match = reduce_and(conj_in, (0,))                                    # (W,)
    any_req = jnp.any(required & term_present)
    match = jnp.where(any_req, match, jnp.uint32(0))

    v_inc = jnp.sum(jax.lax.population_count(tf_or), dtype=jnp.int32)
    return match, v_inc
