"""The ONE rollout scan loop (Unified Policy API).

Every regime the paper compares — static production match plans (§3),
ε-greedy Q-learning episodes (§4), and greedy test-time/serving
rollouts — is the same computation: a ``lax.scan`` over agent steps,
where each step asks a *policy* for an action and advances the batched
match environment.  Historically the repo had three bespoke copies of
that loop (static-plan execution, Q-learning episodes, and the AOT
serve path); they now all route here.  HOW each rule execution streams
the index is a pluggable *scan backend* (``core/scan_backends.py``,
static ``backend=`` argument): the ``"xla"`` reference loop or the
chunked plane-pruned ``"pallas_block_scan"`` kernel, bit-identical.

A policy emits a :class:`PolicyAction` — a structured action that is a
superset of the paper's action space: the rule/reset/stop index, plus
the static-plan extras (rewind the scan pointer before executing,
per-entry Δu/Δv quota overrides).  With the extras at their neutral
values (``reset_before=False``, quotas ``USE_RULE_QUOTA``) the step is
bit-identical to the legacy ``env_step``; with them driven from a
``MatchPlan`` entry it is bit-identical to the legacy plan executor.

``unified_rollout`` returns BOTH products the old loops split between
them: the transition set ``{s, a, r, s2, done, valid}`` (for TD
updates) and the per-step trajectory ``{u, v, topn_sum, cand_cnt}``
(for baseline metrics and state-bin fitting).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
from jax import lax

from .environment import EnvConfig, EnvState, env_reset
from .match_rules import RuleSet
from .reward import step_reward
from .scan_backends import ScanBackend, get_scan_backend
from .state_bins import bin_index

__all__ = [
    "USE_RULE_QUOTA", "PolicyAction", "RolloutResult",
    "policy_env_step", "unified_rollout",
]

# Sentinel quota: "use the rule library's own Δu/Δv stopping condition".
USE_RULE_QUOTA = -1


class PolicyAction(NamedTuple):
    """Structured per-query action emitted by a Policy (all (B,) arrays)."""

    action: jnp.ndarray        # int32 in [0, k+1]: rule idx, a_reset, a_stop
    reset_before: jnp.ndarray  # bool — rewind block_ptr before executing
    du_quota: jnp.ndarray      # int32 — Δu override, USE_RULE_QUOTA = default
    dv_quota: jnp.ndarray      # int32 — Δv override, USE_RULE_QUOTA = default

    @staticmethod
    def plain(action: jnp.ndarray) -> "PolicyAction":
        """Wrap a bare action index with neutral extras."""
        a = action.astype(jnp.int32)
        q = jnp.full_like(a, USE_RULE_QUOTA)
        return PolicyAction(a, jnp.zeros(a.shape, jnp.bool_), q, q)


class RolloutResult(NamedTuple):
    final_state: EnvState            # batched (B, ...) leaves
    transitions: Dict[str, Any]      # {s, a, r, s2, done, valid}: (T, B)
    trajectory: Dict[str, Any]       # {u, v, topn_sum, cand_cnt}:  (T, B)


def policy_env_step(
    cfg: EnvConfig,
    ruleset: RuleSet,
    occ: jnp.ndarray,
    scores: jnp.ndarray,
    term_present: jnp.ndarray,
    state: EnvState,
    pa: PolicyAction,
    backend: Union[str, ScanBackend] = "xla",
) -> EnvState:
    """One agent step under a structured action (BATCHED over queries).

    Equals the legacy ``vmap(env_step)`` when the extras are neutral;
    reset-before is applied unconditionally (plan semantics: the legacy
    executor rewound the pointer regardless of budget exhaustion).
    ``backend`` (static) selects the index-scan strategy for the rule's
    inner loop — see ``core/scan_backends.py``; every registered
    backend is pinned bit-for-bit against ``"xla"``.
    """
    scan = get_scan_backend(backend) if isinstance(backend, str) else backend
    action = pa.action
    is_rule = action < cfg.k_rules
    is_reset = action == cfg.a_reset
    is_stop = action == cfg.a_stop

    bp = jnp.where(pa.reset_before, 0, state.block_ptr)
    state = dataclasses.replace(state, block_ptr=bp)

    rule_idx = jnp.minimum(action, cfg.k_rules - 1)
    allowed, required, du_q, dv_q = ruleset.gather(rule_idx)
    du_q = jnp.where(pa.du_quota >= 0, pa.du_quota, du_q)
    dv_q = jnp.where(pa.dv_quota >= 0, pa.dv_quota, dv_q)
    # Zero quotas make the inner loop a no-op for reset/stop/done.
    du_q = jnp.where(is_rule & ~state.done, du_q, 0)
    dv_q = jnp.where(is_rule & ~state.done, dv_q, 0)

    nstate = scan.run_rule(
        cfg, occ, scores, term_present, state, allowed, required, du_q, dv_q
    )

    block_ptr = jnp.where(is_reset & ~state.done, 0, nstate.block_ptr)
    done = state.done | is_stop | (nstate.u >= cfg.u_budget)
    return dataclasses.replace(nstate, block_ptr=block_ptr, done=done)


def _batch_reset(cfg: EnvConfig, batch: int) -> EnvState:
    return jax.vmap(lambda _: env_reset(cfg))(jnp.arange(batch))


@partial(jax.jit, static_argnums=(0, 4), static_argnames=("backend",))
def unified_rollout(
    cfg: EnvConfig,
    ruleset: RuleSet,
    bins,                          # StateBins or None (policies that bin)
    policy,                        # repro.policies.Policy (a pytree)
    t_max: int,                    # static: episode length
    occ: jnp.ndarray,              # (B, n_blocks, T, F, W) uint32
    scores: jnp.ndarray,           # (B, n_pad) float32
    term_present: jnp.ndarray,     # (B, T) bool
    prod_rewards: Optional[jnp.ndarray] = None,  # (B, Lp) Eq. 4 subtrahend
    rng: Optional[jax.Array] = None,
    *,
    backend: str = "xla",          # static: scan backend (scan_backends.py)
) -> RolloutResult:
    """Run ``policy`` for ``t_max`` steps over a query batch.

    The compiled executable is keyed on (cfg, t_max, backend, policy
    *structure*); policy parameters (Q-tables, plan entries, ε) are
    runtime arguments, so e.g. publishing a new Q-table snapshot never
    retraces.  ``backend`` selects how rule executions stream the index
    (``"xla"`` reference loop vs ``"pallas_block_scan"`` chunked
    plane-pruned kernel); every backend produces bit-identical states.
    """
    batch = occ.shape[0]
    state0 = _batch_reset(cfg, batch)
    if prod_rewards is None:
        prod_rewards = jnp.zeros((batch, 1), jnp.float32)
    if rng is None:
        rng = jax.random.key(0)
    lp = prod_rewards.shape[1]

    def state_bin(state: EnvState) -> jnp.ndarray:
        if bins is None:
            return jnp.zeros((batch,), jnp.int32)
        return bin_index(bins, state.u, state.v)

    scan = get_scan_backend(backend)

    def step(carry, t):
        state, rng = carry
        rng, sub = jax.random.split(rng)

        s_bin = state_bin(state)
        pa = policy.act(s_bin, state, sub, t)
        new_state = policy_env_step(
            cfg, ruleset, occ, scores, term_present, state, pa, scan
        )

        r_prod_t = prod_rewards[:, jnp.minimum(t, lp - 1)]
        r = jax.vmap(partial(step_reward, cfg))(state, new_state, r_prod_t)

        trans = {
            "s": s_bin,
            "a": pa.action,
            "r": r,
            "s2": state_bin(new_state),
            "done": new_state.done,
            "valid": ~state.done,
        }
        traj = {
            "u": new_state.u,
            "v": new_state.v,
            "topn_sum": jnp.sum(
                jnp.where(jnp.isfinite(new_state.topn), new_state.topn, 0.0),
                axis=-1,
            ),
            "cand_cnt": new_state.cand_cnt,
        }
        return (new_state, rng), (trans, traj)

    (final_state, _), (transitions, trajectory) = lax.scan(
        step, (state0, rng), jnp.arange(t_max)
    )
    return RolloutResult(final_state, transitions, trajectory)
