"""Equal-mass discretization of the (u, v) state space (paper §4).

"We run the baseline match plans ... and collect a large set of
{u_t, v_t} pairs ... We assign these points to p bins, such that each
bin has roughly the same number of points."

Two-level quantile scheme: √p equal-mass strata over u, then √p
equal-mass v-quantiles *within each stratum* — every bin holds ≈ N/p of
the harvested points even when u and v are strongly correlated (they
are: both grow monotonically along a scan).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StateBins", "fit_bins", "bin_index"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StateBins:
    u_edges: jnp.ndarray   # (pu - 1,) interior edges over u
    v_edges: jnp.ndarray   # (pu, pv - 1) per-stratum interior edges over v

    @property
    def pu(self) -> int:
        return self.v_edges.shape[0]

    @property
    def pv(self) -> int:
        return self.v_edges.shape[1] + 1

    @property
    def p(self) -> int:
        return self.pu * self.pv

    def tree_flatten(self):
        return ((self.u_edges, self.v_edges), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def fit_bins(u: np.ndarray, v: np.ndarray, p: int = 1024) -> StateBins:
    """Fit from harvested baseline (u, v) pairs (host-side)."""
    u = np.asarray(u, dtype=np.float32).ravel()
    v = np.asarray(v, dtype=np.float32).ravel()
    pu = max(1, int(np.sqrt(p)))
    pv = max(1, p // pu)

    qs_u = np.quantile(u, np.linspace(0, 1, pu + 1)[1:-1])
    u_edges = np.asarray(qs_u, dtype=np.float32)

    strata = np.searchsorted(u_edges, u, side="right")
    v_edges = np.zeros((pu, pv - 1), dtype=np.float32)
    for s in range(pu):
        vs = v[strata == s]
        if len(vs) < pv:
            vs = v  # sparse stratum: fall back to the global distribution
        v_edges[s] = np.quantile(vs, np.linspace(0, 1, pv + 1)[1:-1])

    return StateBins(u_edges=jnp.asarray(u_edges), v_edges=jnp.asarray(v_edges))


def bin_index(bins: StateBins, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Device-side state index in [0, p).  Accepts scalars or batches."""
    uf = u.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.searchsorted(bins.u_edges, uf, side="right")            # stratum
    edges = jnp.take(bins.v_edges, s, axis=0)                       # (..., pv-1)
    vb = jnp.sum(edges <= vf[..., None], axis=-1)
    return (s * bins.pv + vb).astype(jnp.int32)
