"""Reward functions — Eq. 3 and Eq. 4 of the paper.

    r_agent(s_t, a_t) = ( Σ_{i=1..m} g(d_i) ) / ( m · u_{t+1} ),
                        m = min(v_{t+1}, n)

``g(d_i)`` are L1-ranker scores of the top-m documents recalled so far
(the running ``topn`` buffer maintained by the environment).  The final
training reward subtracts the production plan's reward at the same step
(Eq. 4); if an action selects no new documents it earns a small
negative reward instead.
"""
from __future__ import annotations

import jax.numpy as jnp

from .environment import EnvConfig, EnvState

__all__ = ["r_agent", "step_reward"]


def r_agent(cfg: EnvConfig, state: EnvState) -> jnp.ndarray:
    """Eq. 3 evaluated at a state (per query, scalar)."""
    m = jnp.clip(jnp.minimum(state.v, cfg.n_top), 1, cfg.n_top)
    idx = jnp.arange(cfg.n_top)
    topm = jnp.where((idx < m) & jnp.isfinite(state.topn), state.topn, 0.0)
    u = jnp.maximum(state.u, 1).astype(jnp.float32)
    return jnp.sum(topm) / (m.astype(jnp.float32) * u)


def step_reward(
    cfg: EnvConfig,
    prev: EnvState,
    new: EnvState,
    r_production_t: jnp.ndarray,
) -> jnp.ndarray:
    """Eq. 4 with the no-progress penalty.  ``r_production_t`` is the
    production plan's r_agent at the aligned step for the same query
    (precomputed from its trajectory; DESIGN.md §4)."""
    no_new = new.cand_cnt == prev.cand_cnt
    ra = r_agent(cfg, new)
    r = jnp.where(no_new, -cfg.no_progress_penalty, ra - r_production_t)
    # Terminal no-op steps (already done) earn exactly zero.
    return jnp.where(prev.done, 0.0, r)
