"""Table-based Q-learning for dynamic match planning (paper §4).

Q is a dense (p, k+2) table.  Rollouts are fully on-device through the
single ``repro.core.rollout.unified_rollout`` scan: ε-greedy behaviour
during training (``EpsilonGreedy(TabularQPolicy(q), ε)``) and greedy
action selection at test time (``TabularQPolicy``).  TD(0) updates are
batched: transitions landing in the same (state, action) cell are
averaged (scatter-mean) before the learning-rate step, which keeps the
update order-independent and deterministic.

``rollout`` / ``greedy_rollout`` remain as deprecated thin wrappers
over the unified engine.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .environment import EnvConfig, EnvState
from .match_rules import RuleSet
from .rollout import unified_rollout
from .state_bins import StateBins

__all__ = ["QConfig", "init_q", "rollout", "td_update", "train_batch", "greedy_rollout"]


@dataclasses.dataclass(frozen=True)
class QConfig:
    p: int                    # number of state bins
    n_actions: int            # k_rules + 2
    alpha: float = 0.25       # TD learning rate
    gamma: float = 0.98       # discount (paper: 0 < γ ≤ 1)
    t_max: int = 8            # episode cap (paper: max execution time)
    optimistic_init: float = 0.05


def init_q(qcfg: QConfig) -> jnp.ndarray:
    """Optimistic-ish init encourages early exploration of all rules."""
    return jnp.full((qcfg.p, qcfg.n_actions), qcfg.optimistic_init, jnp.float32)


def _epsilon_rollout(cfg, qcfg, ruleset, bins, q, occ, scores, term_present,
                     prod_rewards, epsilon, rng):
    """ε-greedy training episode through the unified engine."""
    from repro.policies import EpsilonGreedy, TabularQPolicy

    policy = EpsilonGreedy(TabularQPolicy(q), epsilon)
    res = unified_rollout(cfg, ruleset, bins, policy, qcfg.t_max,
                          occ, scores, term_present, prod_rewards, rng)
    return res.final_state, res.transitions


def rollout(
    cfg: EnvConfig,
    qcfg: QConfig,
    ruleset: RuleSet,
    bins: StateBins,
    q: jnp.ndarray,            # (p, A)
    occ: jnp.ndarray,          # (B, n_blocks, T, F, W)
    scores: jnp.ndarray,       # (B, n_pad)
    term_present: jnp.ndarray, # (B, T)
    prod_rewards: jnp.ndarray, # (B, Lp) production per-step r_agent (Eq. 4)
    epsilon: jnp.ndarray,      # () float32
    rng: jax.Array,
) -> Tuple[EnvState, dict]:
    """Deprecated: ε-greedy episode for a query batch.  Returns final
    states and the transition set {s, a, r, s2, done, valid} each
    (T_max, B).  Use ``unified_rollout`` + ``EpsilonGreedy``."""
    warnings.warn(
        "qlearning.rollout is deprecated; use "
        "repro.core.rollout.unified_rollout with "
        "repro.policies.EpsilonGreedy(TabularQPolicy(q), eps)",
        DeprecationWarning, stacklevel=2)
    return _epsilon_rollout(cfg, qcfg, ruleset, bins, q, occ, scores,
                            term_present, prod_rewards, epsilon, rng)


def td_update(qcfg: QConfig, q: jnp.ndarray, transitions: dict) -> jnp.ndarray:
    """Scatter-mean TD(0) over the flattened (state, action) cells."""
    s = transitions["s"].reshape(-1)
    a = transitions["a"].reshape(-1)
    r = transitions["r"].reshape(-1)
    s2 = transitions["s2"].reshape(-1)
    done = transitions["done"].reshape(-1)
    valid = transitions["valid"].reshape(-1)

    target = r + qcfg.gamma * jnp.where(done, 0.0, jnp.max(q[s2], axis=-1))
    td = target - q[s, a]
    td = jnp.where(valid, td, 0.0)

    flat = s * qcfg.n_actions + a
    n_cells = qcfg.p * qcfg.n_actions
    sums = jnp.zeros((n_cells,), jnp.float32).at[flat].add(td)
    counts = jnp.zeros((n_cells,), jnp.float32).at[flat].add(valid.astype(jnp.float32))
    mean_td = sums / jnp.maximum(counts, 1.0)
    return q + qcfg.alpha * mean_td.reshape(qcfg.p, qcfg.n_actions)


@partial(jax.jit, static_argnums=(0, 1))
def train_batch(cfg, qcfg, ruleset, bins, q, occ, scores, term_present, prod_rewards, epsilon, rng):
    final_state, transitions = _epsilon_rollout(
        cfg, qcfg, ruleset, bins, q, occ, scores, term_present, prod_rewards, epsilon, rng
    )
    q_new = td_update(qcfg, q, transitions)
    metrics = {
        "mean_u": jnp.mean(final_state.u.astype(jnp.float32)),
        "mean_v": jnp.mean(final_state.v.astype(jnp.float32)),
        "mean_cand": jnp.mean(final_state.cand_cnt.astype(jnp.float32)),
        "mean_reward": jnp.sum(transitions["r"] * transitions["valid"])
        / jnp.maximum(jnp.sum(transitions["valid"]), 1),
        "q_abs_mean": jnp.mean(jnp.abs(q_new)),
    }
    return q_new, metrics


def greedy_rollout(cfg, qcfg, ruleset, bins, q, occ, scores, term_present):
    """Deprecated: test-time greedy argmax over Q (paper §4).  Use
    ``unified_rollout`` + ``TabularQPolicy``."""
    warnings.warn(
        "greedy_rollout is deprecated; use "
        "repro.core.rollout.unified_rollout with "
        "repro.policies.TabularQPolicy(q)",
        DeprecationWarning, stacklevel=2)
    from repro.policies import TabularQPolicy

    res = unified_rollout(cfg, ruleset, bins, TabularQPolicy(q), qcfg.t_max,
                          occ, scores, term_present)
    return res.final_state, res.transitions["a"]
