"""Table-based Q-learning for dynamic match planning (paper §4).

Q is a dense (p, k+2) table.  Rollouts are fully on-device through the
single ``repro.core.rollout.unified_rollout`` scan: ε-greedy behaviour
during training (``EpsilonGreedy(TabularQPolicy(q), ε)``) and greedy
action selection at test time (``TabularQPolicy``).  TD(0) updates are
batched: transitions landing in the same (state, action) cell are
averaged (scatter-mean) before the learning-rate step, which keeps the
update order-independent and deterministic.

``train_batch`` takes a static ``backend`` (core/scan_backends.py), so
training episodes can run plane-pruned Pallas scans, not just serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .rollout import unified_rollout

__all__ = ["QConfig", "init_q", "linear_epsilon", "td_update", "train_batch"]


def linear_epsilon(it: int, iters: int, eps_start: float,
                   eps_end: float) -> float:
    """The linear ε anneal shared by the offline trainer
    (``RetrievalSystem.train_policy``) and the online ``TrainerLoop``."""
    return eps_start + (eps_end - eps_start) * it / max(iters - 1, 1)


@dataclasses.dataclass(frozen=True)
class QConfig:
    p: int                    # number of state bins
    n_actions: int            # k_rules + 2
    alpha: float = 0.25       # TD learning rate
    gamma: float = 0.98       # discount (paper: 0 < γ ≤ 1)
    t_max: int = 8            # episode cap (paper: max execution time)
    optimistic_init: float = 0.05


def init_q(qcfg: QConfig) -> jnp.ndarray:
    """Optimistic-ish init encourages early exploration of all rules."""
    return jnp.full((qcfg.p, qcfg.n_actions), qcfg.optimistic_init, jnp.float32)


def _epsilon_rollout(cfg, qcfg, ruleset, bins, q, occ, scores, term_present,
                     prod_rewards, epsilon, rng, backend="xla"):
    """ε-greedy training episode through the unified engine."""
    from repro.policies import EpsilonGreedy, TabularQPolicy

    policy = EpsilonGreedy(TabularQPolicy(q), epsilon)
    res = unified_rollout(cfg, ruleset, bins, policy, qcfg.t_max,
                          occ, scores, term_present, prod_rewards, rng,
                          backend=backend)
    return res.final_state, res.transitions


def td_update(qcfg: QConfig, q: jnp.ndarray, transitions: dict) -> jnp.ndarray:
    """Scatter-mean TD(0) over the flattened (state, action) cells."""
    s = transitions["s"].reshape(-1)
    a = transitions["a"].reshape(-1)
    r = transitions["r"].reshape(-1)
    s2 = transitions["s2"].reshape(-1)
    done = transitions["done"].reshape(-1)
    valid = transitions["valid"].reshape(-1)

    target = r + qcfg.gamma * jnp.where(done, 0.0, jnp.max(q[s2], axis=-1))
    td = target - q[s, a]
    td = jnp.where(valid, td, 0.0)

    flat = s * qcfg.n_actions + a
    n_cells = qcfg.p * qcfg.n_actions
    sums = jnp.zeros((n_cells,), jnp.float32).at[flat].add(td)
    counts = jnp.zeros((n_cells,), jnp.float32).at[flat].add(valid.astype(jnp.float32))
    mean_td = sums / jnp.maximum(counts, 1.0)
    return q + qcfg.alpha * mean_td.reshape(qcfg.p, qcfg.n_actions)


@partial(jax.jit, static_argnums=(0, 1), static_argnames=("backend",))
def train_batch(cfg, qcfg, ruleset, bins, q, occ, scores, term_present,
                prod_rewards, epsilon, rng, *, backend="xla"):
    final_state, transitions = _epsilon_rollout(
        cfg, qcfg, ruleset, bins, q, occ, scores, term_present, prod_rewards,
        epsilon, rng, backend
    )
    q_new = td_update(qcfg, q, transitions)
    metrics = {
        "mean_u": jnp.mean(final_state.u.astype(jnp.float32)),
        "mean_v": jnp.mean(final_state.v.astype(jnp.float32)),
        "mean_cand": jnp.mean(final_state.cand_cnt.astype(jnp.float32)),
        "mean_reward": jnp.sum(transitions["r"] * transitions["valid"])
        / jnp.maximum(jnp.sum(transitions["valid"]), 1),
        "q_abs_mean": jnp.mean(jnp.abs(q_new)),
    }
    return q_new, metrics
