"""Table-based Q-learning for dynamic match planning (paper §4).

Q is a dense (p, k+2) table.  Rollouts are fully on-device: a
``lax.scan`` over agent steps wrapping the batched environment, with
ε-greedy behaviour during training and greedy action selection at test
time.  TD(0) updates are batched: transitions landing in the same
(state, action) cell are averaged (scatter-mean) before the learning-
rate step, which keeps the update order-independent and deterministic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .environment import EnvConfig, EnvState, env_reset, env_step
from .match_rules import RuleSet
from .reward import step_reward
from .state_bins import StateBins, bin_index

__all__ = ["QConfig", "init_q", "rollout", "td_update", "train_batch", "greedy_rollout"]


@dataclasses.dataclass(frozen=True)
class QConfig:
    p: int                    # number of state bins
    n_actions: int            # k_rules + 2
    alpha: float = 0.25       # TD learning rate
    gamma: float = 0.98       # discount (paper: 0 < γ ≤ 1)
    t_max: int = 8            # episode cap (paper: max execution time)
    optimistic_init: float = 0.05


def init_q(qcfg: QConfig) -> jnp.ndarray:
    """Optimistic-ish init encourages early exploration of all rules."""
    return jnp.full((qcfg.p, qcfg.n_actions), qcfg.optimistic_init, jnp.float32)


def _batch_reset(cfg: EnvConfig, batch: int) -> EnvState:
    return jax.vmap(lambda _: env_reset(cfg))(jnp.arange(batch))


def rollout(
    cfg: EnvConfig,
    qcfg: QConfig,
    ruleset: RuleSet,
    bins: StateBins,
    q: jnp.ndarray,            # (p, A)
    occ: jnp.ndarray,          # (B, n_blocks, T, F, W)
    scores: jnp.ndarray,       # (B, n_pad)
    term_present: jnp.ndarray, # (B, T)
    prod_rewards: jnp.ndarray, # (B, Lp) production per-step r_agent (Eq. 4)
    epsilon: jnp.ndarray,      # () float32
    rng: jax.Array,
) -> Tuple[EnvState, dict]:
    """ε-greedy episode for a query batch.  Returns final states and the
    transition set {s, a, r, s2, done, valid} each (T_max, B)."""
    batch = occ.shape[0]
    state0 = _batch_reset(cfg, batch)
    lp = prod_rewards.shape[1]

    def step(carry, t):
        state, rng = carry
        rng, k1, k2 = jax.random.split(rng, 3)

        s_bin = bin_index(bins, state.u, state.v)              # (B,)
        greedy = jnp.argmax(q[s_bin], axis=-1).astype(jnp.int32)
        explore = jax.random.randint(k1, (batch,), 0, qcfg.n_actions, dtype=jnp.int32)
        take_explore = jax.random.uniform(k2, (batch,)) < epsilon
        action = jnp.where(take_explore, explore, greedy)

        new_state = jax.vmap(partial(env_step, cfg, ruleset))(
            occ, scores, term_present, state, action
        )
        r_prod_t = prod_rewards[:, jnp.minimum(t, lp - 1)]
        r = jax.vmap(partial(step_reward, cfg))(state, new_state, r_prod_t)
        s2_bin = bin_index(bins, new_state.u, new_state.v)

        trans = {
            "s": s_bin,
            "a": action,
            "r": r,
            "s2": s2_bin,
            "done": new_state.done,
            "valid": ~state.done,
        }
        return (new_state, rng), trans

    (final_state, _), transitions = lax.scan(step, (state0, rng), jnp.arange(qcfg.t_max))
    return final_state, transitions


def td_update(qcfg: QConfig, q: jnp.ndarray, transitions: dict) -> jnp.ndarray:
    """Scatter-mean TD(0) over the flattened (state, action) cells."""
    s = transitions["s"].reshape(-1)
    a = transitions["a"].reshape(-1)
    r = transitions["r"].reshape(-1)
    s2 = transitions["s2"].reshape(-1)
    done = transitions["done"].reshape(-1)
    valid = transitions["valid"].reshape(-1)

    target = r + qcfg.gamma * jnp.where(done, 0.0, jnp.max(q[s2], axis=-1))
    td = target - q[s, a]
    td = jnp.where(valid, td, 0.0)

    flat = s * qcfg.n_actions + a
    n_cells = qcfg.p * qcfg.n_actions
    sums = jnp.zeros((n_cells,), jnp.float32).at[flat].add(td)
    counts = jnp.zeros((n_cells,), jnp.float32).at[flat].add(valid.astype(jnp.float32))
    mean_td = sums / jnp.maximum(counts, 1.0)
    return q + qcfg.alpha * mean_td.reshape(qcfg.p, qcfg.n_actions)


@partial(jax.jit, static_argnums=(0, 1))
def train_batch(cfg, qcfg, ruleset, bins, q, occ, scores, term_present, prod_rewards, epsilon, rng):
    final_state, transitions = rollout(
        cfg, qcfg, ruleset, bins, q, occ, scores, term_present, prod_rewards, epsilon, rng
    )
    q_new = td_update(qcfg, q, transitions)
    metrics = {
        "mean_u": jnp.mean(final_state.u.astype(jnp.float32)),
        "mean_v": jnp.mean(final_state.v.astype(jnp.float32)),
        "mean_cand": jnp.mean(final_state.cand_cnt.astype(jnp.float32)),
        "mean_reward": jnp.sum(transitions["r"] * transitions["valid"])
        / jnp.maximum(jnp.sum(transitions["valid"]), 1),
        "q_abs_mean": jnp.mean(jnp.abs(q_new)),
    }
    return q_new, metrics


@partial(jax.jit, static_argnums=(0, 1))
def greedy_rollout(cfg, qcfg, ruleset, bins, q, occ, scores, term_present):
    """Test-time policy: greedy argmax over Q (paper §4)."""
    batch = occ.shape[0]
    state0 = _batch_reset(cfg, batch)

    def step(state, _):
        s_bin = bin_index(bins, state.u, state.v)
        action = jnp.argmax(q[s_bin], axis=-1).astype(jnp.int32)
        new_state = jax.vmap(partial(env_step, cfg, ruleset))(
            occ, scores, term_present, state, action
        )
        return new_state, action

    final_state, actions = lax.scan(step, state0, jnp.arange(qcfg.t_max))
    return final_state, actions
