from .match_rules import RuleSet, default_rule_library, scan_block, block_cost
from .match_plan import MatchPlan, make_plan, plan_rollout, production_plans
from .environment import EnvConfig, EnvState, env_reset, env_step, execute_rule
from .scan_backends import (ScanBackend, available_backends,
                            get_scan_backend, register_scan_backend)
from .state_bins import StateBins, fit_bins, bin_index
from .reward import r_agent, step_reward
from .rollout import (PolicyAction, RolloutResult, USE_RULE_QUOTA,
                      policy_env_step, unified_rollout)
from .qlearning import QConfig, init_q, td_update, train_batch
from .telescope import l1_prune, merge_shard_candidates
