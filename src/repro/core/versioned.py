"""Generic versioned-artifact store: publish / snapshot / subscribe.

Two serving-plane artifacts hot-swap under load — policy snapshots
(`repro.policies.PolicyStore`) and index epochs
(`repro.index.live.IndexEpochStore`).  Both need the same primitive: a
producer publishes immutable snapshots with monotonically increasing
version ids; consumers pin a snapshot and periodically refresh, with a
*staleness bound* — a consumer more than ``staleness_bound`` versions
behind the head must refuse to serve (:class:`StaleVersionError`)
rather than silently answer with an ancient artifact.

This module is that shared core.  Thread-safe: ``publish`` may be
called from a producer thread while consumers ``snapshot``/``validate``
concurrently.  Snapshots are immutable objects fully built before the
head pointer moves, so a reader can never observe a torn snapshot.
Subscriber delivery is per-subscriber serialized and version-monotone —
a callback registered mid-publish observes either the old or the new
version first, never both out of order and never the same version
twice.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

__all__ = ["StaleVersionError", "Subscriber", "VersionedStore"]


class StaleVersionError(RuntimeError):
    """A consumer's pinned snapshot is older than the staleness bound.

    Base class shared by `StalePolicyError` (policy snapshots) and
    `StaleIndexEpochError` (index epochs) so serving loops can catch
    every hot-swap race with one clause."""


class Subscriber:
    """One registered callback with per-subscriber delivery state.

    ``deliver`` serializes invocations of the callback (two concurrent
    publishers never run it at once) and enforces version monotonicity:
    a snapshot at or below the last delivered version is dropped.  This
    closes the subscribe-under-concurrent-publish race where the
    initial replay of the current snapshot could land *after* a newer
    publish already notified the callback, delivering versions out of
    order."""

    __slots__ = ("callback", "_lock", "_last_version")

    def __init__(self, callback: Callable[[Any], None]):
        self.callback = callback
        self._lock = threading.Lock()
        self._last_version = 0

    def deliver(self, snap: Any) -> None:
        with self._lock:
            if snap.version <= self._last_version:
                return
            self._last_version = snap.version
            self.callback(snap)


class VersionedStore:
    """Version machinery shared by every hot-swappable serving artifact.

    Subclasses provide a domain ``publish`` that calls
    :meth:`_publish_snapshot` with a builder; snapshots must be
    immutable objects exposing an integer ``version`` attribute.
    ``stale_error`` names the exception ``validate`` raises (always a
    :class:`StaleVersionError` subclass) and ``artifact`` the noun used
    in messages."""

    stale_error = StaleVersionError
    artifact = "snapshot"

    def __init__(self, staleness_bound: int = 1):
        if staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        self.staleness_bound = staleness_bound
        self._lock = threading.Lock()
        self._snapshot: Optional[Any] = None
        self._subscribers: List[Subscriber] = []

    # ------------------------------------------------------------ publish
    def _publish_snapshot(self, build: Callable[[Optional[Any], int], Any],
                          version: Optional[int] = None) -> int:
        """Install ``build(previous_snapshot, next_version)`` as the new
        head and notify subscribers (outside the lock); returns the new
        version.  The builder runs under the store lock, so it must be
        cheap — assemble heavy payloads before publishing.

        ``version`` pins an explicit head version instead of the default
        head+1 — the cross-process relay uses this so a worker-local
        store mirrors the producer's numbering exactly (a respawned
        worker jumps straight to the head version it is sent; gaps are
        legal, regressions are not)."""
        with self._lock:
            head = self._snapshot.version if self._snapshot else 0
            if version is None:
                version = head + 1
            elif version <= head:
                raise ValueError(
                    f"explicit version {version} must exceed head {head}")
            snap = build(self._snapshot, version)
            assert snap.version == version, "builder must stamp the version"
            self._snapshot = snap
            subscribers = list(self._subscribers)
        for sub in subscribers:
            sub.deliver(snap)
        return version

    # ----------------------------------------------------------- consume
    @property
    def version(self) -> int:
        """Head version (0 before the first publish)."""
        snap = self._snapshot
        return snap.version if snap else 0

    def snapshot(self) -> Any:
        snap = self._snapshot
        if snap is None:
            raise LookupError(
                f"{type(self).__name__} has no published {self.artifact} yet")
        return snap

    def subscribe(self, callback: Callable[[Any], None]) -> Callable[[], None]:
        """Register ``callback(snapshot)`` for future publishes (and
        immediately for the current snapshot, if any).  Returns an
        unsubscribe function.

        Safe under concurrent ``publish``: the callback observes a
        strictly increasing version sequence whose first element is the
        snapshot current at registration *or any later one* — never an
        older version after a newer, never a duplicate."""
        sub = Subscriber(callback)
        with self._lock:
            self._subscribers.append(sub)
            snap = self._snapshot
        if snap is not None:
            # Replay outside the store lock; Subscriber.deliver drops
            # it if a concurrent publish already delivered a newer one.
            sub.deliver(snap)

        def unsubscribe() -> None:
            with self._lock:
                if sub in self._subscribers:
                    self._subscribers.remove(sub)
        return unsubscribe

    def staleness(self, version: int) -> int:
        """Versions between a pinned snapshot and the head."""
        return self.version - version

    def validate(self, version: int) -> int:
        """Enforce the staleness bound on a pinned snapshot version.
        Returns the staleness; raises :attr:`stale_error` beyond the
        bound."""
        staleness = self.staleness(version)
        if staleness > self.staleness_bound:
            raise self.stale_error(
                f"{self.artifact} v{version} is {staleness} versions behind "
                f"head v{self.version} "
                f"(staleness_bound={self.staleness_bound}); "
                "refresh before serving")
        return staleness
