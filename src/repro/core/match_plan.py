"""Static production match plans — the hand-crafted baseline (paper §3).

A plan is a fixed sequence of entries; each entry names a match rule,
optional quota overrides, and whether to reset the scan pointer before
executing.  Executing a plan yields the baseline trajectory used for

  (1) the production candidate sets / NCG / u metrics (Table 1 baseline),
  (2) the (u, v) point cloud that fits the state discretization, and
  (3) the per-step production rewards r_production of Eq. 4.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .match_rules import RuleSet

__all__ = ["MatchPlan", "make_plan", "production_plans", "plan_rollout"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MatchPlan:
    rule_idx: jnp.ndarray      # (L,) int32
    reset_before: jnp.ndarray  # (L,) bool
    du_quota: jnp.ndarray      # (L,) int32  (per-entry override)
    dv_quota: jnp.ndarray      # (L,) int32

    @property
    def length(self) -> int:
        return self.rule_idx.shape[0]

    def prefix(self, length: int) -> "MatchPlan":
        """The first ``length`` entries as a standalone plan — the
        shallow degraded-service fallback: its u is bounded by the
        prefix's summed Δu quotas (each rule execution stops at its
        quota), so serving it under pressure has a known worst case."""
        length = max(1, min(int(length), self.length))
        return MatchPlan(
            rule_idx=self.rule_idx[:length],
            reset_before=self.reset_before[:length],
            du_quota=self.du_quota[:length],
            dv_quota=self.dv_quota[:length],
        )

    def u_cap(self, per_entry_overshoot: int = 0) -> int:
        """Hard upper bound on u for one execution of this plan: the
        summed per-entry Δu quotas, plus the rule loop's worst-case
        quota overshoot per entry (it checks the quota between blocks,
        so the final block's planes — at most one block's worth, i.e.
        terms × fields — land past the quota)."""
        return int(np.asarray(self.du_quota).sum()
                   + self.length * per_entry_overshoot)

    def tree_flatten(self):
        return ((self.rule_idx, self.reset_before, self.du_quota, self.dv_quota), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_plan(
    ruleset: RuleSet,
    entries: Sequence[Tuple[int, bool]],
    du_overrides: Optional[Sequence[int]] = None,
    dv_overrides: Optional[Sequence[int]] = None,
) -> MatchPlan:
    rule_idx = np.array([e[0] for e in entries], dtype=np.int32)
    reset = np.array([e[1] for e in entries], dtype=bool)
    du = np.asarray(ruleset.du_quota)[rule_idx].copy()
    dv = np.asarray(ruleset.dv_quota)[rule_idx].copy()
    if du_overrides is not None:
        du = np.array(du_overrides, dtype=np.int32)
    if dv_overrides is not None:
        dv = np.array(dv_overrides, dtype=np.int32)
    return MatchPlan(
        rule_idx=jnp.asarray(rule_idx),
        reset_before=jnp.asarray(reset),
        du_quota=jnp.asarray(du),
        dv_quota=jnp.asarray(dv),
    )


def production_plans(ruleset: RuleSet) -> dict:
    """Hand-crafted per-category plans (the 'tuned for years' baseline).

    Deliberately thorough WITH accumulated redundancy — rules re-visit
    field subsets already covered and a reset pass re-scans the head —
    which is what years of incremental hand-tuning produce (the paper's
    Fig. 2 baseline sits far above the learned policy at equal
    candidate quality).  The learnable headroom is skipping redundant
    executions per query, not truncating recall.

    CAT1 — rare multi-term: deep all-field pass, topical B|T, body
    backstop, relaxed conjunction, then a reset re-scan of the head.
    CAT2 — navigational: U|T, A|T, U|T again (legacy double pass),
    topical B|T, then a deep all-field sweep.
    """
    return {
        "CAT1": make_plan(ruleset, [(0, False), (3, False), (5, False),
                                    (4, False), (0, True)]),
        "CAT2": make_plan(ruleset, [(1, False), (2, False), (1, True),
                                    (3, False), (0, False)]),
    }


def plan_rollout(cfg, ruleset, plan, occ, scores, term_present,
                 backend: str = "xla"):
    """Batched plan execution through the unified rollout engine.
    Returns (final_state, trajectory with (B, L) leaves).  ``backend``
    selects the index-scan strategy (core/scan_backends.py)."""
    # Local imports: repro.policies wraps MatchPlan, so importing it at
    # module scope would be circular.
    from repro.core.rollout import unified_rollout
    from repro.policies import StaticPlanPolicy

    policy = StaticPlanPolicy(plan, cfg.n_actions)
    res = unified_rollout(
        cfg, ruleset, None, policy, plan.length, occ, scores, term_present,
        backend=backend,
    )
    traj = jax.tree_util.tree_map(lambda x: jnp.moveaxis(x, 0, 1),
                                  res.trajectory)                # (B, L)
    return res.final_state, traj
