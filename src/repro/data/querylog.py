"""Synthetic query log with categories, popularity, and graded judgments.

Queries are generated *from* documents so that graded relevance exists
by construction:

- CAT2-style ("moderate-df multi-term"): 2–3 terms from a popular
  document's title/url — navigational-ish, head-of-distribution terms,
  high historical popularity.
- CAT1-style ("short multi-term, few occurrences over 6 months"):
  3–4 terms from a document's topic pocket ∩ body — rare topical
  queries with low popularity.

Each query carries a judged set: documents rated on a five-point scale
(0–4), exactly the evaluation substrate Table 1 needs (NCG@100 uses the
gains; the weighted eval set samples ∝ popularity, the unweighted set
uniformly over distinct queries).

The classifier `classify_query` reproduces the paper's described
mechanism (features: historical popularity, #terms, term document
frequencies → category) and is validated against the generative labels
in tests.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.index.builder import MAX_QUERY_TERMS, InvertedIndex
from repro.index.corpus import A, B, Corpus, T, U

__all__ = ["QueryLogConfig", "QueryLog", "generate_querylog", "classify_query", "sample_eval_sets"]

CAT1, CAT2 = 0, 1


@dataclasses.dataclass(frozen=True)
class QueryLogConfig:
    n_queries: int = 2000
    n_judged: int = 64
    frac_cat2: float = 0.5
    zipf_a: float = 1.2          # popularity skew over distinct queries
    seed: int = 0


@dataclasses.dataclass
class QueryLog:
    terms: np.ndarray          # (Q, MAX_QUERY_TERMS) int32, -1 pad
    n_terms: np.ndarray        # (Q,) int32
    popularity: np.ndarray     # (Q,) float64, sums to 1
    category: np.ndarray       # (Q,) int8  (0=CAT1, 1=CAT2)
    judged_ids: np.ndarray     # (Q, J) int32, -1 pad
    judged_gains: np.ndarray   # (Q, J) int8, 0..4
    seed_doc: np.ndarray       # (Q,) int32

    @property
    def n_queries(self) -> int:
        return self.terms.shape[0]

    def term_present(self) -> np.ndarray:
        return self.terms >= 0


def _doc_coverage(index: InvertedIndex, terms: np.ndarray, field: int) -> np.ndarray:
    cov = np.zeros(index.n_docs, dtype=np.float32)
    for t in terms:
        ids = index.postings(int(t), field)
        cov[ids] += 1.0
    return cov / max(len(terms), 1)


def _judge(
    rng: np.random.Generator,
    corpus: Corpus,
    index: InvertedIndex,
    terms: np.ndarray,
    topic: int,
    n_judged: int,
) -> Tuple[np.ndarray, np.ndarray]:
    title_cov = _doc_coverage(index, terms, T)
    body_cov = _doc_coverage(index, terms, B)
    topic_match = (corpus.doc_topic == topic).astype(np.float32)
    rel = (
        (0.6 * title_cov + 0.4 * body_cov) * (1.0 + 0.75 * topic_match)
        + 0.25 * corpus.static_rank
        + rng.normal(0, 0.04, size=index.n_docs).astype(np.float32)
    )
    n_top = (3 * n_judged) // 4
    top = np.argpartition(-rel, n_top)[:n_top]
    rand = rng.integers(0, index.n_docs, size=n_judged - n_top)
    judged = np.unique(np.concatenate([top, rand]))[:n_judged]
    pad = n_judged - len(judged)
    gains_f = rel[judged]
    # Five-point scale: thresholds relative to this query's top relevance.
    hi = max(gains_f.max(), 1e-6)
    edges = hi * np.array([0.35, 0.55, 0.7, 0.85])
    gains = np.digitize(gains_f, edges).astype(np.int8)
    judged_ids = np.concatenate([judged.astype(np.int32), np.full(pad, -1, np.int32)])
    gains = np.concatenate([gains, np.zeros(pad, np.int8)])
    return judged_ids, gains


def generate_querylog(
    corpus: Corpus, index: InvertedIndex, config: QueryLogConfig = QueryLogConfig()
) -> QueryLog:
    rng = np.random.default_rng(config.seed)
    Q = config.n_queries

    terms = np.full((Q, MAX_QUERY_TERMS), -1, dtype=np.int32)
    n_terms = np.zeros(Q, dtype=np.int32)
    category = np.zeros(Q, dtype=np.int8)
    seed_doc = np.zeros(Q, dtype=np.int32)
    judged_ids = np.full((Q, config.n_judged), -1, dtype=np.int32)
    judged_gains = np.zeros((Q, config.n_judged), dtype=np.int8)

    # Popular docs attract navigational (CAT2) queries.
    top_pool = max(64, corpus.n_docs // 16)

    for qi in range(Q):
        is_cat2 = rng.random() < config.frac_cat2
        if is_cat2:
            d = int(rng.integers(0, top_pool))
            pool = np.union1d(corpus.field_terms[T][d], corpus.field_terms[U][d])
            nt = int(rng.integers(2, 4))
        else:
            d = int(rng.integers(0, corpus.n_docs))
            topic = corpus.doc_topic[d]
            pool = np.intersect1d(corpus.field_terms[B][d], corpus.topic_terms[topic])
            if len(pool) < 2:
                pool = corpus.field_terms[B][d]
            nt = int(rng.integers(3, MAX_QUERY_TERMS + 1))
        nt = min(nt, len(pool))
        qt = rng.choice(pool, size=max(nt, 1), replace=False).astype(np.int32)
        terms[qi, : len(qt)] = qt
        n_terms[qi] = len(qt)
        category[qi] = CAT2 if is_cat2 else CAT1
        seed_doc[qi] = d
        judged_ids[qi], judged_gains[qi] = _judge(
            rng, corpus, index, qt, int(corpus.doc_topic[d]), config.n_judged
        )

    # Popularity: Zipf over distinct queries, biased so CAT2 (navigational)
    # occupies most of the head — matches the paper's segment-size pattern
    # (CAT2 big in the weighted set, <1% in the unweighted set).
    ranks = np.empty(Q, dtype=np.int64)
    order = np.argsort(category)[::-1]  # CAT2 first
    jitter = rng.permutation(Q // 8) if Q >= 8 else np.arange(Q)
    ranks[order] = np.arange(Q)
    pop = (1.0 + ranks.astype(np.float64)) ** (-config.zipf_a)
    pop /= pop.sum()

    return QueryLog(
        terms=terms,
        n_terms=n_terms,
        popularity=pop,
        category=category,
        judged_ids=judged_ids,
        judged_gains=judged_gains,
        seed_doc=seed_doc,
    )


def classify_query(log: QueryLog, index: InvertedIndex) -> np.ndarray:
    """The paper's query categorizer: historical popularity, number of
    terms, and term document frequencies → category."""
    df_body = index.df[:, B].astype(np.float64)
    mean_df = np.zeros(log.n_queries)
    for qi in range(log.n_queries):
        ts = log.terms[qi, : log.n_terms[qi]]
        mean_df[qi] = df_body[ts].mean() if len(ts) else 0.0
    df_frac = mean_df / index.n_docs
    pop_med = np.median(log.popularity)
    # CAT2: moderately-high df terms and head popularity; CAT1: rare terms.
    return np.where((df_frac > 0.02) & (log.popularity > pop_med), CAT2, CAT1).astype(np.int8)


def sample_eval_sets(
    log: QueryLog, n_eval: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """(weighted_ids, unweighted_ids): the paper's two test samples."""
    rng = np.random.default_rng(seed)
    weighted = rng.choice(log.n_queries, size=n_eval, replace=True, p=log.popularity)
    unweighted = rng.choice(log.n_queries, size=min(n_eval, log.n_queries), replace=False)
    return weighted.astype(np.int64), unweighted.astype(np.int64)
