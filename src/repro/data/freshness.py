"""Freshness workload: new documents arrive, queries chase them.

Drives a `repro.index.live.LiveRetrievalSystem` the way breaking-news
traffic drives a web index: every ``tick`` synthesizes a batch of fresh
documents (topic-pocketed like the corpus generator's, but born at the
BOTTOM of the static-rank order — fresh pages have no link equity yet),
appends queries targeting them (title/topical terms, CAT2-shaped, the
new doc judged relevant), commits an index epoch, and emits a query
wave mixing hot fresh queries with background log traffic.

The wave is what the index-smoke harness and ``benchmarks/index_bench``
replay through a ServeEngine/ReplicaSet while the MergeDaemon compacts
underneath — the end-to-end freshness story: a query for a doc added
two ticks ago must hit it (epoch-keyed caches can't serve the pre-add
answer), and bit-parity with a from-scratch rebuild must hold at every
epoch along the way.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.querylog import CAT2
from repro.index.corpus import A, B, N_FIELDS, T, U

__all__ = ["FreshnessConfig", "FreshnessWorkload"]


@dataclasses.dataclass(frozen=True)
class FreshnessConfig:
    docs_per_tick: int = 16
    queries_per_doc: int = 1      # fresh queries appended per new doc
    wave_queries: int = 64        # total submissions emitted per tick
    frac_fresh: float = 0.7       # share of the wave aimed at fresh docs
    recency_zipf: float = 1.3     # newer fresh queries repeat more
    body_terms: int = 24
    title_terms: int = 4
    static_rank_fresh: float = 0.01   # no link equity yet
    seed: int = 0


class FreshnessWorkload:
    """Stateful generator: each ``tick`` mutates the system (docs +
    queries + commit) and returns the qid wave to replay."""

    def __init__(self, system, cfg: FreshnessConfig = FreshnessConfig()):
        self.system = system
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.fresh_qids: List[int] = []      # appended queries, oldest first
        self.added_docs: List[int] = []
        self.ticks = 0

    # ---------------------------------------------------------- synthesis
    def _synth_doc(self) -> Tuple[List[np.ndarray], int]:
        """One fresh doc in the corpus generator's shape: Zipf body +
        topical pocket, topical title, url ⊂ title, thin anchor."""
        corpus = self.system.corpus
        cfg = self.cfg
        rng = self.rng
        vocab = corpus.config.vocab_size
        topic = int(rng.integers(0, corpus.topic_terms.shape[0]))
        pocket = corpus.topic_terms[topic]

        n_body = max(4, rng.poisson(cfg.body_terms))
        n_topical = max(2, n_body // 4)
        body = np.union1d(
            rng.integers(0, vocab, size=max(1, n_body - n_topical)),
            rng.choice(pocket, size=n_topical),
        ).astype(np.int32)
        n_title = min(len(body), max(2, rng.poisson(cfg.title_terms)))
        topical_in_body = np.intersect1d(body, pocket)
        title = np.union1d(
            topical_in_body[: max(1, n_title // 2)],
            rng.choice(body, size=max(1, n_title // 2)),
        ).astype(np.int32)
        url = np.unique(rng.choice(title, size=min(len(title), 2),
                                   replace=False)).astype(np.int32)
        anchor = np.unique(rng.choice(title, size=1)).astype(np.int32)

        fields: List[np.ndarray] = [None] * N_FIELDS  # type: ignore
        fields[A], fields[U], fields[B], fields[T] = (anchor, url,
                                                      np.unique(body), title)
        return fields, topic

    def _fresh_query_terms(self, fields: Sequence[np.ndarray],
                           topic: int) -> np.ndarray:
        """2–3 terms a user chasing this doc would type: title-led,
        topical — the navigational (CAT2) shape."""
        pool = np.union1d(fields[T], fields[U])
        n = int(self.rng.integers(2, 4))
        n = min(n, len(pool)) or 1
        return np.sort(self.rng.choice(pool, size=n,
                                       replace=False)).astype(np.int32)

    # --------------------------------------------------------------- tick
    def tick(self) -> np.ndarray:
        """Add docs, append their chase queries, commit an epoch, and
        return this tick's submission wave (qids, hot-fresh-heavy)."""
        cfg = self.cfg
        sys_ = self.system
        docs, queries = [], []
        for _ in range(cfg.docs_per_tick):
            fields, topic = self._synth_doc()
            docs.append(fields)
            for _ in range(cfg.queries_per_doc):
                queries.append((fields, topic))
        doc_ids = sys_.add_documents(
            docs, static_rank=[cfg.static_rank_fresh] * len(docs))
        self.added_docs.extend(doc_ids)

        term_lists = [self._fresh_query_terms(f, t) for f, t in queries]
        judged = [[doc_ids[i // max(1, cfg.queries_per_doc)]]
                  for i in range(len(queries))]
        gains = [[4]] * len(queries)       # the fresh doc is the answer
        qids = sys_.append_queries(term_lists, [CAT2] * len(term_lists),
                                   judged_ids=judged, judged_gains=gains)
        self.fresh_qids.extend(int(q) for q in qids)
        sys_.commit_index()                # the mutation becomes an epoch
        self.ticks += 1
        return self.wave()

    def wave(self) -> np.ndarray:
        """One tick's submissions: fresh queries (recency-Zipf repeats
        of the chase queries, newest hottest) mixed with background
        traffic drawn from the base log's popularity."""
        cfg = self.cfg
        rng = self.rng
        n_fresh = int(round(cfg.wave_queries * cfg.frac_fresh))
        n_fresh = min(n_fresh, cfg.wave_queries) if self.fresh_qids else 0
        out = []
        if n_fresh:
            pool = np.asarray(self.fresh_qids[::-1])   # newest first
            ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
            p = ranks ** (-cfg.recency_zipf)
            out.append(rng.choice(pool, size=n_fresh, p=p / p.sum()))
        n_bg = cfg.wave_queries - n_fresh
        if n_bg:
            log = self.system.log
            base = len(log.popularity) - len(self.fresh_qids)
            p = np.asarray(log.popularity[:base], dtype=np.float64)
            out.append(rng.choice(base, size=n_bg, p=p / p.sum()))
        wave = np.concatenate(out).astype(np.int64)
        rng.shuffle(wave)
        return wave

    def stats(self) -> dict:
        return {"ticks": self.ticks, "docs_added": len(self.added_docs),
                "fresh_queries": len(self.fresh_qids)}
