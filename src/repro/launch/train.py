"""End-to-end training drivers.

Two modes, matching the paper's kind (candidate-generation serving) and
the framework's generality:

  policy  — the paper: build corpus/index/query log, train the L1
            ranker, fit state bins, Q-learn per-category match policies,
            evaluate vs production plans.  Fault-tolerant: checkpoints
            the Q-table + RNG state every N iters and resumes.

  lm      — train a reduced LM config for a few hundred steps on
            synthetic data through the exact sharded train step the
            dry-run lowers (1-device mesh on CPU), with checkpoint/
            restart via the resilient loop.

    PYTHONPATH=src python -m repro.launch.train policy --iters 200
    PYTHONPATH=src python -m repro.launch.train lm --arch starcoder2-3b --steps 100
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def train_policy_cmd(args) -> None:
    from repro.data.querylog import CAT1, CAT2, QueryLogConfig
    from repro.distributed.checkpoint import CheckpointManager
    from repro.index.corpus import CorpusConfig
    from repro.policies import PolicyStore, TabularQPolicy
    from repro.ranking.metrics import relative_delta
    from repro.system import RetrievalSystem, SystemConfig

    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=args.n_docs, vocab_size=args.vocab, seed=0),
        querylog=QueryLogConfig(n_queries=args.n_queries, seed=0),
        block_docs=args.block_docs, p_bins=args.p_bins,
        u_budget=args.u_budget, l1_steps=300,
        backend=args.backend,
    ))
    print(f"[build] {sys_.index.n_docs} docs, {sys_.log.n_queries} queries, "
          f"{sys_.index.n_blocks} blocks ({sys_.build_time:.1f}s)")
    sys_.fit_l1(n_queries=min(192, args.n_queries // 4))
    sys_.fit_state_bins(n_queries=128)
    print(f"[bins] p={sys_.bins.p}")

    # Trained policies are published per category into a PolicyStore —
    # a serving engine subscribed to this store would hot-swap to each
    # new version (the serve-while-training loop, docs/policies.md).
    # Every snapshot must cover every category, so not-yet-trained ones
    # serve the hand-tuned static plan.
    store = PolicyStore(staleness_bound=1)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    out = {}
    trained = sys_.baseline_policies((CAT1, CAT2))
    for cat, name in ((CAT1, "CAT1"), (CAT2, "CAT2")):
        q, hist = sys_.train_policy(cat, iters=args.iters, batch=args.batch,
                                    log_every=max(args.iters // 8, 1))
        mgr.save(cat, {"q": q})
        trained[cat] = TabularQPolicy(q)
        version = store.publish(dict(trained))
        qids = np.where(sys_.log.category == cat)[0][:256]
        res = sys_.evaluate(q, qids, cat)
        out[name] = {
            "delta_u_pct": relative_delta(res["policy_u"], res["baseline_u"]),
            "delta_ncg_pct": relative_delta(res["policy_ncg"], res["baseline_ncg"]),
            "policy_version": version,
        }
        print(f"[{name}] Δu={out[name]['delta_u_pct']:+.1f}%  "
              f"ΔNCG={out[name]['delta_ncg_pct']:+.1f}%  "
              f"(published policy snapshot v{version})")
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(out, indent=1))


def train_lm_cmd(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.distributed.fault_tolerance import (
        FailureInjector, FaultToleranceConfig, run_resilient_loop,
    )
    from repro.launch.steps import build_cell

    cell = build_cell(args.arch, "train_4k", mesh=None, reduced=True)
    rng = np.random.default_rng(0)

    params, opt_state = cell.args[0], cell.args[1]
    def mk(x):
        if hasattr(x, "dtype") and not isinstance(x, jnp.ndarray):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return jnp.zeros(x.shape, x.dtype)
            return jnp.zeros(x.shape, x.dtype)
        return x
    # real init (not zeros) for params
    from repro.configs import get_arch
    from repro.models.transformer import init_params
    cfg = get_arch(args.arch).model_cfg(True)
    params = init_params(jax.random.key(0), cfg)
    opt_state = jax.tree_util.tree_map(mk, opt_state)

    b, s = cell.args[2].shape
    step_jit = jax.jit(cell.fn, donate_argnums=(0, 1))
    losses = []

    def data_for(step: int):
        r = np.random.default_rng(1234 + step)        # stateless, seeded by step
        toks = r.integers(0, cfg.vocab, size=(b, s + 1))
        return (jnp.asarray(toks[:, :-1], jnp.int32),
                jnp.asarray(toks[:, 1:], jnp.int32))

    def step_fn(state, step):
        p, o = state["params"], state["opt"]
        tokens, targets = data_for(step)
        p, o, metrics = step_jit(p, o, tokens, targets)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f}")
        return {"params": p, "opt": o}

    ft = FaultToleranceConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25,
                              async_save=True)
    injector = FailureInjector(fail_at=(args.steps // 2,)) if args.inject_failure else None
    res = run_resilient_loop({"params": params, "opt": opt_state}, step_fn,
                             args.steps, ft, injector=injector)
    print(f"[done] steps={args.steps} restarts={res['restarts']} "
          f"first_loss={losses[0]:.3f} last_loss={losses[-1]:.3f} "
          f"wall={res['wall_s']:.0f}s")
    assert losses[-1] < losses[0], "loss should decrease"


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    p = sub.add_parser("policy")
    p.add_argument("--n-docs", type=int, default=8192)
    p.add_argument("--vocab", type=int, default=2048)
    p.add_argument("--n-queries", type=int, default=2000)
    p.add_argument("--block-docs", type=int, default=256)
    p.add_argument("--p-bins", type=int, default=1024)
    p.add_argument("--u-budget", type=int, default=1024)
    p.add_argument("--iters", type=int, default=200)
    p.add_argument("--batch", type=int, default=48)
    p.add_argument("--ckpt-dir", default="results/ckpt_policy")
    p.add_argument("--out", default="results/train_policy.json")
    p.add_argument("--backend", default="xla",
                   help="index-scan backend for training/eval rollouts "
                        "(see repro.core.scan_backends.available_backends)")
    p.set_defaults(fn=train_policy_cmd)

    p = sub.add_parser("lm")
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--ckpt-dir", default="results/ckpt_lm")
    p.add_argument("--inject-failure", action="store_true")
    p.set_defaults(fn=train_lm_cmd)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
