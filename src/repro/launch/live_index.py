"""Live-index serving driver: serve, mutate, merge — all at once.

Builds a `LiveRetrievalSystem` (tiered live index: mmap base + delta
segments), serves a freshness workload through a `ReplicaSet` while a
`MergeDaemon` compacts delta segments into new base generations in the
background, and checks the subsystem's contracts along the way:

    PYTHONPATH=src python -m repro.launch.live_index --replicas 2 \
        --ticks 6 --backend xla

``--smoke`` is the CI gate (``make index-smoke``): tiny sizes, and hard
assertions that across >= 2 epoch swaps under load (a) every submitted
query completed with a response — zero dropped, zero sheds of any
kind, (b) >= 2 merges ran (new base generations) while serving, (c)
responses span >= 2 distinct index epochs, and (d) the parity harness
is green — the live (base + delta) view is bit-identical to a
from-scratch rebuild at every recorded epoch, on both scan backends.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--ticks", type=int, default=6,
                    help="freshness ticks (each adds docs + an epoch)")
    ap.add_argument("--docs-per-tick", type=int, default=16)
    ap.add_argument("--wave", type=int, default=48,
                    help="queries submitted per tick")
    ap.add_argument("--backend", default="xla",
                    help="index-scan backend for serving rollouts")
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--n-queries", type=int, default=400)
    ap.add_argument("--capacity-mult", type=float, default=2.0,
                    help="index capacity as a multiple of the seed corpus")
    ap.add_argument("--merge-min-docs", type=int, default=24,
                    help="delta docs before the daemon compacts")
    ap.add_argument("--storage-dir", default=None,
                    help="base-generation directory (default: a tempdir; "
                         "generations are mmapped from here)")
    ap.add_argument("--staleness-bound", type=int, default=64)
    ap.add_argument("--parity-queries", type=int, default=6,
                    help="queries sampled per epoch for the parity check")
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--max-bucket", type=int, default=32)
    ap.add_argument("--cache", type=int, default=512)
    ap.add_argument("--out", default="results/live_index.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the run")
    ap.add_argument("--metrics-json", default=None,
                    help="write the merged fleet+index metrics snapshot")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny sizes + zero-dropped + parity "
                         "assertions across >= 2 epoch swaps")
    args = ap.parse_args()

    if args.smoke:
        args.replicas = 2
        args.n_docs, args.n_queries = 2048, 200
        args.ticks, args.docs_per_tick, args.wave = 4, 16, 32
        args.merge_min_docs = 24

    from repro.cluster import ClusterConfig, ReplicaSet, Shed
    from repro.data.freshness import FreshnessConfig, FreshnessWorkload
    from repro.data.querylog import QueryLogConfig
    from repro.index.corpus import CorpusConfig
    from repro.index.live import (LiveRetrievalSystem, MergeConfig,
                                  MergeDaemon, check_epoch_parity)
    from repro.index.live.live_index import MERGE_MS_EDGES
    from repro.obs import NULL_TRACER, Tracer, merge_snapshots
    from repro.policies import PolicyStore
    from repro.serving import EngineConfig
    from repro.system import SystemConfig

    tracer = Tracer() if args.trace_out else NULL_TRACER
    tmp = None
    if args.storage_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="live-index-")
        args.storage_dir = tmp.name

    sys_ = LiveRetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=args.n_docs, vocab_size=1024, seed=0),
        querylog=QueryLogConfig(n_queries=args.n_queries, seed=0),
        block_docs=256, p_bins=512, u_budget=1024,
        l1_steps=80 if args.smoke else 150,
        backend=args.backend,
    ), capacity_docs=int(args.capacity_mult * args.n_docs),
       storage_dir=args.storage_dir,
       staleness_bound=args.staleness_bound, tracer=tracer)
    sys_.fit_l1(n_queries=96)
    sys_.fit_state_bins(n_queries=64)
    live = sys_.live
    print(f"[build] {args.n_docs} docs / {sys_.log.n_queries} queries, "
          f"capacity {live.capacity_docs} docs "
          f"({live.capacity_blocks} blocks), base gen 0 "
          f"{'mmapped' if live.stats()['base_mmapped'] else 'in-memory'} "
          f"({sys_.build_time:.1f}s)")

    # Baseline production-plan policies: this driver exercises the
    # index plane, not training — the plans are fixed, the INDEX moves.
    store = PolicyStore(staleness_bound=1)
    store.publish(sys_.baseline_policies(), fallbacks=sys_.fallback_policies())

    # Record every epoch publish for the post-run parity sweep.
    epochs_seen = []
    unsubscribe = live.store.subscribe(epochs_seen.append)

    workload = FreshnessWorkload(sys_, FreshnessConfig(
        docs_per_tick=args.docs_per_tick, wave_queries=args.wave, seed=0))
    cluster = ReplicaSet(sys_, store,
                         ClusterConfig(n_replicas=args.replicas),
                         EngineConfig(min_bucket=args.min_bucket,
                                      max_bucket=args.max_bucket,
                                      cache_capacity=args.cache,
                                      backend=args.backend),
                         tracer=tracer)
    cluster.warmup()

    results, t0 = [], time.time()
    daemon = MergeDaemon(live, MergeConfig(
        min_delta_docs=args.merge_min_docs, poll_interval_s=0.02))
    with cluster, daemon:
        for tick in range(args.ticks):
            wave = workload.tick()          # docs + queries + epoch commit
            daemon.trigger()
            results.extend(cluster.serve(wave))
        # settle: let the daemon compact the final delta, then serve a
        # last wave against the merged head
        t_settle = time.time()
        while (live.delta_docs >= args.merge_min_docs
               and time.time() - t_settle < 30):
            time.sleep(0.02)
        results.extend(cluster.serve(workload.wave()))
    if daemon.last_error is not None:
        raise daemon.last_error
    wall = time.time() - t0
    unsubscribe()

    stats = cluster.stats()
    istats = live.stats()
    n_shed = sum(isinstance(r, Shed) for r in results)
    resp_epochs = sorted({r.index_epoch for r in results
                          if not isinstance(r, Shed)})
    fresh_hits = [r for r in results if not isinstance(r, Shed)
                  and r.qid >= args.n_queries]

    # Parity sweep: live view vs from-scratch rebuild at every recorded
    # epoch, on both scan backends.
    rng = np.random.default_rng(1)
    parity = []
    for ep in epochs_seen:
        qids = rng.choice(sys_.log.n_queries, size=args.parity_queries,
                          replace=False)
        parity.append(check_epoch_parity(sys_, ep, qids))
    print(f"[parity] {len(parity)} epochs green "
          f"(v{epochs_seen[0].version}..v{epochs_seen[-1].version}, "
          f"both backends)")

    out = {
        "wall_s": wall,
        "qps": len(results) / wall,
        "ticks": workload.ticks,
        "docs_added": istats["docs_added"],
        "commits": istats["commits"],
        "merges": istats["merges"],
        "generation": istats["generation"],
        "epoch_head": istats["epoch"],
        "response_epochs": resp_epochs,
        "epoch_swaps_total": sum(r.engine.summary()["index_epoch_swaps"]
                                 for r in cluster.replicas),
        "n_results": len(results),
        "n_shed": n_shed,
        "n_fresh_responses": len(fresh_hits),
        "merge_ms": live.registry.histogram(
            "index.merge_ms", MERGE_MS_EDGES).snapshot(),
        "bytes_per_query_base": istats["bytes_per_query_base"],
        "bytes_per_query_delta": istats["bytes_per_query_delta"],
        "parity": parity,
        "cluster": stats,
    }
    print(f"[serve] {len(results)} results ({out['qps']:.1f} qps), "
          f"{n_shed} shed, {istats['merges']} merges -> gen "
          f"{istats['generation']}, epochs served {resp_epochs}, "
          f"epoch_lag_max={stats['epoch_lag_observed_max']}")

    if args.smoke:
        assert stats["n_submitted"] == stats["n_responses"] + stats["n_shed"], \
            "dropped queries: submitted != responses + shed"
        assert n_shed == 0 and stats["n_shed"] == 0, \
            f"{n_shed} queries shed while the index mutated (must be zero)"
        assert istats["merges"] >= 2, \
            f"expected >= 2 background merges, got {istats['merges']}"
        assert istats["generation"] >= 2, \
            f"expected >= 2 base generations, got {istats['generation']}"
        assert len(resp_epochs) >= 2, \
            f"responses must span >= 2 index epochs, saw {resp_epochs}"
        assert len(fresh_hits) > 0, \
            "no fresh-query responses: appended queries never served"
        assert all(p["ok"] for p in parity), "parity sweep failed"
        assert istats["base_mmapped"], "merged base generations must mmap"
        print(f"[smoke] OK: zero dropped/shed across "
              f"{len(resp_epochs)} epochs, {istats['merges']} merges, "
              f"parity green at {len(parity)} epochs on both backends")

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(out, indent=1, default=str))

    if args.trace_out:
        tracer.log.write_chrome(args.trace_out, process_name="repro-live-index")
        print(f"[trace] {len(tracer.log)} events -> {args.trace_out}")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(merge_snapshots(
            [cluster.metrics_snapshot(), live.registry.snapshot()]),
            indent=1))
        print(f"[metrics] fleet+index snapshot -> {args.metrics_json}")
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
