"""Serving driver: a thin CLI over `repro.serving.ServeEngine`.

Trains the L0 policies + L1 ranker inline (as the seed driver did),
then streams query batches through the online engine — admission →
result cache → shape-bucketed micro-batching → pre-compiled per-shard
rollout → L1 prune — with latency accounting both in wall time and in
index blocks (u), the unit the paper shows is linear in machine time.

    PYTHONPATH=src python -m repro.launch.serve --batches 4 --batch 64

Output keeps the seed schema (one JSON row per batch with t_inputs_s /
t_serve_s / mean_u / p99_u / qps_host) and adds engine fields
(cache hits, compile counts, latency percentiles) plus a trailing
engine summary at results/serve_summary.json.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--n-queries", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--out", default="results/serve.json")
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--max-bucket", type=int, default=64)
    ap.add_argument("--cache", type=int, default=4096,
                    help="result-cache capacity (0 disables)")
    ap.add_argument("--shards", type=int, default=1,
                    help="logical index shards for scatter-gather serving")
    ap.add_argument("--backend", default="xla",
                    help="rollout backend (see repro.serving.available_backends)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (Perfetto-"
                         "loadable) of the serving run to this path")
    ap.add_argument("--metrics-json", default=None,
                    help="write the engine's metrics-registry snapshot "
                         "to this path")
    args = ap.parse_args()

    from repro.data.querylog import CAT1, CAT2, QueryLogConfig
    from repro.index.corpus import CorpusConfig
    from repro.obs import NULL_TRACER, Tracer
    from repro.serving import EngineConfig, ServeEngine
    from repro.system import RetrievalSystem, SystemConfig

    tracer = Tracer() if args.trace_out else NULL_TRACER

    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=args.n_docs, vocab_size=2048, seed=0),
        querylog=QueryLogConfig(n_queries=args.n_queries, seed=0),
        block_docs=256, p_bins=1024, u_budget=1024, l1_steps=250,
    ))
    sys_.fit_l1(n_queries=128)
    sys_.fit_state_bins(n_queries=96)
    # Trained tabular policies published as snapshot v1 of a PolicyStore;
    # the engine pins the snapshot and would pick up any later publish.
    store = sys_.train_policy_store(cats=(CAT1, CAT2),
                                    iters=args.iters, batch=48)

    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=args.min_bucket, max_bucket=args.max_bucket,
        cache_capacity=args.cache, n_shards=args.shards,
        backend=args.backend), tracer=tracer)
    n_compiles_warm = engine.warmup()
    print(f"warmup: {n_compiles_warm} bucket executables compiled "
          f"(policy snapshot v{engine.policy_version})")

    stats = []
    rng = np.random.default_rng(0)
    for bi in range(args.batches):
        qids = rng.integers(0, sys_.log.n_queries, size=args.batch)
        t0 = time.time()
        rids = [engine.submit(int(q)) for q in qids]
        t_inputs = time.time() - t0          # admission + cache lookups
        t0 = time.time()
        engine.flush()
        t_serve = time.time() - t0
        res = [engine.take_response(r) for r in rids]

        u_all = np.array([r.u for r in res], np.float64)
        lat = np.array([r.latency_s for r in res], np.float64)
        stats.append({
            "batch": bi, "t_inputs_s": t_inputs, "t_serve_s": t_serve,
            "mean_u": float(u_all.mean()),
            "p99_u": float(np.quantile(u_all, 0.99)),
            "qps_host": args.batch / (t_inputs + t_serve),
            # engine-specific fields (new in the serving subsystem)
            "n_cached": sum(r.cached for r in res),
            "latency_p50_ms": float(np.quantile(lat, 0.50)) * 1e3,
            "latency_p99_ms": float(np.quantile(lat, 0.99)) * 1e3,
            "compiles_cum": engine.compile_count,
        })
        print(stats[-1])

    summary = engine.summary()
    print("engine summary:", json.dumps(summary, indent=1))

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(stats, indent=1))
    Path(args.out).with_name("serve_summary.json").write_text(
        json.dumps(summary, indent=1))
    if args.trace_out:
        tracer.log.write_chrome(args.trace_out, process_name="repro-serve")
        print(f"trace: {len(tracer.log)} events -> {args.trace_out} "
              f"(open at ui.perfetto.dev)")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(engine.telemetry.registry.snapshot(),
                                indent=1))
        print(f"metrics: registry snapshot -> {args.metrics_json}")


if __name__ == "__main__":
    main()
