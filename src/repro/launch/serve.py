"""Serving driver: batched query evaluation through the full telescope
(L0 learned match policy → shard merge → L1 rank/prune), with latency
accounting in index blocks (u) — the unit the paper shows is linear in
wall time.

    PYTHONPATH=src python -m repro.launch.serve --batches 4 --batch 64
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=8192)
    ap.add_argument("--n-queries", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--out", default="results/serve.json")
    args = ap.parse_args()

    import jax

    from repro.core.telescope import l1_prune
    from repro.data.querylog import CAT1, CAT2, QueryLogConfig
    from repro.index.corpus import CorpusConfig
    from repro.ranking.metrics import batched_ncg
    from repro.system import RetrievalSystem, SystemConfig

    sys_ = RetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=args.n_docs, vocab_size=2048, seed=0),
        querylog=QueryLogConfig(n_queries=args.n_queries, seed=0),
        block_docs=256, p_bins=1024, u_budget=1024, l1_steps=250,
    ))
    sys_.fit_l1(n_queries=128)
    sys_.fit_state_bins(n_queries=96)
    policies = {}
    for cat in (CAT1, CAT2):
        policies[cat], _ = sys_.train_policy(cat, iters=args.iters, batch=48)

    from repro.core.qlearning import greedy_rollout

    stats = []
    rng = np.random.default_rng(0)
    for bi in range(args.batches):
        qids = rng.integers(0, sys_.log.n_queries, size=args.batch)
        t0 = time.time()
        occ, scores, tp = sys_.batch_inputs(qids)
        t_inputs = time.time() - t0

        # route each query by its category's policy (batch split by cat)
        res = {}
        t0 = time.time()
        for cat in (CAT1, CAT2):
            m = sys_.log.category[qids] == cat
            if not m.any():
                continue
            fin, _ = greedy_rollout(sys_.env_cfg, sys_.qcfg, sys_.ruleset,
                                    sys_.bins, policies[cat],
                                    occ[m], scores[m], tp[m])
            ids, sc = l1_prune(scores[m], fin.cand, keep=100)
            res[cat] = (fin, ids)
        jax.block_until_ready(ids)
        t_serve = time.time() - t0

        u_all = np.concatenate([np.asarray(res[c][0].u) for c in res])
        stats.append({
            "batch": bi, "t_inputs_s": t_inputs, "t_serve_s": t_serve,
            "mean_u": float(u_all.mean()),
            "p99_u": float(np.quantile(u_all, 0.99)),
            "qps_host": args.batch / (t_inputs + t_serve),
        })
        print(stats[-1])

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
