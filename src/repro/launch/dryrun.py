import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) cell on
the production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --all                # 16×16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod    # 2×16×16
    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k

The 512 fake host devices exist ONLY here (the env var above is set
before any jax import, including the repro imports below).  Smoke tests
and benches see 1 device.

Per cell the JSON records:
  - lower/compile wall time
  - compiled.memory_analysis(): per-device argument/output/temp bytes
  - compiled.cost_analysis(): PER-DEVICE post-SPMD flops + bytes accessed
    (calibrated: a 1-device matmul reports global FLOPs exactly; a
    256-device sharded matmul reports the per-shard program — see
    EXPERIMENTS.md §Dry-run)
  - per-type collective bytes parsed from the partitioned HLO
    (result-shape bytes per op, per device)
"""
import argparse
import gc
import json
import re
import time
import traceback
from pathlib import Path

import jax

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective type (result-shape bytes; the
    post-SPMD module is already the per-device program)."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    pat = re.compile(
        r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\("
    )
    for m in pat.finditer(hlo_text):
        ty, op = m.group(1), m.group(2)
        out[op] += _type_bytes(ty)
        counts[op] += 1
    return {"bytes": out, "counts": counts}


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
             reduced: bool = False) -> dict:
    from repro.launch.steps import build_cell

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "devices": int(len(mesh.devices.flatten()))}
    try:
        cell = build_cell(arch_id, shape_name, mesh=mesh, reduced=reduced)
        t0 = time.time()
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        with mesh:
            lowered = jitted.lower(*cell.args)
        rec["t_lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        rec["hlo_chars"] = len(txt)
        rec["ok"] = True
        del compiled, lowered, jitted, cell, txt
        gc.collect()
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def all_cells():
    from repro.configs import list_archs
    return [(a.arch_id, s) for a in list_archs().values() for s in a.shapes]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.launch.mesh import make_production_mesh

    meshes = []
    if args.both_meshes:
        meshes = [(make_production_mesh(multi_pod=False), "pod16x16"),
                  (make_production_mesh(multi_pod=True), "multipod2x16x16")]
    else:
        mp = bool(args.multi_pod)
        meshes = [(make_production_mesh(multi_pod=mp),
                   "multipod2x16x16" if mp else "pod16x16")]

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    outdir = Path(args.out)

    for mesh, mesh_name in meshes:
        for arch_id, shape_name in cells:
            path = outdir / mesh_name / f"{arch_id}__{shape_name}.json"
            path.parent.mkdir(parents=True, exist_ok=True)
            t0 = time.time()
            rec = run_cell(arch_id, shape_name, mesh, mesh_name, args.reduced)
            path.write_text(json.dumps(rec, indent=1))
            status = "OK " if rec.get("ok") else "FAIL"
            print(f"[{status}] {mesh_name:16s} {arch_id:24s} {shape_name:16s} "
                  f"{time.time() - t0:6.1f}s "
                  + (f"peak={rec['memory']['peak_bytes_est']/2**30:.2f}GiB "
                     f"flops/dev={rec['cost']['flops_per_device']:.3g}"
                     if rec.get("ok") else rec.get("error", "")[:120]),
                  flush=True)


if __name__ == "__main__":
    main()
