"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 CPU device
while the dry-run sees 512 fake ones).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = one 256-chip v5e pod; 2×16×16 = two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or fake) devices exist —
    used by multi-device CPU tests."""
    return jax.make_mesh((data, model), ("data", "model"))
