"""Online-learning cluster driver: trainer-fed replica set CLI.

Builds the retrieval system, starts a `TrainerLoop` that trains from
the cluster's served-traffic tap and publishes policy snapshots (live
+ SHALLOW fallbacks) into a shared `PolicyStore`, and serves a random
query stream through a `ReplicaSet` (queue-aware routing + the
pressure-tiered admission ladder) while training runs — the paper's
serve-while-training deployment in one process.

    PYTHONPATH=src python -m repro.launch.cluster --replicas 2 \
        --publish-every 10 --backend xla

``--smoke`` is the CI gate: tiny corpus, 2 replicas, 2 publish cycles,
a hard assertion that every submitted query completed with either a
response or an explicit Shed (zero dropped), that the trainer consumed
ONLY the served-traffic tap, and — under a moderate burst against a
finite u budget — that the ladder degraded (some SHALLOW) without a
single hard SHED.

``--replica-backend process --smoke`` is the process-cell gate
(``make proc-smoke``): a LIVE system serves through worker processes
while documents commit (two index epochs) and the trainer publishes
(three policy versions) mid-stream; asserts zero dropped tickets, that
every worker applied >= 3 policy versions and >= 2 index epochs (via
its control-channel acks), and — from /proc/<pid>/smaps — that the
workers' index mappings hold ZERO private-dirty pages, i.e. the fleet
shares ONE physical copy of the base generation.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def _cell_mapping_stats(pids, cell_root: str) -> dict:
    """Per-worker Rss/Pss/Private_Dirty (kB) of every mapping under the
    process cell's storage dir, straight from /proc/<pid>/smaps."""
    per_worker = []
    for pid in pids:
        rss = pss = private = 0
        n_maps = 0
        in_cell = False
        try:
            with open(f"/proc/{pid}/smaps") as fh:
                for line in fh:
                    fields = line.split()
                    if line[0] != ' ' and '-' in fields[0]:  # mapping header
                        in_cell = len(fields) >= 6 and \
                            fields[-1].startswith(cell_root)
                        n_maps += in_cell
                    elif in_cell and fields[0] in ("Rss:", "Pss:",
                                                   "Private_Dirty:"):
                        kb = int(fields[1])
                        if fields[0] == "Rss:":
                            rss += kb
                        elif fields[0] == "Pss:":
                            pss += kb
                        else:
                            private += kb
        except OSError:
            continue
        per_worker.append({"pid": pid, "n_mappings": n_maps,
                           "rss_kb": rss, "pss_kb": pss,
                           "private_dirty_kb": private})
    return {"workers": per_worker,
            "rss_kb_total": sum(w["rss_kb"] for w in per_worker),
            "pss_kb_total": sum(w["pss_kb"] for w in per_worker),
            "private_dirty_kb_total": sum(w["private_dirty_kb"]
                                          for w in per_worker)}


def _rand_doc(rng, vocab: int):
    return [np.unique(rng.integers(0, vocab, size=k)).astype(np.int32)
            for k in (1, 2, 8, 3)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--publish-every", type=int, default=10,
                    help="training epochs between snapshot publishes")
    ap.add_argument("--iters", type=int, default=30,
                    help="total training epochs")
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--backend", default="xla",
                    help="index-scan backend (training AND serving)")
    ap.add_argument("--replica-backend", default="thread",
                    choices=["thread", "process"],
                    help="replica execution: in-process threads (default) "
                         "or worker processes over shm rings + one mmap-"
                         "shared index (docs/cluster.md)")
    ap.add_argument("--routing", default="queue_aware",
                    choices=["queue_aware", "round_robin"])
    ap.add_argument("--staleness-bound", type=int, default=2)
    ap.add_argument("--u-budget-inflight", type=float, default=float("inf"),
                    help="fleet admission budget in u (inf disables "
                         "degradation/shedding)")
    ap.add_argument("--no-ladder", action="store_true",
                    help="binary admit/shed instead of the FULL/SHALLOW/"
                         "CACHED_ONLY/SHED service ladder")
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--n-queries", type=int, default=400)
    ap.add_argument("--batch", type=int, default=24,
                    help="queries per serving wave")
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--max-bucket", type=int, default=32)
    ap.add_argument("--cache", type=int, default=512)
    ap.add_argument("--out", default="results/cluster.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (Perfetto-"
                         "loadable) of the whole run to this path")
    ap.add_argument("--metrics-json", default=None,
                    help="write the merged fleet metrics snapshot "
                         "(counters/gauges/per-(level,category) "
                         "histograms) to this path")
    ap.add_argument("--statusz-out", default=None,
                    help="write the cell's statusz introspection JSON "
                         "(head versions, per-worker health/watchdog "
                         "verdicts, ring stats) to this path")
    ap.add_argument("--slo-target", type=float, default=None,
                    help="enable the read-only SLO burn-rate monitor at "
                         "this availability target (e.g. 0.999); the "
                         "verdict lands in the output JSON under 'slo'")
    ap.add_argument("--slo-latency-ms", type=float, default=50.0,
                    help="latency threshold for the SLO's goodness "
                         "criterion (snapped up to a histogram edge)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny sizes + zero-dropped assertion")
    args = ap.parse_args()

    proc = args.replica_backend == "process"
    if args.smoke:
        args.replicas = 2
        args.n_docs, args.n_queries = 2048, 200
        args.iters, args.publish_every = 8, 4      # exactly 2 publish cycles
        args.train_batch, args.batch = 16, 16
        if proc:
            # the process gate also exercises index-epoch relays, so it
            # trims sizes further — worker spawn + JIT dominate on CI
            args.n_docs, args.n_queries = 1024, 128

    from repro.cluster import (ClusterConfig, ReplicaSet, ServiceLevel, Shed,
                               TrainerConfig, TrainerLoop)
    from repro.data.querylog import CAT1, CAT2, QueryLogConfig
    from repro.index.corpus import CorpusConfig
    from repro.obs import NULL_TRACER, SLOConfig, SLOMonitor, Tracer
    from repro.policies import PolicyStore
    from repro.serving import EngineConfig
    from repro.system import RetrievalSystem, SystemConfig

    tracer = Tracer() if args.trace_out else NULL_TRACER

    sys_cfg = SystemConfig(
        corpus=CorpusConfig(n_docs=args.n_docs, vocab_size=1024, seed=0),
        querylog=QueryLogConfig(n_queries=args.n_queries, seed=0),
        block_docs=256, p_bins=512, u_budget=1024,
        l1_steps=150 if not args.smoke else 80,
        backend=args.backend,
    )
    if proc:
        # live system so the smoke can commit documents mid-stream and
        # prove epoch relays land inside the worker processes
        from repro.index.live import LiveRetrievalSystem
        sys_ = LiveRetrievalSystem(sys_cfg,
                                   capacity_docs=args.n_docs + 512)
    else:
        sys_ = RetrievalSystem(sys_cfg)
    sys_.fit_l1(n_queries=96)
    sys_.fit_state_bins(n_queries=64)
    print(f"[build] {sys_.index.n_docs} docs / {sys_.log.n_queries} queries "
          f"/ {sys_.index.n_blocks} blocks ({sys_.build_time:.1f}s)")

    shallow_caps = {cat: sys_.shallow_u_cap(cat) for cat in (CAT1, CAT2)}
    store = PolicyStore(staleness_bound=args.staleness_bound)
    trainer = TrainerLoop(sys_, store, cfg=TrainerConfig(
        iters=args.iters, publish_every=args.publish_every,
        batch=args.train_batch, publish_initial=False,
        # promotion gate probes a held-out slice of served traffic once
        # the tap holdout fills (falls back to the log slice before)
        probe_from_tap=True), tracer=tracer)
    trainer.publish_now()                 # v1 up before replicas construct
    cluster = ReplicaSet(sys_, store, ClusterConfig(
        n_replicas=args.replicas, routing=args.routing,
        backend=args.replica_backend,
        u_inflight_budget=args.u_budget_inflight,
        ladder=not args.no_ladder,
        tap_holdout_every=4,              # eval holdout for the gate
        # keep the cold SHALLOW estimate inside its provable cap, so a
        # degraded admission can never be priced above what it can cost
        prior_shallow_u=float(min(shallow_caps.values()))),
        EngineConfig(min_bucket=args.min_bucket, max_bucket=args.max_bucket,
                     cache_capacity=args.cache, backend=args.backend),
        tracer=tracer)
    trainer.source = cluster.tap          # train on served traffic only
    cluster.warmup()

    slo_mon = None
    if args.slo_target is not None:
        # Read-only: observes fleet snapshots between waves, publishes
        # slo.* gauges into the cluster registry, never touches admission.
        slo_mon = SLOMonitor(
            SLOConfig(target=args.slo_target,
                      latency_slo_ms=args.slo_latency_ms),
            registry=cluster.registry)

    rng = np.random.default_rng(0)
    results, t0 = [], time.time()
    burst_results, burst_tickets = [], []
    with cluster:
        trainer.start()
        waves = 0
        while trainer.alive or waves < (3 if proc else 1):
            qids = rng.integers(0, sys_.log.n_queries, size=args.batch)
            results.extend(cluster.serve(qids))
            waves += 1
            if slo_mon is not None:
                slo_mon.observe(cluster.metrics_snapshot())
            if proc and waves in (1, 2):
                # two commits mid-stream -> two index epochs the cell
                # must relay into every worker over its control pipe
                sys_.add_documents([_rand_doc(rng, 1024) for _ in range(4)])
                sys_.commit_index()
        trainer.join()
        # final wave on the last published version (and, on the process
        # backend, the last committed epoch)
        results.extend(cluster.serve(
            rng.integers(0, sys_.log.n_queries, size=args.batch)))
        waves += 1
        if slo_mon is not None:
            slo_mon.observe(cluster.metrics_snapshot())

        if args.statusz_out:
            # Must be written while workers are alive — statusz reads
            # ring-header heartbeats and process liveness.
            p = Path(args.statusz_out)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(cluster.statusz(), indent=1,
                                    default=str))
            print(f"[statusz] cell status -> {args.statusz_out}")

        proc_stats = None
        if proc:
            import os

            # relays are async — wait for every worker to ack the head
            # epoch and policy version before asserting on them
            head_epoch = sys_.index_epoch
            head_version = store.version
            deadline = time.time() + 60.0
            while time.time() < deadline:
                st = cluster.stats()
                lag = cluster.version_lag()
                if (min(st["replica_index_epochs"]) >= head_epoch
                        and min(lag["replica_versions"]) >= head_version):
                    break
                time.sleep(0.1)
            summaries = cluster.stats()["replicas"]
            worker_pids = [s["worker_pid"] for s in summaries]
            proc_stats = {
                "n_cpus": os.cpu_count(),
                "worker_pids": worker_pids,
                "worker_restarts": [s["n_restarts"] for s in summaries],
                "head_index_epoch": head_epoch,
                "replica_index_epochs":
                    cluster.stats()["replica_index_epochs"],
                "head_policy_version": head_version,
                "replica_policy_versions":
                    cluster.version_lag()["replica_versions"],
                "cell_dir": cluster.proc_cell_dir,
                "mappings": _cell_mapping_stats(worker_pids,
                                                cluster.proc_cell_dir),
            }

        if args.smoke and not args.no_ladder and not proc:
            # Moderate burst against a finite budget: size the ledger
            # so the FULL rung saturates after a few queries while the
            # SHALLOW rung provably fits the whole burst — the ladder
            # must absorb the pressure with degraded service, zero
            # hard SHEDs.
            burst = 48
            cap = max(shallow_caps.values())
            burst_qids = rng.integers(0, sys_.log.n_queries, size=burst)
            est = cluster.admission.estimator
            est_med = float(np.median([est.estimate(int(q))
                                       for q in burst_qids]))
            budget = max(3 * est_med, sys_.cfg.u_budget) + (burst + 1) * cap
            cluster.admission.u_inflight_budget = budget
            cluster.admission.full_watermark = \
                min(0.5, max(3 * est_med, sys_.cfg.u_budget) / budget)
            burst_tickets = [cluster.submit(int(q)) for q in burst_qids]
            burst_results = [t.result(timeout=120.0) for t in burst_tickets]
    wall = time.time() - t0

    stats = cluster.stats()
    n_shed = sum(isinstance(r, Shed) for r in results)
    out = {
        "waves": waves,
        "wall_s": wall,
        "qps": len(results) / wall,
        "versions_published": trainer.versions_published,
        "probe_recall_per_version": [row["probe_recall"]
                                     for row in trainer.history],
        "probe_source_per_version": [row["probe_source"]
                                     for row in trainer.history],
        "n_results": len(results),
        "n_shed": n_shed,
        "trainer_tap_batches": trainer.tap_batches,
        "trainer_log_batches": trainer.log_batches,
        "cluster": stats,
    }
    if proc_stats is not None:
        out["proc"] = proc_stats
    if slo_mon is not None:
        out["slo"] = slo_mon.check()
        print(f"[slo] verdict={out['slo']['verdict']} "
              f"burn_fast={out['slo']['burn_fast']:.2f} "
              f"burn_slow={out['slo']['burn_slow']:.2f} "
              f"(target {args.slo_target}, latency <= "
              f"{out['slo']['effective_latency_slo_ms']:g} ms)")
    print(f"[serve] {len(results)} results over {waves} waves "
          f"({out['qps']:.1f} qps), {n_shed} shed, "
          f"versions {trainer.versions_published}, "
          f"version_lag_max={stats['version_lag_observed_max']}, "
          f"tap_batches={trainer.tap_batches}")

    if args.smoke:
        assert len(trainer.versions_published) >= 3, \
            f"expected >= 3 publishes (v1 + 2 cycles), got {trainer.versions_published}"
        assert stats["n_submitted"] == stats["n_responses"] + stats["n_shed"], \
            "dropped queries: submitted != responses + shed"
        assert len(results) + len(burst_results) == stats["n_submitted"], \
            "lost tickets"
        assert stats["version_lag_observed_max"] <= args.staleness_bound, \
            "served a snapshot beyond the staleness bound"
        # the trainer consumed the served-traffic tap, never the log
        assert trainer.tap_batches > 0 and trainer.log_batches == 0, \
            (f"trainer must train from served traffic only "
             f"(tap={trainer.tap_batches}, log={trainer.log_batches})")
        if not args.no_ladder and not proc:
            # graceful degradation under the burst: zero hard SHEDs,
            # pressure visibly absorbed by the SHALLOW rung
            hard_sheds = [r for r in burst_results if isinstance(r, Shed)]
            assert not hard_sheds, \
                f"ladder hard-shed under a moderate burst: {hard_sheds[:3]}"
            mix = {l.name: sum(t.level == l for t in burst_tickets)
                   for l in ServiceLevel}
            out["burst_mix"] = mix
            assert mix["SHALLOW"] > 0, f"expected SHALLOW under burst: {mix}"
            print(f"[smoke] burst mix {mix} (zero hard sheds)")
        if proc:
            ps = out["proc"]
            # >= 3 policy versions applied IN the workers (relayed over
            # the control pipe, acked back)
            assert min(ps["replica_policy_versions"]) >= 3, \
                f"workers behind on policy: {ps['replica_policy_versions']}"
            # >= 2 index epochs beyond the initial one (two mid-stream
            # commits), every worker at the head
            assert ps["head_index_epoch"] >= 3, ps["head_index_epoch"]
            assert min(ps["replica_index_epochs"]) >= \
                ps["head_index_epoch"], \
                f"workers behind on epochs: {ps['replica_index_epochs']}"
            import os
            assert len(set(ps["worker_pids"])) == args.replicas and \
                os.getpid() not in ps["worker_pids"], \
                f"expected {args.replicas} distinct worker processes"
            # a crash+respawn mid-run is recovery working, but the gate
            # demands a clean run — worker deaths here are real bugs
            assert sum(ps["worker_restarts"]) == 0, \
                f"workers died during smoke: {ps['worker_restarts']}"
            # single-mapping proof: every worker mmaps the cell's base
            # generation, and across the fleet those mappings hold ZERO
            # private-dirty pages — nobody copied the index, the page
            # cache holds one physical copy (sum Pss << sum Rss)
            maps = ps["mappings"]
            assert all(w["n_mappings"] > 0 and w["rss_kb"] > 0
                       for w in maps["workers"]), maps
            assert maps["private_dirty_kb_total"] == 0, \
                (f"workers hold private copies of the index: "
                 f"{maps['private_dirty_kb_total']} kB private-dirty")
            # Pss divides each page by its mapper count, so N workers
            # over one physical copy show sum(Pss) ~ sum(Rss)/N
            assert maps["pss_kb_total"] <= 0.75 * maps["rss_kb_total"], \
                f"index pages not physically shared: {maps}"
            print(f"[smoke] proc cell OK: versions "
                  f"{ps['replica_policy_versions']}, epochs "
                  f"{ps['replica_index_epochs']} (head "
                  f"{ps['head_index_epoch']}), index mappings "
                  f"rss={maps['rss_kb_total']}kB "
                  f"pss={maps['pss_kb_total']}kB private_dirty=0 "
                  f"across {len(maps['workers'])} workers "
                  f"({ps['n_cpus']} cpus)")
        print("[smoke] OK: zero dropped non-shed queries, "
              f"{len(trainer.versions_published)} versions trained from "
              f"the served tap, lag <= {args.staleness_bound}")

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(out, indent=1, default=str))

    if args.trace_out:
        # Merged fleet timeline: parent spans + every worker's rebased
        # tail (process backend) in one Perfetto-loadable file.
        n_entries = cluster.write_trace(args.trace_out,
                                        process_name="repro-cluster")
        print(f"[trace] {n_entries} entries -> {args.trace_out} "
              f"(open at ui.perfetto.dev)")
    if args.metrics_json:
        p = Path(args.metrics_json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(cluster.metrics_snapshot(), indent=1))
        print(f"[metrics] fleet snapshot -> {args.metrics_json}")


if __name__ == "__main__":
    main()
