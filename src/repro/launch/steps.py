"""Per-cell step builders: for every (arch × shape) pair, produce the
function the dry-run lowers plus its abstract inputs and shardings.

This is the single source of truth shared by launch/dryrun.py (lower +
compile on the production mesh), launch/train.py / serve.py (real
execution), and the per-arch smoke tests (reduced configs, 1 device).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchDef, get_arch
from repro.distributed.sharding_rules import (
    data_axes, gnn_param_specs, kv_cache_specs, lm_param_specs,
    recsys_param_specs, spec_tree, zero1_state_specs,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm

__all__ = ["CellSpec", "build_cell", "REDUCED_SHAPES"]


@dataclasses.dataclass
class CellSpec:
    arch_id: str
    shape_name: str
    fn: Callable                     # the step to lower / run
    args: Tuple[Any, ...]            # ShapeDtypeStructs (dry-run) or arrays
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_note: str = ""


# Reduced per-kind shapes used by smoke tests (CPU, 1 device).
REDUCED_SHAPES = {
    "train": dict(global_batch=4, seq_len=64),
    "prefill": dict(global_batch=2, seq_len=64),
    "decode": dict(global_batch=4, seq_len=64),
    "train_graph": dict(n_nodes=128, n_edges=512, d_feat=16, n_classes=7),
    "train_minibatch": dict(n_nodes=256, n_edges=2048, batch_nodes=16,
                            fanout=(5, 3), d_feat=16, n_classes=7),
    "train_batched_graphs": dict(n_nodes=10, n_edges=20, batch=8, d_feat=16,
                                 n_classes=2),
    "train_recsys": dict(batch=64),
    "serve": dict(batch=32),
    "retrieval": dict(batch=1, n_candidates=2048),
    "serve_websearch": dict(query_batch=8),
    "train_websearch": dict(query_batch=8),
}


def _sd(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec_tree_):
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree_,
        is_leaf=lambda x: isinstance(x, P),
    )


def _dp(mesh) -> Tuple[str, ...]:
    return data_axes(mesh) if mesh is not None else ()


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp(mesh)])) if mesh else 1


# ======================================================================== LM
def _lm_opt_cfg(reduced: bool) -> AdamWConfig:
    # bf16 moments halve optimizer HBM on the big configs (grok-1 fits).
    return AdamWConfig(lr=1e-4, weight_decay=0.01,
                       state_dtype=jnp.float32 if reduced else jnp.bfloat16)


def make_lm_train_step(cfg, mesh, opt_cfg: AdamWConfig, param_specs=None):
    """Loss + grads (+ optional gradient-accumulation microbatching) +
    clip + AdamW.  Microbatching divides activation memory by `mb`
    (measured 3x on starcoder2 train_4k); the accumulator is explicitly
    constrained to the parameter sharding — without the constraint GSPMD
    replicates FSDP expert grads over `data` (37 GiB/device on grok-1;
    EXPERIMENTS.md §Perf)."""
    from repro.models.transformer import lm_loss

    mb = max(1, cfg.microbatch)

    def constrain(tree):
        # Only FSDP configs need the explicit accumulator constraint; for
        # TP-only params GSPMD already picks the param sharding, and the
        # constraint forces extra resharding copies (deepseek: +6 GiB).
        if mesh is None or param_specs is None or not getattr(cfg, "fsdp", False):
            return tree
        return jax.tree_util.tree_map(
            lambda t, sp: jax.lax.with_sharding_constraint(
                t, jax.sharding.NamedSharding(mesh, sp)),
            tree, param_specs)

    def loss_fn(p, tokens, targets):
        return lm_loss(p, tokens, targets, cfg, mesh)

    def train_step(params, opt_state, tokens, targets):
        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets)
            grads = constrain(grads)
        else:
            b = tokens.shape[0] // mb
            tk = tokens.reshape(mb, b, -1)
            tg = targets.reshape(mb, b, -1)

            acc_dt = getattr(cfg, "grad_accum_dtype", jnp.float32)

            def mb_step(carry, xs):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, xs[0], xs[1])
                gacc = jax.tree_util.tree_map(
                    lambda a, c: (a.astype(jnp.float32) + c.astype(jnp.float32)).astype(acc_dt),
                    gacc, g)
                return (constrain(gacc), lacc + l), None

            zero = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (grads, loss), _ = jax.lax.scan(mb_step, (zero, jnp.float32(0.0)), (tk, tg))
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _build_lm(arch: ArchDef, shape_name: str, mesh, reduced: bool) -> CellSpec:
    from repro.models.transformer import (
        decode_step, init_kv_cache, init_params, prefill,
    )

    cfg = arch.model_cfg(reduced)
    spec = arch.shape(shape_name)
    sp = dict(REDUCED_SHAPES[spec.kind]) if reduced else dict(spec.params)
    b, s = sp["global_batch"], sp["seq_len"]
    dp = _dp(mesh)
    msize = mesh.shape["model"] if mesh else None

    params_abs = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    p_specs = lm_param_specs(params_abs, msize, getattr(cfg, "fsdp", False),
                             getattr(cfg, "zero3", False))

    if spec.kind == "train":
        opt_cfg = _lm_opt_cfg(reduced)
        opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), params_abs)
        # moments shard like params + ZeRO-1 over `data`
        mom_specs = (zero1_state_specs(params_abs, p_specs, mesh)
                     if mesh is not None else jax.tree_util.tree_map(lambda _: P(), params_abs))
        o_specs = {"mu": mom_specs, "nu": mom_specs, "count": P()}
        tok_axes = (dp + ("model",)) if getattr(cfg, "zero3", False) and dp else dp
        tok_spec = P(tok_axes if tok_axes else None, None)

        fn = make_lm_train_step(cfg, mesh, opt_cfg, param_specs=p_specs)
        args = (params_abs, opt_abs,
                _sd((b, s), jnp.int32), _sd((b, s), jnp.int32))
        in_sh = (_named(mesh, p_specs), _named(mesh, o_specs),
                 _named(mesh, tok_spec), _named(mesh, tok_spec))
        out_sh = (_named(mesh, p_specs), _named(mesh, o_specs),
                  _named(mesh, {"loss": P(), "grad_norm": P()}))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, out_sh,
                        donate_argnums=(0, 1))

    if spec.kind == "prefill":
        fn = lambda params, tokens: prefill(params, tokens, cfg, mesh)
        cache_abs = jax.eval_shape(lambda: init_kv_cache(cfg, b, s))
        c_specs = kv_cache_specs(cache_abs, mesh) if mesh else None
        args = (params_abs, _sd((b, s), jnp.int32))
        in_sh = (_named(mesh, p_specs), _named(mesh, P(dp if dp else None, None)))
        out_sh = ((_named(mesh, P(dp if dp else None, "model")), _named(mesh, c_specs))
                  if mesh else None)
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, out_sh)

    # decode (decode_32k / long_500k): one new token against an S-token cache
    fn = lambda params, token, cache, pos: decode_step(params, token, cache, pos, cfg, mesh)
    cache_abs = jax.eval_shape(lambda: init_kv_cache(cfg, b, s))
    c_specs = kv_cache_specs(cache_abs, mesh) if mesh else None
    bspec = P(dp) if (mesh and b % _dp_size(mesh) == 0 and b >= _dp_size(mesh)) else P()
    args = (params_abs, _sd((b,), jnp.int32), cache_abs, _sd((b,), jnp.int32))
    in_sh = (_named(mesh, p_specs), _named(mesh, bspec), _named(mesh, c_specs),
             _named(mesh, bspec))
    out_sh = ((_named(mesh, P(bspec[0] if bspec else None, "model")),
               _named(mesh, c_specs)) if mesh else None)
    return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, out_sh,
                    donate_argnums=(2,))


# ======================================================================= GNN
def _build_gnn(arch: ArchDef, shape_name: str, mesh, reduced: bool) -> CellSpec:
    from repro.models.gnn import (
        SAGEConfig, sage_block_forward, sage_full_forward, sage_graph_forward,
        sage_init,
    )
    from repro.models.layers import dense_init

    spec = arch.shape(shape_name)
    sp = dict(REDUCED_SHAPES[spec.kind]) if reduced else dict(spec.params)
    base = arch.model_cfg(reduced)
    cfg = SAGEConfig(d_in=sp["d_feat"], d_hidden=base.d_hidden,
                     n_classes=sp["n_classes"], n_layers=base.n_layers,
                     aggregator=base.aggregator)
    all_axes = (_dp(mesh) + ("model",)) if mesh else ()
    n_dev = (int(np.prod(list(mesh.shape.values()))) if mesh else 1)
    opt_cfg = AdamWConfig(lr=1e-3)

    def ce_loss(logits, labels, mask):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        gold = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
        return -jnp.sum(gold * mask) / jnp.maximum(mask.sum(), 1.0)

    p_abs = jax.eval_shape(lambda: sage_init(jax.random.key(0), cfg))
    p_specs = gnn_param_specs(p_abs, mesh.shape["model"] if mesh else None)
    edge_spec = P(None, all_axes if all_axes else None)

    def pad_e(e: int) -> int:
        return ((e + n_dev - 1) // n_dev) * n_dev

    if spec.kind in ("train_graph", "train_minibatch", "train_batched_graphs"):
        if spec.kind == "train_graph":
            n, e = sp["n_nodes"], pad_e(sp["n_edges"])

            def fn(params, opt_state, feats, edges, labels, mask):
                def loss_fn(p):
                    logits = sage_full_forward(p, cfg, feats, edges)
                    return ce_loss(logits, labels, mask)
                loss, grads = jax.value_and_grad(loss_fn)(params)
                params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
                return params, opt_state, loss

            opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_abs)
            args = (p_abs, opt_abs, _sd((n, sp["d_feat"]), jnp.float32),
                    _sd((2, e), jnp.int32), _sd((n,), jnp.int32),
                    _sd((n,), jnp.float32))
            in_sh = (_named(mesh, p_specs), _named(mesh, jax.tree_util.tree_map(lambda _: P(), opt_abs)),
                     _named(mesh, P()), _named(mesh, edge_spec),
                     _named(mesh, P()), _named(mesh, P()))
            out_sh = None
            return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, out_sh,
                            donate_argnums=(0, 1))

        if spec.kind == "train_minibatch":
            bn = sp["batch_nodes"]
            f_out, f_in = sp["fanout"]          # e.g. (15, 10): inner, outer
            # fixed frontier/edge budgets (sampler pads up to these)
            e1 = bn * f_in
            fr1 = bn + e1
            e0 = fr1 * f_out
            fr0 = fr1 + e0

            def fn(params, opt_state, feats, src0, dst0, src1, dst1, labels):
                blocks = [(src0, dst0, fr1), (src1, dst1, bn)]

                def loss_fn(p):
                    logits = sage_block_forward(p, cfg, feats, blocks)
                    return ce_loss(logits, labels, jnp.ones((bn,), jnp.float32))
                loss, grads = jax.value_and_grad(loss_fn)(params)
                params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
                return params, opt_state, loss

            opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_abs)
            e0p, e1p = pad_e(e0), pad_e(e1)
            args = (p_abs, opt_abs, _sd((fr0, sp["d_feat"]), jnp.float32),
                    _sd((e0p,), jnp.int32), _sd((e0p,), jnp.int32),
                    _sd((e1p,), jnp.int32), _sd((e1p,), jnp.int32),
                    _sd((bn,), jnp.int32))
            evec = P(all_axes if all_axes else None)
            in_sh = (_named(mesh, p_specs),
                     _named(mesh, jax.tree_util.tree_map(lambda _: P(), opt_abs)),
                     _named(mesh, P()), _named(mesh, evec), _named(mesh, evec),
                     _named(mesh, evec), _named(mesh, evec), _named(mesh, P()))
            return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None,
                            donate_argnums=(0, 1))

        # train_batched_graphs (molecule)
        bsz, npg, epg = sp["batch"], sp["n_nodes"], sp["n_edges"]
        n, e = bsz * npg, pad_e(bsz * epg)
        readout_abs = jax.eval_shape(lambda: {
            "w": dense_init(jax.random.key(1), (cfg.n_classes, sp["n_classes"])),
            "b": jnp.zeros((sp["n_classes"],)),
        })

        def fn(params, readout, opt_state, feats, edges, graph_id, labels):
            def loss_fn(pr):
                p, r = pr
                logits = sage_graph_forward(p, cfg, feats, edges, graph_id, bsz, r)
                return ce_loss(logits, labels, jnp.ones((bsz,), jnp.float32))
            loss, grads = jax.value_and_grad(loss_fn)((params, readout))
            (params, readout), opt_state = adamw_update(
                (params, readout), grads, opt_state, opt_cfg)
            return params, readout, opt_state, loss

        opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), (p_abs, readout_abs))
        args = (p_abs, readout_abs, opt_abs, _sd((n, sp["d_feat"]), jnp.float32),
                _sd((2, e), jnp.int32), _sd((n,), jnp.int32), _sd((bsz,), jnp.int32))
        in_sh = (_named(mesh, p_specs), _named(mesh, jax.tree_util.tree_map(lambda _: P(), readout_abs)),
                 _named(mesh, jax.tree_util.tree_map(lambda _: P(), opt_abs)),
                 _named(mesh, P()), _named(mesh, edge_spec), _named(mesh, P()),
                 _named(mesh, P()))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None,
                        donate_argnums=(0, 1, 2))

    raise ValueError(spec.kind)


# ==================================================================== recsys
def _build_recsys(arch: ArchDef, shape_name: str, mesh, reduced: bool) -> CellSpec:
    from repro.models import recsys as R

    spec = arch.shape(shape_name)
    kind = "train_recsys" if spec.kind == "train" else spec.kind
    sp = dict(REDUCED_SHAPES[kind]) if reduced else dict(spec.params)
    cfg = arch.model_cfg(reduced)
    dp = _dp(mesh)
    bspec = P(dp if dp else None, None)
    opt_cfg = AdamWConfig(lr=1e-3)

    is_b4r = arch.arch_id == "bert4rec"

    if is_b4r:
        p_abs = jax.eval_shape(lambda: R.bert4rec_init(jax.random.key(0), cfg))
    else:
        init = {"wide-deep": R.wide_deep_init, "deepfm": R.deepfm_init,
                "dcn-v2": R.dcn_init}[arch.arch_id]
        p_abs = jax.eval_shape(lambda: init(jax.random.key(0), cfg))
    p_specs = recsys_param_specs(p_abs, mesh.shape["model"] if mesh else None)

    def ctr_forward(params, sparse, dense):
        if arch.arch_id == "wide-deep":
            return R.wide_deep_forward(params, sparse, cfg, dense, mesh=mesh)
        if arch.arch_id == "deepfm":
            return R.deepfm_forward(params, sparse, cfg, mesh=mesh)
        return R.dcn_forward(params, sparse, cfg, dense, mesh=mesh)

    n_dense = getattr(cfg, "n_dense", 0)

    if spec.kind == "train":
        if is_b4r:
            b, s = sp["batch"], cfg.seq_len
            n_mask, n_neg = 16, 256

            def fn(params, opt_state, seq, mask_pos, mask_tgt, negs):
                def loss_fn(p):
                    h = R.bert4rec_forward(p, seq, cfg, mesh=mesh)
                    hm = jnp.take_along_axis(
                        h, mask_pos[..., None], axis=1)          # (B, M, E)
                    emb = p["item_embed"]
                    pos_e = jnp.take(emb, mask_tgt, axis=0)       # (B, M, E)
                    neg_e = jnp.take(emb, negs, axis=0)           # (B, N, E)
                    pos_s = jnp.sum(hm * pos_e, -1)               # (B, M)
                    neg_s = jnp.einsum("bme,bne->bmn", hm, neg_e)
                    # sampled softmax
                    alls = jnp.concatenate([pos_s[..., None], neg_s], -1)
                    return -jnp.mean(jax.nn.log_softmax(alls.astype(jnp.float32))[..., 0])
                loss, grads = jax.value_and_grad(loss_fn)(params)
                params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
                return params, opt_state, loss

            opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_abs)
            o_specs = {"mu": p_specs, "nu": p_specs, "count": P()}
            args = (p_abs, opt_abs, _sd((b, s), jnp.int32),
                    _sd((b, n_mask), jnp.int32), _sd((b, n_mask), jnp.int32),
                    _sd((b, n_neg), jnp.int32))
            in_sh = (_named(mesh, p_specs), _named(mesh, o_specs),
                     _named(mesh, bspec), _named(mesh, bspec),
                     _named(mesh, bspec), _named(mesh, bspec))
            return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None,
                            donate_argnums=(0, 1))

        b = sp["batch"]

        def fn(params, opt_state, sparse, dense, labels):
            def loss_fn(p):
                return R.bce_loss(ctr_forward(p, sparse, dense), labels)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        opt_abs = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_abs)
        o_specs = {"mu": p_specs, "nu": p_specs, "count": P()}
        args = (p_abs, opt_abs, _sd((b, cfg.n_sparse), jnp.int32),
                _sd((b, max(n_dense, 1)), jnp.float32), _sd((b,), jnp.float32))
        in_sh = (_named(mesh, p_specs), _named(mesh, o_specs), _named(mesh, bspec),
                 _named(mesh, bspec), _named(mesh, P(dp if dp else None)))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None,
                        donate_argnums=(0, 1))

    if spec.kind == "serve":
        b = sp["batch"]
        if is_b4r:
            def fn(params, seq):
                h = R.bert4rec_forward(params, seq, cfg, mesh=mesh)
                user = h[:, -1]                                    # (B, E)
                # chunked top-k over the (sharded) item table
                chunk = max(1, min(1024, b))
                nb = b // chunk
                uc = user[: nb * chunk].reshape(nb, chunk, -1)

                def score_chunk(carry, u):
                    sc = u @ params["item_embed"][: cfg.n_items].T
                    v, i = jax.lax.top_k(sc, 100)
                    return carry, (v, i)

                _, (v, i) = jax.lax.scan(score_chunk, 0, uc)
                return v.reshape(nb * chunk, 100), i.reshape(nb * chunk, 100)

            args = (p_abs, _sd((b, cfg.seq_len), jnp.int32))
            in_sh = (_named(mesh, p_specs), _named(mesh, bspec))
            return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)

        def fn(params, sparse, dense):
            return ctr_forward(params, sparse, dense)

        args = (p_abs, _sd((b, cfg.n_sparse), jnp.int32),
                _sd((b, max(n_dense, 1)), jnp.float32))
        in_sh = (_named(mesh, p_specs), _named(mesh, bspec), _named(mesh, bspec))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)

    # retrieval: 1 query vs n_candidates
    n_cand = sp["n_candidates"]
    if is_b4r:
        def fn(params, seq):
            h = R.bert4rec_forward(params, seq, cfg, mesh=mesh)
            user = h[0, -1]
            scores = params["item_embed"][: cfg.n_items] @ user
            return jax.lax.top_k(scores, 100)

        args = (p_abs, _sd((1, cfg.seq_len), jnp.int32))
        in_sh = (_named(mesh, p_specs), _named(mesh, P()))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)

    def fn(params, sparse, dense):
        scores = ctr_forward(params, sparse, dense)
        return jax.lax.top_k(scores, 100)

    cand_spec = P(dp if dp else None, None)
    args = (p_abs, _sd((n_cand, cfg.n_sparse), jnp.int32),
            _sd((n_cand, max(n_dense, 1)), jnp.float32))
    in_sh = (_named(mesh, p_specs), _named(mesh, cand_spec), _named(mesh, cand_spec))
    return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)


# ================================================================= websearch
def _build_websearch(arch: ArchDef, shape_name: str, mesh, reduced: bool) -> CellSpec:
    from jax.experimental.shard_map import shard_map

    from repro.core.environment import EnvConfig
    from repro.core.qlearning import QConfig, train_batch
    from repro.core.rollout import unified_rollout
    from repro.core.state_bins import StateBins
    from repro.core.match_rules import default_rule_library
    from repro.core.telescope import merge_shard_candidates
    from repro.index.builder import MAX_QUERY_TERMS
    from repro.index.corpus import N_FIELDS
    from repro.policies import TabularQPolicy

    wcfg = arch.model_cfg(reduced)
    spec = arch.shape(shape_name)
    sp = dict(REDUCED_SHAPES[spec.kind]) if reduced else dict(spec.params)
    q_batch = sp["query_batch"]
    dp = _dp(mesh)
    msize = mesh.shape["model"] if mesh else 1

    nb_local = wcfg.n_blocks // msize
    w = wcfg.block_docs // 32
    n_pad_local = nb_local * wcfg.block_docs
    env_cfg = EnvConfig(n_blocks=nb_local, block_docs=wcfg.block_docs,
                        k_rules=wcfg.k_rules, max_candidates=wcfg.max_candidates,
                        n_top=wcfg.n_top, u_budget=wcfg.u_budget)
    qcfg = QConfig(p=wcfg.p_bins, n_actions=env_cfg.n_actions, t_max=wcfg.t_max)
    ruleset = default_rule_library()
    pu = int(np.sqrt(wcfg.p_bins))
    pv = wcfg.p_bins // pu
    bins_abs = StateBins(u_edges=_sd((pu - 1,), jnp.float32),
                         v_edges=_sd((pu, pv - 1), jnp.float32))
    bins_specs = StateBins(u_edges=P(), v_edges=P())

    occ_abs = _sd((q_batch, wcfg.n_blocks, MAX_QUERY_TERMS, N_FIELDS, w), jnp.uint32)
    scores_abs = _sd((q_batch, wcfg.n_blocks * wcfg.block_docs), jnp.float32)
    tp_abs = _sd((q_batch, MAX_QUERY_TERMS), jnp.bool_)
    q_abs = _sd((wcfg.p_bins, env_cfg.n_actions), jnp.float32)

    occ_spec = P(dp if dp else None, "model" if mesh else None, None, None, None)
    scores_spec = P(dp if dp else None, "model" if mesh else None)
    tp_spec = P(dp if dp else None, None)

    if spec.kind == "serve_websearch":
        def local_serve(qt, bins, occ, scores, tp):
            final = unified_rollout(env_cfg, ruleset, bins, TabularQPolicy(qt),
                                    qcfg.t_max, occ, scores, tp,
                                    backend=wcfg.backend).final_state
            if mesh is None:
                return final.cand, final.u, final.cand_cnt
            shard = jax.lax.axis_index("model")
            cand = jnp.where(final.cand >= 0,
                             final.cand + shard * n_pad_local, -1)
            gathered = jax.lax.all_gather(cand, "model")        # (S, Qloc, K)
            merged = merge_shard_candidates(gathered, keep=wcfg.max_candidates)
            u_tot = jax.lax.psum(final.u, "model")
            return merged, u_tot, jax.lax.psum(final.cand_cnt, "model")

        if mesh is None:
            fn = local_serve
        else:
            fn = shard_map(
                local_serve, mesh=mesh,
                in_specs=(P(), StateBins(u_edges=P(), v_edges=P()),
                          P(dp, "model", None, None, None),
                          P(dp, "model"), P(dp, None)),
                out_specs=(P(dp, None), P(dp), P(dp)),
                check_rep=False,
            )
        args = (q_abs, bins_abs, occ_abs, scores_abs, tp_abs)
        in_sh = (_named(mesh, P()), _named(mesh, bins_specs), _named(mesh, occ_spec),
                 _named(mesh, scores_spec), _named(mesh, tp_spec))
        return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)

    # rl_rollout: a policy-training step; per-shard TD stats are averaged
    # over the index shards ("the same policy on every machine").
    lp = wcfg.t_max
    prod_abs = _sd((q_batch, lp), jnp.float32)

    def local_train(qt, bins, occ, scores, tp, prod_r, rng):
        q_new, metrics = train_batch(env_cfg, qcfg, ruleset, bins, qt, occ,
                                     scores, tp, prod_r, jnp.float32(0.1), rng,
                                     backend=wcfg.backend)
        if mesh is not None:
            q_new = jax.lax.pmean(q_new, "model")
            q_new = jax.lax.pmean(q_new, dp)
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(jax.lax.pmean(m, "model"), dp), metrics)
        return q_new, metrics

    if mesh is None:
        fn = lambda qt, bins, occ, scores, tp, prod_r, rng: local_train(
            qt, bins, occ, scores, tp, prod_r, rng)
    else:
        fn = shard_map(
            local_train, mesh=mesh,
            in_specs=(P(), StateBins(u_edges=P(), v_edges=P()),
                      P(dp, "model", None, None, None), P(dp, "model"),
                      P(dp, None), P(dp, None), P()),
            out_specs=(P(), jax.tree_util.tree_map(lambda _: P(),
                       {"mean_u": 0, "mean_v": 0, "mean_cand": 0,
                        "mean_reward": 0, "q_abs_mean": 0})),
            check_rep=False,
        )
    rng_abs = jax.eval_shape(lambda: jax.random.key(0))
    args = (q_abs, bins_abs, occ_abs, scores_abs, tp_abs, prod_abs, rng_abs)
    in_sh = (_named(mesh, P()), _named(mesh, bins_specs), _named(mesh, occ_spec),
             _named(mesh, scores_spec), _named(mesh, tp_spec),
             _named(mesh, P(dp if dp else None, None)), _named(mesh, P()))
    return CellSpec(arch.arch_id, shape_name, fn, args, in_sh, None)


# =================================================================== dispatch
def build_cell(arch_id: str, shape_name: str, mesh=None, reduced: bool = False,
               cfg_override=None) -> CellSpec:
    arch = get_arch(arch_id)
    if cfg_override is not None:
        arch = dataclasses.replace(arch, model_cfg=lambda reduced_: cfg_override)
    builder = {
        "lm": _build_lm,
        "gnn": _build_gnn,
        "recsys": _build_recsys,
        "websearch": _build_websearch,
    }[arch.family]
    return builder(arch, shape_name, mesh, reduced)
