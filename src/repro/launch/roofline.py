import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis from the compiled dry-run artifacts.

Three terms per (arch × shape), single-pod mesh (16×16), TPU v5e
constants:

    compute    = FLOPs_per_chip      / 197e12 FLOP/s (bf16)
    memory     = HBM_bytes_per_chip  / 819e9  B/s
    collective = wire_bytes_per_chip / 50e9   B/s (ICI)

Sources and calibrations (EXPERIMENTS.md §Roofline):
 - `compiled.cost_analysis()` reports the PER-DEVICE post-SPMD program
   (verified against a hand-counted sharded matmul).
 - XLA cost analysis counts a `lax.scan`/`while` BODY ONCE, not
   ×trip-count.  For LM cells we therefore lower each cell twice —
   n_layers=L and n_layers=0 — and reconstruct
       total(L) = probe(0) + L × (probe(L) − probe(0))
   (the layer scan is the only trip-count-dependent region between the
   two probes; the loss/microbatch scans are configured to one chunk
   in BOTH probes so they cancel exactly).
 - collective wire bytes: Σ over collective ops of result-shape bytes ×
   type multiplier (all-reduce ×2 for its reduce-scatter+all-gather
   ring phases; others ×1), from the per-device partitioned HLO.
"""
import argparse
import dataclasses
import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s ICI per link

COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}

__all__ = ["roofline_terms", "model_flops", "analyze_cell", "main"]


def wire_bytes(coll: dict) -> float:
    return sum(COLL_MULT[k] * v for k, v in coll["bytes"].items())


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float) -> dict:
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_bytes_dev / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    total = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bound": dom,
        "roofline_frac": (t_c / total) if total > 0 else 0.0,
    }


# ------------------------------------------------------- analytic FLOPs
def _param_count(tree) -> int:
    import jax
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


def model_flops(arch_id: str, shape_name: str) -> dict:
    """MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE) /
    2·N_active·D (serve).  Global, whole step."""
    import jax
    from repro.configs import get_arch
    from repro.models.transformer import init_params

    arch = get_arch(arch_id)
    spec = arch.shape(shape_name)
    if arch.family != "lm":
        return {"model_flops": None, "n_params": None, "note": "6ND defined for LM"}
    cfg = arch.model_cfg(False)
    params = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    n_total = _param_count(params)
    if cfg.moe is not None:
        ex = params["layers"]["ffn"]["experts"]
        n_experts_all = _param_count(ex)
        n_active = (n_total - n_experts_all
                    + int(n_experts_all * cfg.moe.top_k / cfg.moe.n_experts))
    else:
        n_active = n_total
    sp = spec.params
    if spec.kind == "train":
        d = sp["global_batch"] * sp["seq_len"]
        mf = 6 * n_active * d
    elif spec.kind == "prefill":
        d = sp["global_batch"] * sp["seq_len"]
        mf = 2 * n_active * d
    else:  # decode: one token per sequence + attention over the cache
        d = sp["global_batch"]
        kv_flops = (2 * cfg.n_layers * sp["global_batch"] * sp["seq_len"]
                    * cfg.n_heads * cfg.d_head * 2)
        mf = 2 * n_active * d + kv_flops
    return {"model_flops": float(mf), "n_params": n_total, "n_active": n_active}


# -------------------------------------------------- scan-corrected probes
def lm_probe(arch_id: str, shape_name: str, mesh, cfg_override=None) -> dict:
    """Reconstruct trip-count-true per-device flops / bytes / wire bytes
    with a THREE-POINT probe over n_layers ∈ {L, L/2, 0}.

    XLA cost analysis counts every op once — a scanned layer body once
    (under-counts ×L) but also one-time ops on L-STACKED buffers (cache
    pass-through, stacked-param optimizer math) at full ∝L size.  The
    linear model  measured(l) = A + c·l + b·[l>0]  separates them:
        c = (m(L) − m(L/2)) / (L − L/2)     (∝L one-time ops)
        b = m(L) − m(0) − c·L               (the once-counted body)
        true(L) = m(L) + (L−1)·b
    Nested scans inside the body (chunked attention / loss / microbatch)
    would still undercount, so the probe config forces single-chunk
    attention + loss and microbatch=1 — the layer scan is then the only
    trip-count structure.  (Validated: probe-true matches analytic 6ND
    within the attention/embedding margins; EXPERIMENTS.md §Roofline.)
    """
    import dataclasses as dc

    import jax

    from repro.configs import get_arch
    from repro.launch.dryrun import collective_bytes
    from repro.launch.steps import build_cell

    arch = get_arch(arch_id)
    base_cfg = cfg_override if cfg_override is not None else arch.model_cfg(False)
    spec = arch.shape(shape_name)
    sp = spec.params
    seq = sp.get("seq_len", base_cfg.max_seq)
    tokens = sp.get("global_batch", 1) * seq
    probe_cfg = dc.replace(base_cfg, loss_chunk=tokens, microbatch=1,
                           q_chunk=seq)
    if probe_cfg.mla is not None:
        probe_cfg = dc.replace(
            probe_cfg, mla=dc.replace(probe_cfg.mla, q_chunk=seq))

    def measure(cfg):
        cell = build_cell(arch_id, shape_name, mesh=mesh, cfg_override=cfg)
        with mesh:
            compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                               out_shardings=cell.out_shardings,
                               donate_argnums=cell.donate_argnums
                               ).lower(*cell.args).compile()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "wire": wire_bytes(coll)}

    L = probe_cfg.n_layers
    half = max(L // 2, 1)
    m_L = measure(probe_cfg)
    m_h = measure(dc.replace(probe_cfg, n_layers=half))
    m_0 = measure(dc.replace(probe_cfg, n_layers=0))
    out = {}
    for k in ("flops", "bytes", "wire"):
        c = max((m_L[k] - m_h[k]) / max(L - half, 1), 0.0)
        b = max(m_L[k] - m_0[k] - c * L, 0.0)
        out[k + "_per_device"] = m_L[k] + (L - 1) * b
        out[k + "_layer"] = b
        out[k + "_linear"] = c
        out[k + "_outside"] = m_0[k]
    return out


def analyze_cell(rec: dict, corrected: dict | None = None) -> dict:
    """rec: dry-run JSON. corrected: optional lm_probe output."""
    if corrected is not None:
        f = corrected["flops_per_device"]
        b = corrected["bytes_per_device"]
        w = corrected["wire_per_device"]
    else:
        f = rec["cost"]["flops_per_device"]
        b = rec["cost"]["bytes_accessed_per_device"]
        w = wire_bytes(rec["collectives"])
    terms = roofline_terms(f, b, w)
    terms.update({"flops_per_device": f, "bytes_per_device": b,
                  "wire_bytes_per_device": w})
    return terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun/pod16x16")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--lm-corrected", action="store_true",
                    help="run the L/L0 probes for LM cells (slow)")
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False) if args.lm_corrected else None

    rows = []
    for path in sorted(Path(args.dryrun_dir).glob("*.json")):
        rec = json.loads(path.read_text())
        if not rec.get("ok"):
            continue
        arch_id, shape = rec["arch"], rec["shape"]
        corrected = None
        if args.lm_corrected and get_arch(arch_id).family == "lm":
            try:
                corrected = lm_probe(arch_id, shape, mesh)
            except Exception as e:  # noqa: BLE001
                corrected = None
                rec["probe_error"] = str(e)[:200]
        terms = analyze_cell(rec, corrected)
        mf = model_flops(arch_id, shape)
        n_dev = rec["devices"]
        hlo_global = terms["flops_per_device"] * n_dev
        ratio = (mf["model_flops"] / hlo_global
                 if mf.get("model_flops") and hlo_global else None)
        rows.append({
            "arch": arch_id, "shape": shape, "corrected": corrected is not None,
            **terms,
            "model_flops": mf.get("model_flops"),
            "useful_ratio": ratio,
            "peak_bytes": rec["memory"]["peak_bytes_est"],
        })
        r = rows[-1]
        print(f"{arch_id:24s} {shape:16s} bound={r['bound']:10s} "
              f"c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s "
              f"x={r['collective_s']:.2e}s useful={r['useful_ratio'] if r['useful_ratio'] else 0:.2f}",
              flush=True)

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
