"""StaticPlanPolicy — the hand-tuned production baseline as a Policy.

Wrapping a :class:`~repro.core.match_plan.MatchPlan` makes the paper's
"statically designed match plan" just another policy behind the same
rollout engine: entry ``t`` of the plan becomes the step-``t`` action,
including reset-before semantics and per-entry Δu/Δv quota overrides.
Past the end of the plan the policy emits ``a_stop``, so it is safe to
run under any ``t_max >= plan.length`` (serving uses a shared horizon).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.match_plan import MatchPlan
from repro.core.rollout import PolicyAction, USE_RULE_QUOTA

from .base import Policy

__all__ = ["StaticPlanPolicy"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StaticPlanPolicy(Policy):
    plan: MatchPlan
    n_actions_: int               # k_rules + 2 (static: a_stop = n_actions-1)

    def tree_flatten(self):
        return ((self.plan,), (self.n_actions_,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    @property
    def n_actions(self) -> int:
        return self.n_actions_

    @property
    def horizon(self) -> Optional[int]:
        return self.plan.length

    def act(self, s_bin, state, rng, t) -> PolicyAction:
        L = self.plan.length
        b = s_bin.shape[0]
        i = jnp.minimum(t, L - 1)
        in_plan = t < L
        a_stop = jnp.int32(self.n_actions_ - 1)

        action = jnp.where(in_plan, self.plan.rule_idx[i], a_stop)
        reset = jnp.where(in_plan, self.plan.reset_before[i], False)
        du = jnp.where(in_plan, self.plan.du_quota[i], USE_RULE_QUOTA)
        dv = jnp.where(in_plan, self.plan.dv_quota[i], USE_RULE_QUOTA)
        bcast = lambda x, dt: jnp.broadcast_to(x.astype(dt), (b,))
        return PolicyAction(
            action=bcast(action, jnp.int32),
            reset_before=bcast(reset, jnp.bool_),
            du_quota=bcast(du, jnp.int32),
            dv_quota=bcast(dv, jnp.int32),
        )

    def describe(self) -> dict:
        out = super().describe()
        out["plan_length"] = self.plan.length
        return out
