"""Tabular policies over the discretized (u, v) state space (paper §4).

``TabularQPolicy`` is the test-time/serving policy: greedy argmax over
a dense (p, k+2) Q-table.  ``EpsilonGreedy`` wraps ANY inner policy
with ε-exploration; ε is a traced leaf, so schedules (the linear decay
the trainer uses) never retrace the rollout.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.rollout import PolicyAction, USE_RULE_QUOTA

from .base import Policy

__all__ = ["TabularQPolicy", "EpsilonGreedy"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TabularQPolicy(Policy):
    q: jnp.ndarray                # (p, n_actions) float32

    def tree_flatten(self):
        return ((self.q,), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_actions(self) -> int:
        return self.q.shape[-1]

    def act(self, s_bin, state, rng, t) -> PolicyAction:
        greedy = jnp.argmax(self.q[s_bin], axis=-1).astype(jnp.int32)
        return PolicyAction.plain(greedy)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EpsilonGreedy(Policy):
    """ε-greedy exploration wrapper; explored steps take a uniform
    action with the rule library's default quotas and no reset-before."""

    inner: Policy
    epsilon: jnp.ndarray          # () float32, traced (schedulable)

    def __post_init__(self):
        self.epsilon = jnp.asarray(self.epsilon, jnp.float32)

    def tree_flatten(self):
        return ((self.inner, self.epsilon), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)
        obj.inner, obj.epsilon = children
        return obj

    @property
    def n_actions(self) -> int:
        return self.inner.n_actions

    @property
    def horizon(self):
        return self.inner.horizon

    def act(self, s_bin, state, rng, t) -> PolicyAction:
        k0, k1, k2 = jax.random.split(rng, 3)
        base = self.inner.act(s_bin, state, k0, t)
        b = s_bin.shape[0]
        explore = jax.random.randint(k1, (b,), 0, self.n_actions,
                                     dtype=jnp.int32)
        take = jax.random.uniform(k2, (b,)) < self.epsilon
        neutral = jnp.full((b,), USE_RULE_QUOTA, jnp.int32)
        return PolicyAction(
            action=jnp.where(take, explore, base.action),
            reset_before=jnp.where(take, False, base.reset_before),
            du_quota=jnp.where(take, neutral, base.du_quota),
            dv_quota=jnp.where(take, neutral, base.dv_quota),
        )

    def describe(self) -> dict:
        out = super().describe()
        out["inner"] = self.inner.describe()
        return out
