"""The Policy protocol — anything that can drive `unified_rollout`.

A policy is a *pytree*: its parameters (Q-table, plan entries, ε) are
leaves, so they are runtime arguments of compiled rollouts, while its
class and static metadata are aux data, so the executable cache keys on
policy *structure* only.  Publishing new parameters through a
:class:`repro.policies.PolicyStore` therefore never retraces.

Required surface::

    act(s_bin, state, rng, t) -> PolicyAction   # traced, batched
    n_actions: int                              # k_rules + 2
    horizon:   Optional[int]                    # natural episode length

``act`` receives the discretized state index ``s_bin`` (B,), the full
batched :class:`EnvState` (for richer policies that look beyond the
paper's (u, v) bins), a per-step PRNG key, and the step counter ``t``.
"""
from __future__ import annotations

from typing import Optional

from repro.core.rollout import PolicyAction, USE_RULE_QUOTA  # re-export

__all__ = ["Policy", "PolicyAction", "USE_RULE_QUOTA"]


class Policy:
    """Base class for rollout policies (subclasses register as pytrees)."""

    def act(self, s_bin, state, rng, t) -> PolicyAction:
        raise NotImplementedError

    @property
    def n_actions(self) -> int:
        raise NotImplementedError

    @property
    def horizon(self) -> Optional[int]:
        """Natural episode length, or None to use the caller's t_max."""
        return None

    def describe(self) -> dict:
        """Human-readable metadata (kind + static structure)."""
        return {"kind": type(self).__name__, "n_actions": self.n_actions,
                "horizon": self.horizon}
