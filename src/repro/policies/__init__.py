"""Unified Policy API (docs/policies.md).

Static production plans, learned tabular Q policies, and exploration
wrappers all implement one protocol and run through the single
``repro.core.rollout.unified_rollout`` scan; ``PolicyStore`` versions
immutable snapshots for serve-while-training.
"""
from repro.core.rollout import PolicyAction, RolloutResult, USE_RULE_QUOTA, unified_rollout

from .base import Policy
from .static_plan import StaticPlanPolicy
from .store import PolicySnapshot, PolicyStore, StalePolicyError
from .tabular import EpsilonGreedy, TabularQPolicy

__all__ = [
    "EpsilonGreedy", "Policy", "PolicyAction", "PolicySnapshot",
    "PolicyStore", "RolloutResult", "StalePolicyError", "StaticPlanPolicy",
    "TabularQPolicy", "USE_RULE_QUOTA", "unified_rollout",
]
