"""Versioned policy snapshots: publish / snapshot / subscribe.

The serve-while-training direction (ROADMAP "async replication") needs
one primitive: a trainer publishes immutable policy snapshots with
monotonically increasing version ids, and serving replicas pin a
snapshot and periodically refresh, with a *staleness bound* — a replica
more than ``staleness_bound`` versions behind the head must refuse to
serve (``StalePolicyError``) rather than silently answer with an
ancient policy.

The version/staleness/subscribe machinery itself lives in
`repro.core.versioned.VersionedStore` — the same core the live index's
`IndexEpochStore` publishes epochs through — and this module keeps the
policy-specific payload: snapshot validation, the fallback carry-
forward rule, and :class:`PolicySnapshot` immutability (the
category→policy dict is copied on publish, so a reader can never
observe a torn snapshot).
"""
from __future__ import annotations

from dataclasses import dataclass
from types import MappingProxyType
from typing import Dict, Mapping, Optional

from repro.core.versioned import StaleVersionError, VersionedStore

from .base import Policy

__all__ = ["PolicySnapshot", "PolicyStore", "StalePolicyError"]


class StalePolicyError(StaleVersionError):
    """A consumer's pinned snapshot is older than the staleness bound."""


_EMPTY: Mapping[int, Policy] = MappingProxyType({})


@dataclass(frozen=True)
class PolicySnapshot:
    version: int                        # monotonically increasing, from 1
    policies: Mapping[int, Policy]      # category -> Policy (read-only)
    # category -> degraded-service fallback (typically a truncated
    # StaticPlanPolicy with bounded u).  Published and hot-swapped
    # TOGETHER with the live set: a replica can never pair a new live
    # policy with a stale fallback or vice versa.
    fallbacks: Mapping[int, Policy] = _EMPTY

    def describe(self) -> dict:
        return {"version": self.version,
                "policies": {k: p.describe() for k, p in self.policies.items()},
                "fallbacks": {k: p.describe()
                              for k, p in self.fallbacks.items()}}


def _validate_policies(policies: Dict[int, Policy], role: str = "policies",
                       allow_empty: bool = False) -> None:
    if not isinstance(policies, dict) or (not policies and not allow_empty):
        raise TypeError(
            f"PolicyStore.publish expects a non-empty {{category: Policy}} "
            f"dict for {role}, got {type(policies).__name__}")
    for cat, pol in policies.items():
        if not isinstance(pol, Policy):
            raise TypeError(
                f"category {cat} ({role}): expected a repro.policies.Policy, "
                f"got {type(pol).__name__}. Raw Q-table arrays are no longer "
                "accepted — wrap them with TabularQPolicy(q) (or a "
                "MatchPlan with StaticPlanPolicy(plan, n_actions)).")


class PolicyStore(VersionedStore):
    stale_error = StalePolicyError
    artifact = "policy snapshot"

    # ------------------------------------------------------------ publish
    def publish(self, policies: Dict[int, Policy],
                fallbacks: Optional[Dict[int, Policy]] = None,
                version: Optional[int] = None) -> int:
        """Install a new snapshot; returns its (strictly increasing)
        version id and notifies subscribers.

        ``fallbacks`` is the degraded-service policy set (category ->
        cheap bounded-u Policy, e.g. a truncated StaticPlanPolicy).
        When omitted, the previous snapshot's fallbacks are carried
        forward — live policies and their fallbacks always travel in
        the same snapshot, so replicas hot-swap them atomically.

        ``version`` pins an explicit version id (must exceed the head):
        the process-cell relay republishes the producer's snapshots into
        worker-local stores under the producer's own numbering, so
        version-lag accounting means the same thing on both sides.
        """
        _validate_policies(policies)
        if fallbacks is not None:
            _validate_policies(fallbacks, role="fallbacks", allow_empty=True)
        frozen = MappingProxyType(dict(policies))
        fb_frozen = (MappingProxyType(dict(fallbacks))
                     if fallbacks is not None else None)

        def build(prev: Optional[PolicySnapshot], ver: int) -> PolicySnapshot:
            fb = fb_frozen if fb_frozen is not None else (
                prev.fallbacks if prev else _EMPTY)
            return PolicySnapshot(ver, frozen, fb)

        return self._publish_snapshot(build, version=version)
