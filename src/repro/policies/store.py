"""Versioned policy snapshots: publish / snapshot / subscribe.

The serve-while-training direction (ROADMAP "async replication") needs
one primitive: a trainer publishes immutable policy snapshots with
monotonically increasing version ids, and serving replicas pin a
snapshot and periodically refresh, with a *staleness bound* — a replica
more than ``staleness_bound`` versions behind the head must refuse to
serve (``StalePolicyError``) rather than silently answer with an
ancient policy.

Thread-safe: ``publish`` may be called from a trainer thread while
engine replicas ``snapshot``/``validate`` concurrently.  Snapshots are
immutable (the category→policy dict is copied on publish), so a reader
can never observe a torn snapshot: the mapping is fully built before
the head pointer moves.  Subscriber delivery is per-subscriber
serialized and version-monotone — a callback registered mid-publish
observes either the old or the new version first, never both out of
order and never the same version twice.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from types import MappingProxyType
from typing import Callable, Dict, List, Mapping, Optional

from .base import Policy

__all__ = ["PolicySnapshot", "PolicyStore", "StalePolicyError"]


class StalePolicyError(RuntimeError):
    """A consumer's pinned snapshot is older than the staleness bound."""


_EMPTY: Mapping[int, Policy] = MappingProxyType({})


@dataclass(frozen=True)
class PolicySnapshot:
    version: int                        # monotonically increasing, from 1
    policies: Mapping[int, Policy]      # category -> Policy (read-only)
    # category -> degraded-service fallback (typically a truncated
    # StaticPlanPolicy with bounded u).  Published and hot-swapped
    # TOGETHER with the live set: a replica can never pair a new live
    # policy with a stale fallback or vice versa.
    fallbacks: Mapping[int, Policy] = _EMPTY

    def describe(self) -> dict:
        return {"version": self.version,
                "policies": {k: p.describe() for k, p in self.policies.items()},
                "fallbacks": {k: p.describe()
                              for k, p in self.fallbacks.items()}}


def _validate_policies(policies: Dict[int, Policy], role: str = "policies",
                       allow_empty: bool = False) -> None:
    if not isinstance(policies, dict) or (not policies and not allow_empty):
        raise TypeError(
            f"PolicyStore.publish expects a non-empty {{category: Policy}} "
            f"dict for {role}, got {type(policies).__name__}")
    for cat, pol in policies.items():
        if not isinstance(pol, Policy):
            raise TypeError(
                f"category {cat} ({role}): expected a repro.policies.Policy, "
                f"got {type(pol).__name__}. Raw Q-table arrays are no longer "
                "accepted — wrap them with TabularQPolicy(q) (or a "
                "MatchPlan with StaticPlanPolicy(plan, n_actions)).")


class _Subscriber:
    """One registered callback with per-subscriber delivery state.

    ``deliver`` serializes invocations of the callback (two concurrent
    publishers never run it at once) and enforces version monotonicity:
    a snapshot at or below the last delivered version is dropped.  This
    closes the subscribe-under-concurrent-publish race where the
    initial replay of the current snapshot could land *after* a newer
    publish already notified the callback, delivering versions out of
    order."""

    __slots__ = ("callback", "_lock", "_last_version")

    def __init__(self, callback: Callable[[PolicySnapshot], None]):
        self.callback = callback
        self._lock = threading.Lock()
        self._last_version = 0

    def deliver(self, snap: PolicySnapshot) -> None:
        with self._lock:
            if snap.version <= self._last_version:
                return
            self._last_version = snap.version
            self.callback(snap)


class PolicyStore:
    def __init__(self, staleness_bound: int = 1):
        if staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        self.staleness_bound = staleness_bound
        self._lock = threading.Lock()
        self._snapshot: Optional[PolicySnapshot] = None
        self._subscribers: List[_Subscriber] = []

    # ------------------------------------------------------------ publish
    def publish(self, policies: Dict[int, Policy],
                fallbacks: Optional[Dict[int, Policy]] = None) -> int:
        """Install a new snapshot; returns its (strictly increasing)
        version id and notifies subscribers.

        ``fallbacks`` is the degraded-service policy set (category ->
        cheap bounded-u Policy, e.g. a truncated StaticPlanPolicy).
        When omitted, the previous snapshot's fallbacks are carried
        forward — live policies and their fallbacks always travel in
        the same snapshot, so replicas hot-swap them atomically.
        """
        _validate_policies(policies)
        if fallbacks is not None:
            _validate_policies(fallbacks, role="fallbacks", allow_empty=True)
        with self._lock:
            version = (self._snapshot.version if self._snapshot else 0) + 1
            fb = (MappingProxyType(dict(fallbacks)) if fallbacks is not None
                  else (self._snapshot.fallbacks if self._snapshot else _EMPTY))
            snap = PolicySnapshot(version, MappingProxyType(dict(policies)), fb)
            self._snapshot = snap
            subscribers = list(self._subscribers)
        for sub in subscribers:
            sub.deliver(snap)
        return version

    # ----------------------------------------------------------- consume
    @property
    def version(self) -> int:
        """Head version (0 before the first publish)."""
        snap = self._snapshot
        return snap.version if snap else 0

    def snapshot(self) -> PolicySnapshot:
        snap = self._snapshot
        if snap is None:
            raise LookupError("PolicyStore has no published snapshot yet")
        return snap

    def subscribe(self, callback: Callable[[PolicySnapshot], None]) -> Callable[[], None]:
        """Register ``callback(snapshot)`` for future publishes (and
        immediately for the current snapshot, if any).  Returns an
        unsubscribe function.

        Safe under concurrent ``publish``: the callback observes a
        strictly increasing version sequence whose first element is the
        snapshot current at registration *or any later one* — never an
        older version after a newer, never a duplicate."""
        sub = _Subscriber(callback)
        with self._lock:
            self._subscribers.append(sub)
            snap = self._snapshot
        if snap is not None:
            # Replay outside the store lock; _Subscriber.deliver drops
            # it if a concurrent publish already delivered a newer one.
            sub.deliver(snap)

        def unsubscribe() -> None:
            with self._lock:
                if sub in self._subscribers:
                    self._subscribers.remove(sub)
        return unsubscribe

    def staleness(self, version: int) -> int:
        """Versions between a pinned snapshot and the head."""
        return self.version - version

    def validate(self, version: int) -> int:
        """Enforce the staleness bound on a pinned snapshot version.
        Returns the staleness; raises :class:`StalePolicyError` beyond
        the bound."""
        staleness = self.staleness(version)
        if staleness > self.staleness_bound:
            raise StalePolicyError(
                f"snapshot v{version} is {staleness} versions behind head "
                f"v{self.version} (staleness_bound={self.staleness_bound}); "
                "refresh before serving")
        return staleness
