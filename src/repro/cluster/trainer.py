"""Background trainer feeding a `PolicyStore` while replicas serve.

The paper's planner is trained *online*: Bing keeps learning the MDP
policy against live traffic while the index serves it.  This loop is
that trainer: per-category tabular Q-learning epochs
(`RetrievalSystem.policy_train_step`, the same `train_batch` unit as
offline training) run on a background thread, and every
``publish_every`` epochs a fresh `{category: TabularQPolicy}` snapshot
is published into the shared store — the replicas hot-swap to it at
their next drain.  Each publish carries the degraded-service
**fallback policies** in the same snapshot (live policy and its
SHALLOW fallback hot-swap atomically; see docs/cluster.md).

Training batches come from a **served-traffic tap** when one is wired
(`source=cluster.tap`): the trainer samples the queries the fleet
actually served — popularity-weighted by construction, with degraded
and shed tickets boosted — instead of drawing synthetic samples from
the query log.  That closes the paper's train-on-live-traffic loop:
the MDP spends its capacity exactly where serving pressure is.  With
no tap, the loop falls back to direct query-log sampling (the offline
shape used by tests and the standalone trainer CLI).

Publishes are **eval-gated** by default (the standard online-promotion
pattern): each candidate Q-table is scored on a fixed probe set with
the serving-path recall proxy (`probe_recall` — rollout + L1 prune,
bit-identical to what a 1-shard engine serves), and the snapshot always
carries the best scorer so far.  A version bump therefore never
regresses candidate quality on the probe set — the monotonicity the
online-learning demo asserts — while the cadence stays fixed (a
rejected candidate re-publishes the incumbent).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.qlearning import init_q, linear_epsilon
from repro.core.rollout import unified_rollout
from repro.core.telescope import l1_prune
from repro.data.querylog import CAT1, CAT2
from repro.obs import NULL_TRACER, Tracer
from repro.policies import Policy, PolicyStore, TabularQPolicy

from .tap import ServedTrafficTap

__all__ = ["TrainerConfig", "TrainerLoop", "candidate_recall", "probe_recall"]


def candidate_recall(doc_ids: np.ndarray, judged_ids: np.ndarray,
                     judged_gains: np.ndarray) -> np.ndarray:
    """Per-query recall proxy: fraction of positively judged docs
    (gain > 0) present in the returned candidate ids.  ``doc_ids`` is
    (B, keep) with -1 padding; judged arrays are the query log's."""
    out = np.zeros(doc_ids.shape[0])
    keep = doc_ids.shape[1]
    for i in range(doc_ids.shape[0]):
        pos = judged_ids[i][(judged_ids[i] >= 0) & (judged_gains[i] > 0)]
        if len(pos) == 0:
            out[i] = 1.0
            continue
        got = np.intersect1d(doc_ids[i][doc_ids[i] >= 0], pos).size
        out[i] = got / min(len(pos), keep)
    return out


def probe_recall(system, policy: Policy, qids: Sequence[int],
                 keep: int = 100) -> float:
    """Mean candidate recall of ``policy`` on fixed probe queries via
    the serving path (rollout → L1 prune) — for a 1-shard engine with
    the same ``keep`` this is bit-identical to served responses
    (`tests/test_serving.py::test_engine_matches_direct_rollout`), so a
    gate decision here is exactly a statement about serving quality."""
    qids = np.asarray(qids)
    occ, scores, tp = system.batch_inputs(qids)
    t_max = policy.horizon or system.qcfg.t_max
    fin = unified_rollout(system.env_cfg, system.ruleset, system.bins,
                          policy, t_max, occ, scores, tp,
                          backend=system.cfg.backend).final_state
    ids, _ = l1_prune(scores, fin.cand, keep=keep)
    return float(candidate_recall(np.asarray(ids),
                                  system.log.judged_ids[qids],
                                  system.log.judged_gains[qids]).mean())


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    iters: int = 60               # total training epochs
    publish_every: int = 20       # epochs between publishes
    batch: int = 32               # queries per training batch
    eps_start: float = 0.5
    eps_end: float = 0.05
    seed: int = 0
    gate: bool = True             # eval-gated promotion (monotone probe score)
    probe_queries: int = 32       # probe-set size per category
    keep: int = 100               # L1 prune depth for probe scoring
    # Gate on a held-out slice of the served-traffic tap instead of the
    # fixed query-log probe set (needs a source with tap holdout
    # enabled; falls back to the fixed set while the holdout is empty).
    # The probe set is then fresh per gate, so the incumbent is
    # re-scored on the same queries — promotion compares both policies
    # on live traffic, but scores are no longer monotone in version
    # (each gate is a new sample), hence opt-in.
    probe_from_tap: bool = False
    publish_initial: bool = True  # publish v1 before any training
    fallback_plan_len: int = 2    # SHALLOW fallback = plan prefix of this many entries
    # With a served-traffic source, how long one epoch may wait for the
    # tap to fill before skipping a category's update (the fleet serves
    # concurrently, so early epochs briefly race the first responses).
    wait_for_source_s: float = 30.0


class TrainerLoop:
    """Runs ``cfg.iters`` epochs on a daemon thread, publishing every
    ``publish_every`` epochs (plus the initial snapshot), so a full run
    publishes ``publish_initial + iters // publish_every`` versions.

    ``source`` (a :class:`ServedTrafficTap`, typically
    ``cluster.tap``) switches training batches from query-log sampling
    to the cluster's served-traffic stream; it may also be assigned
    after construction but before :meth:`start` (the cluster is
    usually built after the trainer's first publish).
    """

    def __init__(self, system, store: PolicyStore,
                 cats: Sequence[int] = (CAT1, CAT2),
                 cfg: TrainerConfig = TrainerConfig(),
                 source: Optional[ServedTrafficTap] = None,
                 tracer: Tracer = NULL_TRACER):
        assert system.bins is not None, "fit_state_bins() first"
        self.system = system
        self.store = store
        self.cats = tuple(cats)
        self.cfg = cfg
        self.source = source
        self.tracer = tracer
        rng = np.random.default_rng(cfg.seed)
        self._rng = rng
        self._key = jax.random.key(cfg.seed)
        self._qids_all = {c: np.where(system.log.category == c)[0]
                          for c in self.cats}
        self._q = {c: init_q(system.qcfg) for c in self.cats}
        self._best_q = dict(self._q)
        self._best_score: Dict[int, float] = {c: -np.inf for c in self.cats}
        # Degraded-service fallbacks ride along with every publish so a
        # snapshot is always (live policy, its fallback) as one unit.
        self._fallbacks = system.fallback_policies(
            self.cats, length=cfg.fallback_plan_len)
        self.probe_qids = {c: self._qids_all[c][: cfg.probe_queries]
                           for c in self.cats}
        self.history: List[dict] = []     # one row per publish
        self.epochs_done = 0
        self.tap_batches = 0              # batches drawn from the tap
        self.log_batches = 0              # batches drawn from the query log
        self.starved_batches = 0          # tap dry past the wait: skipped
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    # ------------------------------------------------------------ publish
    def _probe_set(self, cat: int) -> Tuple[np.ndarray, str]:
        """The gate's probe queries for one category: a fresh held-out
        sample of served traffic when tap gating is on and the holdout
        has filled, else the fixed query-log slice."""
        if self.cfg.probe_from_tap and self.source is not None:
            qids = self.source.holdout_sample(cat, self.cfg.probe_queries,
                                              self._rng)
            if qids is not None and len(qids):
                return qids, "tap"
        return self.probe_qids[cat], "log"

    def _gate(self) -> Tuple[Dict[int, Policy], Dict[int, float], Dict[int, str]]:
        """Score current Q-tables on the probe sets; promote improvers."""
        scores: Dict[int, float] = {}
        sources: Dict[int, str] = {}
        for c in self.cats:
            if not self.cfg.gate:
                self._best_q[c] = self._q[c]
                scores[c], sources[c] = float("nan"), "none"
                continue
            probe, sources[c] = self._probe_set(c)
            s = probe_recall(self.system, TabularQPolicy(self._q[c]),
                             probe, keep=self.cfg.keep)
            if sources[c] == "tap":
                # The probe set is a fresh traffic sample each gate, so
                # the incumbent's remembered score is for *different*
                # queries — re-score it on the same probe so promotion
                # compares the two policies apples-to-apples.
                incumbent = (s if self._best_q[c] is self._q[c]
                             else probe_recall(
                                 self.system, TabularQPolicy(self._best_q[c]),
                                 probe, keep=self.cfg.keep))
                promoted = s >= incumbent
                if promoted:
                    self._best_q[c] = self._q[c]
                scores[c] = self._best_score[c] = s if promoted else incumbent
            else:
                promoted = s >= self._best_score[c]
                if promoted:
                    self._best_score[c] = s
                    self._best_q[c] = self._q[c]
                scores[c] = self._best_score[c]
            self.tracer.instant("gate_decision", category=c,
                                probe_recall=s, promoted=promoted,
                                probe_source=sources[c])
        return ({c: TabularQPolicy(self._best_q[c]) for c in self.cats},
                scores, sources)

    def publish_now(self) -> int:
        """Gate + publish the current tables immediately (e.g. to get
        v1 up before replicas construct); returns the version."""
        with self.tracer.span("eval_gate") as gate_span:
            policies, scores, sources = self._gate()
            gate_span.end(probe_recall={str(c): scores[c]
                                        for c in self.cats})
        with self.tracer.span("publish") as pub_span:
            version = self.store.publish(policies,
                                         fallbacks=dict(self._fallbacks))
            pub_span.end(version=version)
        self.history.append({
            "version": version,
            "epoch": self.epochs_done,
            # Index epoch at publish time: correlates policy versions
            # with the corpus state they were trained against (0 on a
            # static index).
            "index_epoch": getattr(self.system, "index_epoch", 0),
            "probe_recall": {c: scores[c] for c in self.cats},
            "probe_source": sources,
            "tap_batches": self.tap_batches,
            "log_batches": self.log_batches,
        })
        return version

    # -------------------------------------------------------------- train
    def _sample(self, cat: int) -> Optional[np.ndarray]:
        """One training batch of qids: from the served-traffic tap when
        wired (waiting briefly while the fleet's first responses land),
        else from the query log.  None = starved (skip the update)."""
        if self.source is None:
            self.log_batches += 1
            return self.system.sample_train_qids(cat, self.cfg.batch,
                                                 self._rng)
        deadline = time.monotonic() + self.cfg.wait_for_source_s
        while not self._stop.is_set():
            qids = self.source.sample(cat, self.cfg.batch, self._rng)
            if qids is not None:
                self.tap_batches += 1
                self.tracer.instant("tap_draw", category=cat, n=len(qids))
                return qids
            if time.monotonic() >= deadline:
                break
            time.sleep(0.005)
        self.starved_batches += 1
        return None

    def _epoch(self, it: int) -> None:
        eps = linear_epsilon(it, self.cfg.iters, self.cfg.eps_start,
                             self.cfg.eps_end)
        with self.tracer.span("epoch", it=it):
            for c in self.cats:
                qids = self._sample(c)
                if qids is None:
                    continue              # tap starved: epoch still counts
                self._key, sub = jax.random.split(self._key)
                self._q[c], _ = self.system.policy_train_step(
                    c, self._q[c], sub, eps, qids)
        self.epochs_done += 1

    def _run(self) -> None:
        try:
            if self.cfg.publish_initial:
                self.publish_now()
            for it in range(self.cfg.iters):
                if self._stop.is_set():
                    return
                self._epoch(it)
                if (it + 1) % self.cfg.publish_every == 0:
                    self.publish_now()
        except BaseException as e:          # noqa: BLE001 — surfaced in join()
            self.error = e

    # ------------------------------------------------------------ control
    @property
    def versions_published(self) -> List[int]:
        return [row["version"] for row in self.history]

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "TrainerLoop":
        if self._thread is not None:
            raise RuntimeError("trainer already started")
        self._thread = threading.Thread(target=self._run, name="trainer",
                                        daemon=True)
        self._thread.start()
        return self

    def run_to_completion(self) -> "TrainerLoop":
        """Synchronous variant (tests, CLI without --serve)."""
        self._run()
        if self.error is not None:
            raise self.error
        return self

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)
        if self.error is not None:
            raise self.error
