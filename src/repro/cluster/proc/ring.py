"""Fixed-slot SPSC ring buffers over POSIX shared memory.

The router→replica hop in the process cell is a **memcpy, not a
pickle**: requests and responses are fixed-layout binary records (see
`repro.cluster.proc.messages`) pushed through one single-producer /
single-consumer ring per direction per replica.  The ring lives in a
`multiprocessing.shared_memory` segment sized at creation — slot count
and slot payload capacity are fixed, so both sides compute every
offset arithmetically and never allocate.

Protocol — bounded MPMC queue à la Vyukov, specialised to SPSC:

- each slot starts with a u64 sequence number, initialised to its own
  slot index ``j``;
- the producer claims position ``pos`` (its private monotonically
  increasing counter mirrored at header ``tail``), waits until
  ``slot[pos % n].seq == pos``, memcpys the payload, then publishes by
  setting ``seq = pos + 1``;
- the consumer at position ``pos`` (header ``head``) waits until
  ``seq == pos + 1``, copies the payload out, then recycles the slot
  with ``seq = pos + n``.

The sequence word is the only synchronisation point: it is written
last by the producer and last by the consumer, so a torn read can
never expose a half-written payload (CPython's GIL + the kernel give
us cache coherence; numpy u64 loads/stores on aligned memory are
single instructions).  ``head``/``tail`` in the header are advisory
mirrors used for occupancy/telemetry — correctness never reads them.

Waiting is hybrid: spin for a few hundred iterations (the common case
under load — the peer is actively draining), then sleep with capped
exponential backoff ("park").  Parks and wakes are counted in the
header so the obs plane can report contention per replica.

Header layout (64 bytes, one cache line):

====== ======= ====================================================
offset  type    field
====== ======= ====================================================
0       u64     head       consumer position (advisory mirror)
8       u64     tail       producer position (advisory mirror)
16      u64     producer_parks   producer slept waiting for space
24      u64     consumer_parks   consumer slept waiting for data
32      u64     wakes      successful pops after at least one park
40      f64     heartbeat  writer-stamped monotonic time (liveness)
48      u64     depth_hint writer-published queue depth (router load)
56      u64     (reserved)
====== ======= ====================================================
"""
from __future__ import annotations

import struct
import time
from multiprocessing import shared_memory
from typing import Iterator, List, Optional

import numpy as np

__all__ = ["RingClosed", "RingFull", "ShmRing"]

_HDR_BYTES = 64
_OFF_HEAD = 0
_OFF_TAIL = 8
_OFF_PROD_PARKS = 16
_OFF_CONS_PARKS = 24
_OFF_WAKES = 32
_OFF_HEARTBEAT = 40
_OFF_DEPTH_HINT = 48

_SLOT_HDR = struct.Struct("<QII")   # seq u64, len u32, pad u32
_U64 = struct.Struct("<Q")
_U32 = struct.Struct("<I")
_F64 = struct.Struct("<d")

_SPIN_ITERS = 200          # busy iterations before the first sleep
_PARK_MIN_S = 50e-6        # first sleep
_PARK_MAX_S = 2e-3         # backoff cap


class RingFull(Exception):
    """try_push on a full ring."""


class RingClosed(Exception):
    """The peer died or the ring was closed while waiting."""


def _align8(n: int) -> int:
    return (n + 7) & ~7


class ShmRing:
    """One direction of a replica's message channel.

    Exactly one producer and one consumer, in different processes.
    Create with :meth:`create` (owner side, unlinks on close) and
    :meth:`attach` (peer side, never unlinks).
    """

    def __init__(self, shm: shared_memory.SharedMemory, n_slots: int,
                 slot_bytes: int, owner: bool):
        self._shm = shm
        self._buf = shm.buf
        self.n_slots = n_slots
        self.slot_bytes = slot_bytes            # payload capacity
        self._slot_stride = _align8(_SLOT_HDR.size + slot_bytes)
        self._owner = owner
        self._closed = False
        # Private positions — the shared head/tail words are advisory.
        self._head = 0
        self._tail = 0
        # Lazy numpy views for the batch paths (see _views): strided
        # windows over the SAME shared buffer the scalar path uses.
        self._np_seq = None
        self._np_len = None
        self._np_payload = None

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, n_slots: int, slot_bytes: int,
               name: Optional[str] = None) -> "ShmRing":
        if n_slots < 2 or n_slots & (n_slots - 1):
            raise ValueError(f"n_slots must be a power of two >= 2, "
                             f"got {n_slots}")
        stride = _align8(_SLOT_HDR.size + slot_bytes)
        size = _HDR_BYTES + n_slots * stride
        shm = shared_memory.SharedMemory(create=True, size=size, name=name)
        ring = cls(shm, n_slots, slot_bytes, owner=True)
        shm.buf[:_HDR_BYTES] = b"\x00" * _HDR_BYTES
        for j in range(n_slots):
            off = ring._slot_off(j)
            _SLOT_HDR.pack_into(shm.buf, off, j, 0, 0)
        return ring

    @classmethod
    def attach(cls, name: str, n_slots: int, slot_bytes: int) -> "ShmRing":
        shm = shared_memory.SharedMemory(name=name)
        # CPython registers every attach with the resource_tracker
        # (bpo-38119).  Workers are spawned by the ring's creator, so
        # they SHARE its tracker process and the double registration is
        # an idempotent set-add — the creator's unlink() performs the
        # single matching unregister.  (Do NOT unregister here: that
        # would remove the creator's entry and make its later unlink
        # KeyError inside the shared tracker.)
        return cls(shm, n_slots, slot_bytes, owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # Drop the numpy views BEFORE the memoryview: each one holds a
        # buffer export of the shm mapping, and SharedMemory.close()
        # raises BufferError while any export is alive.
        self._np_seq = self._np_len = self._np_payload = None
        self._buf = None
        self._shm.close()
        if self._owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:
                pass

    # -------------------------------------------------------------- header
    def _load_u64(self, off: int) -> int:
        return _U64.unpack_from(self._buf, off)[0]

    def _store_u64(self, off: int, val: int) -> None:
        _U64.pack_into(self._buf, off, val)

    def _bump_u64(self, off: int) -> None:
        # Single writer per counter → plain read-modify-write is safe.
        _U64.pack_into(self._buf, off,
                       _U64.unpack_from(self._buf, off)[0] + 1)

    def stamp_heartbeat(self) -> None:
        _F64.pack_into(self._buf, _OFF_HEARTBEAT, time.monotonic())

    def heartbeat(self) -> float:
        return _F64.unpack_from(self._buf, _OFF_HEARTBEAT)[0]

    def set_depth_hint(self, depth: int) -> None:
        self._store_u64(_OFF_DEPTH_HINT, max(0, depth))

    def depth_hint(self) -> int:
        return self._load_u64(_OFF_DEPTH_HINT)

    def occupancy(self) -> int:
        """Messages currently in the ring (advisory — reads the
        mirrored head/tail, fine for load signals and stats)."""
        return max(0, self._load_u64(_OFF_TAIL) - self._load_u64(_OFF_HEAD))

    def park_stats(self) -> dict:
        return {"producer_parks": self._load_u64(_OFF_PROD_PARKS),
                "consumer_parks": self._load_u64(_OFF_CONS_PARKS),
                "wakes": self._load_u64(_OFF_WAKES)}

    # --------------------------------------------------------------- slots
    def _slot_off(self, j: int) -> int:
        return _HDR_BYTES + j * self._slot_stride

    def _slot_seq(self, j: int) -> int:
        return _U64.unpack_from(self._buf, self._slot_off(j))[0]

    # ------------------------------------------------------------ producer
    def try_push(self, payload: bytes) -> bool:
        """Push without blocking; False when the ring is full."""
        if self._closed:
            raise RingClosed("ring closed")
        if len(payload) > self.slot_bytes:
            raise ValueError(
                f"payload of {len(payload)} bytes exceeds slot capacity "
                f"{self.slot_bytes}; oversized messages must be rejected "
                "at the codec layer, not silently truncated")
        pos = self._tail
        j = pos & (self.n_slots - 1)
        off = self._slot_off(j)
        if self._slot_seq(j) != pos:
            return False                        # slot not yet recycled
        body = off + _SLOT_HDR.size
        self._buf[body: body + len(payload)] = payload
        _U32.pack_into(self._buf, off + 8, len(payload))
        # Publish seq LAST, as its own store: a combined header write
        # can become visible low-address-first, letting the consumer
        # see the new seq with a stale length (torn read).
        _U64.pack_into(self._buf, off, pos + 1)
        self._tail = pos + 1
        self._store_u64(_OFF_TAIL, self._tail)
        return True

    def push(self, payload: bytes,
             deadline_s: Optional[float] = None,
             alive: Optional[callable] = None) -> None:
        """Blocking push with spin-then-park wait.

        ``alive`` is polled while parked; when it returns False the
        peer is considered dead and :class:`RingClosed` is raised —
        the caller requeues, it must not spin on a corpse.
        """
        spins = 0
        sleep_s = _PARK_MIN_S
        parked = False
        while not self.try_push(payload):
            spins += 1
            if spins < _SPIN_ITERS:
                continue
            if not parked:
                parked = True
                self._bump_u64(_OFF_PROD_PARKS)
            if alive is not None and not alive():
                raise RingClosed("consumer gone")
            if deadline_s is not None and time.monotonic() > deadline_s:
                raise RingClosed("push deadline exceeded")
            time.sleep(sleep_s)
            sleep_s = min(sleep_s * 2, _PARK_MAX_S)

    # ------------------------------------------------------------ consumer
    def try_pop(self) -> Optional[bytes]:
        if self._closed:
            raise RingClosed("ring closed")
        pos = self._head
        j = pos & (self.n_slots - 1)
        off = self._slot_off(j)
        if self._slot_seq(j) != pos + 1:
            return None                         # nothing published yet
        # seq was published after len + payload, so both are valid here
        length = _U32.unpack_from(self._buf, off + 8)[0]
        body = off + _SLOT_HDR.size
        payload = bytes(self._buf[body: body + length])
        # Recycle by storing ONLY seq — the producer rewrites len
        _U64.pack_into(self._buf, off, pos + self.n_slots)
        self._head = pos + 1
        self._store_u64(_OFF_HEAD, self._head)
        return payload

    def pop_many(self, limit: int = 64) -> Iterator[bytes]:
        """Drain up to ``limit`` available messages without blocking."""
        for _ in range(limit):
            msg = self.try_pop()
            if msg is None:
                return
            yield msg

    def pop(self, timeout_s: Optional[float] = None,
            alive: Optional[callable] = None) -> Optional[bytes]:
        """Blocking pop with spin-then-park wait; None on timeout."""
        deadline = (time.monotonic() + timeout_s
                    if timeout_s is not None else None)
        spins = 0
        sleep_s = _PARK_MIN_S
        parked = False
        while True:
            msg = self.try_pop()
            if msg is not None:
                if parked:
                    self._bump_u64(_OFF_WAKES)
                return msg
            spins += 1
            if spins < _SPIN_ITERS:
                continue
            if not parked:
                parked = True
                self._bump_u64(_OFF_CONS_PARKS)
            if alive is not None and not alive():
                raise RingClosed("producer gone")
            if deadline is not None and time.monotonic() > deadline:
                return None
            time.sleep(sleep_s)
            sleep_s = min(sleep_s * 2, _PARK_MAX_S)

    # ------------------------------------------------------- batch transfer
    # The batch paths move B records per ring operation: ONE bulk copy
    # into the payload region, ONE gate publish (slot ``pos``'s sequence
    # word, stored last — the consumer pops strictly in order, so slots
    # pos+1..pos+k-1 published before it stay invisible until the gate
    # opens), and one park/wake per batch episode instead of per record.
    # A batch never spans the wraparound: each call covers one
    # contiguous slot run and the caller loops — a split batch lands as
    # two (whole) publishes, records are never torn.

    def _views(self):
        """Strided numpy windows over the slot region (lazy; shared
        with the scalar path byte-for-byte).  seq/len are per-slot
        columns; payload is the (n_slots, slot_bytes) data matrix."""
        if self._np_seq is None:
            if self._closed:
                raise RingClosed("ring closed")
            n, stride = self.n_slots, self._slot_stride
            raw = np.frombuffer(self._buf, np.uint8,
                                count=_HDR_BYTES + n * stride)
            slots = raw[_HDR_BYTES:].reshape(n, stride)
            self._np_seq = slots[:, :8].view("<u8")[:, 0]
            self._np_len = slots[:, 8:12].view("<u4")[:, 0]
            self._np_payload = slots[:, _SLOT_HDR.size:
                                     _SLOT_HDR.size + self.slot_bytes]
        return self._np_seq, self._np_len, self._np_payload

    def _free_run(self, seq, want: int):
        """(pos, j0, k): producer-side length of the free contiguous
        slot run starting at the tail, capped at ``want`` and the lap
        boundary."""
        pos = self._tail
        j0 = pos & (self.n_slots - 1)
        run = min(want, self.n_slots - j0)
        free = seq[j0:j0 + run] == (
            pos + np.arange(run, dtype=np.uint64))
        k = int(run if free.all() else np.argmin(free))
        return pos, j0, k

    def _publish(self, seq, pos: int, j0: int, k: int) -> None:
        if k > 1:
            seq[j0 + 1:j0 + k] = pos + 1 + np.arange(1, k, dtype=np.uint64)
        seq[j0] = pos + 1                       # the gate
        self._tail = pos + k
        self._store_u64(_OFF_TAIL, self._tail)

    def try_push_records(self, recs) -> int:
        """Publish a FIFO prefix of ``recs`` — an (m, rec_bytes) uint8
        matrix of fixed-size records — in one bulk copy + one gate
        store.  Returns how many were pushed (0 when full); never
        writes a partial record."""
        if self._closed:
            raise RingClosed("ring closed")
        recs = np.ascontiguousarray(recs, np.uint8)
        if recs.ndim != 2:
            raise ValueError(
                f"records must be an (m, rec_bytes) matrix, "
                f"got shape {recs.shape}")
        m, rec_bytes = recs.shape
        if rec_bytes > self.slot_bytes:
            raise ValueError(
                f"record of {rec_bytes} bytes exceeds slot capacity "
                f"{self.slot_bytes}; oversized messages must be rejected "
                "at the codec layer, not silently truncated")
        if m == 0:
            return 0
        seq, lenv, payload = self._views()
        pos, j0, k = self._free_run(seq, m)
        if k == 0:
            return 0
        payload[j0:j0 + k, :rec_bytes] = recs[:k]
        lenv[j0:j0 + k] = rec_bytes
        self._publish(seq, pos, j0, k)
        return k

    def try_push_many(self, payloads: List[bytes]) -> int:
        """Variable-length sibling of :meth:`try_push_records`: pushes
        a FIFO prefix of ``payloads`` with one gate store.  EVERY
        payload is length-validated before ANY slot is written, so an
        oversized record inside a batch raises without corrupting the
        sequence protocol or publishing a partial batch."""
        if self._closed:
            raise RingClosed("ring closed")
        for p in payloads:
            if len(p) > self.slot_bytes:
                raise ValueError(
                    f"payload of {len(p)} bytes exceeds slot capacity "
                    f"{self.slot_bytes}; oversized messages must be "
                    "rejected at the codec layer, not silently truncated")
        if not payloads:
            return 0
        seq, lenv, payload = self._views()
        pos, j0, k = self._free_run(seq, len(payloads))
        if k == 0:
            return 0
        for i in range(k):
            p = payloads[i]
            payload[j0 + i, :len(p)] = np.frombuffer(p, np.uint8)
            lenv[j0 + i] = len(p)
        self._publish(seq, pos, j0, k)
        return k

    def _push_all(self, pusher, total: int,
                  deadline_s: Optional[float] = None,
                  alive: Optional[callable] = None) -> None:
        """Drive a try_push_* callable until ``total`` records landed,
        with the spin-then-park wait counted ONCE per batch episode."""
        done = 0
        spins = 0
        sleep_s = _PARK_MIN_S
        parked = False
        while done < total:
            k = pusher(done)
            if k:
                done += k
                spins = 0
                continue
            spins += 1
            if spins < _SPIN_ITERS:
                continue
            if not parked:
                parked = True
                self._bump_u64(_OFF_PROD_PARKS)
            if alive is not None and not alive():
                raise RingClosed("consumer gone")
            if deadline_s is not None and time.monotonic() > deadline_s:
                raise RingClosed("push deadline exceeded")
            time.sleep(sleep_s)
            sleep_s = min(sleep_s * 2, _PARK_MAX_S)

    def push_records(self, recs,
                     deadline_s: Optional[float] = None,
                     alive: Optional[callable] = None) -> None:
        """Blocking fixed-size batch push.  A batch larger than the
        free slot run lands as several whole sub-batches (split at the
        wraparound / occupancy boundary, records never torn)."""
        recs = np.ascontiguousarray(recs, np.uint8)
        self._push_all(lambda done: self.try_push_records(recs[done:]),
                       recs.shape[0], deadline_s=deadline_s, alive=alive)

    def push_many(self, payloads: List[bytes],
                  deadline_s: Optional[float] = None,
                  alive: Optional[callable] = None) -> None:
        """Blocking variable-length batch push (validates every length
        up front; see :meth:`try_push_many`)."""
        for p in payloads:
            if len(p) > self.slot_bytes:
                raise ValueError(
                    f"payload of {len(p)} bytes exceeds slot capacity "
                    f"{self.slot_bytes}; oversized messages must be "
                    "rejected at the codec layer, not silently truncated")
        self._push_all(lambda done: self.try_push_many(payloads[done:]),
                       len(payloads), deadline_s=deadline_s, alive=alive)

    def _ready_run(self, seq, want: int):
        """(pos, j0, r): consumer-side length of the published run
        starting at the head, capped at ``want`` and the lap
        boundary."""
        pos = self._head
        j0 = pos & (self.n_slots - 1)
        run = min(want, self.n_slots - j0)
        if run <= 0:
            return pos, j0, 0
        ready = seq[j0:j0 + run] == (
            pos + 1 + np.arange(run, dtype=np.uint64))
        r = int(run if ready.all() else np.argmin(ready))
        return pos, j0, r

    def _recycle(self, seq, pos: int, j0: int, r: int) -> None:
        # Mirror of _publish: later slots recycle first, slot ``pos``'s
        # store is the gate — the producer claims slots strictly in
        # order, so no slot frees up before the whole batch is copied.
        n = self.n_slots
        if r > 1:
            seq[j0 + 1:j0 + r] = pos + n + np.arange(1, r, dtype=np.uint64)
        seq[j0] = pos + n
        self._head = pos + r
        self._store_u64(_OFF_HEAD, self._head)

    def try_pop_records(self, limit: int, rec_bytes: int) -> np.ndarray:
        """Pop up to ``limit`` fixed-size records in one gather; returns
        an owned (r, rec_bytes) uint8 matrix (possibly empty)."""
        if self._closed:
            raise RingClosed("ring closed")
        seq, lenv, payload = self._views()
        pos, j0, r = self._ready_run(seq, int(limit))
        if r == 0:
            return np.empty((0, rec_bytes), np.uint8)
        if not (lenv[j0:j0 + r] == rec_bytes).all():
            raise ValueError(
                f"fixed-size pop of {rec_bytes}-byte records found other "
                f"lengths {sorted(set(int(x) for x in lenv[j0:j0 + r]))} — "
                "producer/consumer codec mismatch")
        out = payload[j0:j0 + r, :rec_bytes].copy()
        self._recycle(seq, pos, j0, r)
        return out

    def try_pop_batch(self, limit: int = 64) -> List[bytes]:
        """Variable-length batch pop: up to ``limit`` payloads with one
        batched recycle (one gate store, not one per message)."""
        if self._closed:
            raise RingClosed("ring closed")
        seq, lenv, payload = self._views()
        pos, j0, r = self._ready_run(seq, int(limit))
        if r == 0:
            return []
        out = [bytes(payload[j0 + i, :int(lenv[j0 + i])]) for i in range(r)]
        self._recycle(seq, pos, j0, r)
        return out

    def pop_batch(self, limit: int = 64,
                  timeout_s: Optional[float] = None,
                  alive: Optional[callable] = None) -> List[bytes]:
        """Blocking variable-length batch pop; empty list on timeout.
        Parks once per empty episode and counts one wake per batch."""
        deadline = (time.monotonic() + timeout_s
                    if timeout_s is not None else None)
        spins = 0
        sleep_s = _PARK_MIN_S
        parked = False
        while True:
            out = self.try_pop_batch(limit)
            if out:
                if parked:
                    self._bump_u64(_OFF_WAKES)
                return out
            spins += 1
            if spins < _SPIN_ITERS:
                continue
            if not parked:
                parked = True
                self._bump_u64(_OFF_CONS_PARKS)
            if alive is not None and not alive():
                raise RingClosed("producer gone")
            if deadline is not None and time.monotonic() > deadline:
                return []
            time.sleep(sleep_s)
            sleep_s = min(sleep_s * 2, _PARK_MAX_S)
