"""Process-backed serving cell: GIL-free replicas over one mmap-shared
index.

`ReplicaSet(..., ClusterConfig(backend="process"))` swaps each
thread-backed `Replica` for a :class:`ProcessReplica` — a worker
process that mmaps the cell's saved base generation (one page-cache
copy fleet-wide), receives tickets over a binary shared-memory ring
(`ShmRing`), and follows policy/index publishes relayed over its
control pipe (`FollowerSystem`).  docs/cluster.md has the full
architecture section.
"""
from .follower import FollowerSystem
from .messages import (REQUEST_BYTES, decode_request, decode_response,
                       encode_request, encode_response, response_bytes)
from .replica import ProcessReplica
from .ring import RingClosed, RingFull, ShmRing
from .worker import WorkerSpec, worker_main

__all__ = ["FollowerSystem", "ProcessReplica", "REQUEST_BYTES",
           "RingClosed", "RingFull", "ShmRing", "WorkerSpec",
           "decode_request", "decode_response", "encode_request",
           "encode_response", "response_bytes", "worker_main"]
