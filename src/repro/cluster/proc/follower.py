"""Worker-side read replica of the live index.

A process-backed replica cannot reach into the parent's `LiveIndex` —
it follows it instead.  The parent relays every published
:class:`~repro.index.live.live_index.IndexEpoch` over the control
channel as a compact payload: ``(version, generation, gen_dir, ops)``.
The worker mmaps the base generation from ``gen_dir`` (zero-copy —
every worker in the cell maps the SAME physical pages the parent
wrote) and rebuilds the cheap in-memory :class:`DeltaSegment` from the
committed op log, then republishes the epoch into a local
`IndexEpochStore` **under the producer's version numbering**, so
staleness bounds and epoch-lag accounting mean the same thing on both
sides of the process boundary.  Gaps are legal (a respawned worker
jumps straight to the head epoch it is sent); duplicates — e.g. the
subscribe-time replay of an epoch the spawn spec already carried — are
skipped.

The serving read path (`EpochReadMixin`) is the exact code the
in-process `LiveRetrievalSystem` serves with; only the epoch *source*
differs.  What is NOT followed: query-log appends
(``append_queries``).  The follower serves the seed log; freshness
workloads that append queries need the thread backend today
(docs/cluster.md records the limitation).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence, Tuple

from repro.index.live.live_index import IndexEpochStore, IndexView
from repro.index.live.segments import BaseSegment, DeltaOp, DeltaSegment
from repro.index.live.system import EpochReadMixin
from repro.system import RetrievalSystem, SystemConfig

__all__ = ["FollowerSystem"]

#: Base generations kept mapped — the head epoch's plus the previous
#: one, so a pinned view keeps working across one merge relay.
_BASES_KEPT = 2


class FollowerSystem(EpochReadMixin, RetrievalSystem):
    """`RetrievalSystem` whose index epochs arrive over IPC.

    ``base_dir`` is the PRISTINE corpus-built generation the parent
    saved once for the whole cell: the deterministic query log, idf
    table and env shapes are derived from it, so they are bit-identical
    to the parent's regardless of how many merges have happened by the
    time this worker (re)spawns.  ``init_epoch`` is the head epoch at
    spawn time, applied before the first query is served.
    """

    def __init__(self, cfg: SystemConfig, base_dir, *,
                 capacity_docs: int,
                 init_epoch: Tuple[int, int, str, Sequence[DeltaOp]],
                 staleness_bound: int = 64):
        pristine = BaseSegment.load(base_dir)
        super().__init__(cfg, index=pristine.index)
        bd = pristine.index.block_docs
        if capacity_docs % bd != 0:
            raise ValueError(f"capacity_docs {capacity_docs} not a "
                             f"multiple of block_docs {bd}")
        self.capacity_docs = capacity_docs
        self.capacity_blocks = capacity_docs // bd
        # Fixed shapes across epochs, same as LiveRetrievalSystem.
        self.env_cfg = dataclasses.replace(self.env_cfg,
                                           n_blocks=self.capacity_blocks)
        self._bases: "OrderedDict[str, BaseSegment]" = OrderedDict()
        self._store = IndexEpochStore(staleness_bound=staleness_bound)
        self._init_epoch_reader()
        version, generation, gen_dir, ops = init_epoch
        base = self._load_base(gen_dir)
        delta = DeltaSegment(base, list(ops))
        view = IndexView(base, delta, capacity_docs)
        self._store.publish(view, generation, ops=ops, version=version)
        self.static_rank, self.doc_len = self._epoch_planes(
            self._store.snapshot())

    # ----------------------------------------------------------- epoching
    @property
    def index_epoch_store(self) -> IndexEpochStore:
        return self._store

    @property
    def index_epoch(self) -> int:
        return self._store.version

    def apply_epoch(self, version: int, generation: int, gen_dir,
                    ops: Sequence[DeltaOp]) -> int:
        """Install one relayed epoch; returns the local head version.
        Out-of-order or duplicate relays (≤ the local head) are skipped
        — the relay stream is monotone per producer, but a respawn's
        spec and the subscribe replay can both carry the same head."""
        if version <= self._store.version:
            return self._store.version
        base = self._load_base(gen_dir)
        delta = DeltaSegment(base, list(ops))
        view = IndexView(base, delta, self.capacity_docs)
        return self._store.publish(view, generation, ops=ops,
                                   version=version)

    def _load_base(self, gen_dir) -> BaseSegment:
        key = str(gen_dir)
        base = self._bases.get(key)
        if base is None:
            base = BaseSegment.load(gen_dir)      # np.memmap, mode="r"
            self._bases[key] = base
            while len(self._bases) > _BASES_KEPT:
                self._bases.popitem(last=False)
        else:
            self._bases.move_to_end(key)
        return base
