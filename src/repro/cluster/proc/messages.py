"""Fixed-layout binary codecs for the process cell's data plane.

Requests and responses cross the router↔worker boundary through
`ShmRing` slots as packed structs — no pickle on the hot path.  Slot
capacity is fixed at ring creation, so the response codec is sized for
the engine's ``keep`` (top-k width) and anything larger is rejected at
encode time (the ring raises before a partial write can happen).

Control-plane traffic (policy snapshots, index epochs, worker stats)
is low-rate and structurally rich; it travels pickled over the
worker's `multiprocessing.Pipe` instead — see
`repro.cluster.proc.worker` for the message grammar.
"""
from __future__ import annotations

import struct
from typing import Optional, Tuple, Union

import numpy as np

from repro.cluster.admission import Shed
from repro.serving import ServiceLevel
from repro.serving.engine import ServeResponse

__all__ = ["REQUEST_BYTES", "REQ_DTYPE", "decode_request",
           "decode_request_block", "decode_response", "encode_request",
           "encode_request_block", "encode_response", "response_bytes"]

# ticket u64 | qid i64 | level i32 | category i32 | trace_root u64
# trace_root is the ticket's root span id (0 = tracing off): the trace
# context that rides the data plane so worker-side spans can join the
# parent's per-ticket Perfetto track (docs/observability.md).
_REQ = struct.Struct("<QqiiQ")
REQUEST_BYTES = _REQ.size

# The same record as a packed numpy dtype: a request SLAB is one
# (n, REQUEST_BYTES) uint8 matrix built/read in a single view, so the
# batch ring paths (`ShmRing.push_records`/`try_pop_records`) move B
# tickets per memcpy.  Field-for-field identical to _REQ — pinned by an
# assert here and a codec-parity test in tier-1.
REQ_DTYPE = np.dtype([("ticket", "<u8"), ("qid", "<i8"),
                      ("level", "<i4"), ("category", "<i4"),
                      ("trace_root", "<u8")])
assert REQ_DTYPE.itemsize == REQUEST_BYTES

# ticket u64 | qid i64 | category i32 | level i32 | status u8 | cached u8
# | pad u16 | u i32 | cand_cnt i32 | policy_version i32 | index_epoch i32
# | n_docs i32 | latency f64 | reason char[48]
_RESP_HDR = struct.Struct("<QqiiBBHiiiiid48s")
_REASON_BYTES = 48

_STATUS_OK = 0
_STATUS_SHED = 1

Result = Union[ServeResponse, Shed]


def response_bytes(keep: int) -> int:
    """Slot payload size for responses carrying up to ``keep`` docs."""
    return _RESP_HDR.size + keep * 8          # keep × (i32 id + f32 score)


# ------------------------------------------------------------- requests
def encode_request(ticket_id: int, qid: int, level: ServiceLevel,
                   category: int, trace_root: int = 0) -> bytes:
    return _REQ.pack(ticket_id, qid, int(level), category, trace_root)


def decode_request(payload: bytes) -> Tuple[int, int, ServiceLevel, int, int]:
    ticket_id, qid, level, category, trace_root = _REQ.unpack(payload)
    return ticket_id, qid, ServiceLevel(level), category, trace_root


def encode_request_block(tickets, qids, levels, categories,
                         trace_roots=None) -> np.ndarray:
    """Pack a whole request slab into one (n, REQUEST_BYTES) uint8
    matrix — five column stores instead of n struct packs."""
    n = len(tickets)
    block = np.empty(n, REQ_DTYPE)
    block["ticket"] = np.asarray(tickets, np.uint64)
    block["qid"] = np.asarray(qids, np.int64)
    block["level"] = np.asarray(levels, np.int32)
    block["category"] = np.asarray(categories, np.int32)
    block["trace_root"] = (0 if trace_roots is None
                           else np.asarray(trace_roots, np.uint64))
    return block.view(np.uint8).reshape(n, REQUEST_BYTES)


def decode_request_block(recs: np.ndarray) -> np.ndarray:
    """Inverse of :meth:`encode_request_block`: an (r, REQUEST_BYTES)
    uint8 matrix (e.g. from ``ShmRing.try_pop_records``) viewed as a
    structured array — fields are columns, no per-record unpack."""
    recs = np.ascontiguousarray(recs, np.uint8)
    return recs.reshape(-1).view(REQ_DTYPE)


# ------------------------------------------------------------ responses
def encode_response(ticket_id: int, result: Result, keep: int) -> bytes:
    if isinstance(result, Shed):
        reason = result.reason.encode("utf-8")[:_REASON_BYTES]
        return _RESP_HDR.pack(
            ticket_id, result.qid, result.category, 0, _STATUS_SHED,
            0, 0, 0, 0, 0, 0, 0, float(result.est_u), reason)
    r = result
    ids = np.asarray(r.doc_ids, dtype=np.int32)
    scores = np.asarray(r.scores, dtype=np.float32)
    n = ids.shape[0]
    if n > keep:
        raise ValueError(f"response carries {n} docs but the ring was "
                         f"sized for keep={keep}")
    hdr = _RESP_HDR.pack(
        ticket_id, r.qid, r.category, int(r.level), _STATUS_OK,
        1 if r.cached else 0, 0, int(r.u), int(r.cand_cnt),
        int(r.policy_version), int(r.index_epoch), n,
        float(r.latency_s), b"")
    return hdr + ids.tobytes() + scores.tobytes()


def decode_response(payload: bytes) -> Tuple[int, Result]:
    (ticket_id, qid, category, level, status, cached, _pad, u, cand_cnt,
     policy_version, index_epoch, n, lat_or_est_u,
     reason) = _RESP_HDR.unpack_from(payload)
    if status == _STATUS_SHED:
        return ticket_id, Shed(qid, category, lat_or_est_u,
                               reason.rstrip(b"\x00").decode("utf-8"))
    off = _RESP_HDR.size
    ids = np.frombuffer(payload, np.int32, count=n, offset=off).copy()
    scores = np.frombuffer(payload, np.float32, count=n,
                           offset=off + 4 * n).copy()
    return ticket_id, ServeResponse(
        request_id=ticket_id, qid=qid, category=category,
        doc_ids=ids, scores=scores, u=u, cand_cnt=cand_cnt,
        cached=bool(cached), latency_s=lat_or_est_u,
        policy_version=policy_version, index_epoch=index_epoch,
        level=ServiceLevel(level))
