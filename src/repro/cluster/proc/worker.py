"""Worker process: one `ServeEngine` behind two shared-memory rings.

Spawned (never forked — JAX is fork-unsafe) with a :class:`WorkerSpec`
that carries everything needed to rebuild the serving state
deterministically:

- the `SystemConfig` (corpus + query log regenerate bit-identically),
- the path of the cell's saved base generation, opened via
  ``np.memmap`` so N workers map ONE physical copy of the postings,
- the trained L1 parameters / state bins / Q-config (host arrays),
- the head policy snapshot and (live cells) the head index epoch at
  spawn time, applied before the first ticket is served.

The main loop mirrors `repro.cluster.replica.Replica._run`: drain
control messages (policy/epoch relays — staleness is enforced HERE, by
the worker-local stores), pop request records off the inbound ring,
submit them with the same shed/retry semantics the thread replica uses,
flush when the ring runs dry (latency path) or step full buckets
otherwise, then push fixed-layout response records back.  The worker
also stamps a heartbeat and publishes its engine queue depth into the
ring header, which is the parent-side router's load signal.

Observability across the boundary: when the spec enables tracing, the
worker opens a ``worker`` span on the ticket's track (the trace-root
id rides the request record) and passes it to ``engine.submit`` so the
engine's queue/batch/execute/respond children land on the SAME
Perfetto row the parent's admit/ring spans live on.  Finished entries
ship as deltas piggybacked on stats replies (and a periodic
unsolicited stats message, which doubles as the freshness feed for
postmortem bundles); the parent rebases them with the clock offset it
measured from the ``ping``→``pong`` handshake at startup.
"""
from __future__ import annotations

import dataclasses
import os
import time
import traceback
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from repro.serving.levels import ServiceLevel

from .messages import (REQUEST_BYTES, decode_request_block, encode_response)
from .ring import RingClosed, ShmRing

__all__ = ["WorkerSpec", "worker_main"]

#: Per-iteration cap on ring pops — control messages and completions
#: must keep flowing under a request flood.
_DRAIN_LIMIT = 256
_IDLE_WAIT_S = 0.002
#: Unsolicited stats/trace cadence: keeps the parent's last-known
#: metrics + trace tail fresh enough that a SIGKILL's postmortem
#: bundle holds recent state, not just whatever stats() last pulled.
_STATS_INTERVAL_S = 1.0


@dataclasses.dataclass
class WorkerSpec:
    """Everything a worker needs to reconstruct its replica state."""
    replica_idx: int
    sys_cfg: Any                      # repro.system.SystemConfig
    base_dir: str                     # pristine corpus-built generation
    live: bool                        # follow relayed index epochs?
    capacity_docs: Optional[int]
    init_epoch: Optional[Tuple]       # (version, generation, gen_dir, ops)
    init_policy: Tuple                # (version, policies, fallbacks)
    l1_params: Any
    bins: Any
    qcfg: Any
    engine_cfg: Any                   # repro.serving.EngineConfig
    policy_staleness_bound: int
    index_staleness_bound: int
    req_ring: Tuple[str, int, int]    # (shm name, n_slots, slot_bytes)
    resp_ring: Tuple[str, int, int]
    trace: bool = False               # record worker-side spans
    trace_capacity: int = 16384       # worker TraceLog ring size


def worker_main(spec: WorkerSpec, conn) -> None:
    """Process entry point (spawn target — must be module-level)."""
    try:
        _serve(spec, conn)
    except BaseException:                         # noqa: BLE001
        # The parent's collector turns this into a respawn (or a shed
        # of the outstanding tickets once restarts are exhausted).
        try:
            conn.send(("died", traceback.format_exc()))
        except Exception:                         # noqa: BLE001
            pass
        raise
    finally:
        try:
            conn.close()
        except Exception:                         # noqa: BLE001
            pass


def _build_system(spec: WorkerSpec):
    # Imports happen here, inside the spawned child, so module import
    # of proc/ stays light in the parent.
    from repro.index.live.segments import BaseSegment
    from repro.system import RetrievalSystem

    from .follower import FollowerSystem

    if spec.live:
        system = FollowerSystem(
            spec.sys_cfg, spec.base_dir,
            capacity_docs=spec.capacity_docs,
            init_epoch=spec.init_epoch,
            staleness_bound=spec.index_staleness_bound)
    else:
        base = BaseSegment.load(spec.base_dir)    # np.memmap, shared
        system = RetrievalSystem(spec.sys_cfg, index=base.index)
    # Trained artifacts travel with the spec — the worker must serve
    # with the parent's exact L1/bins, not retrain its own.
    system.l1_params = spec.l1_params
    system.bins = spec.bins
    system.qcfg = spec.qcfg
    return system


def _serve(spec: WorkerSpec, conn) -> None:
    from repro.obs import NULL_TRACER, TraceLog, Tracer
    from repro.policies import PolicyStore
    from repro.serving import AdmissionError, CacheOnlyMiss, ServeEngine
    from repro.serving.engine import (SLAB_ADMISSION_REJECT,
                                      SLAB_CACHED_ONLY_MISS)
    from repro.core.versioned import StaleVersionError

    req = ShmRing.attach(*spec.req_ring)
    resp = ShmRing.attach(*spec.resp_ring)
    system = _build_system(spec)

    store = PolicyStore(staleness_bound=spec.policy_staleness_bound)
    version, policies, fallbacks = spec.init_policy
    store.publish(policies, fallbacks=fallbacks, version=version)
    tracer = (Tracer(log=TraceLog(capacity=spec.trace_capacity))
              if spec.trace else NULL_TRACER)
    engine = ServeEngine(system, store, spec.engine_cfg, tracer=tracer)
    keep = spec.engine_cfg.keep

    # engine rid -> (ticket id, qid, category, worker span): enough to
    # shed outstanding work explicitly when a batch poisons the engine.
    rid2ticket: Dict[int, Tuple[int, int, int, Any]] = {}
    retry: deque = deque()                        # stale-raced submissions
    stopping = False
    drain = True
    failures = 0
    max_failures = 3
    trace_cursor = 0

    def trace_delta() -> list:
        nonlocal trace_cursor
        if not tracer.enabled:
            return []
        entries, trace_cursor = tracer.log.drain_since(trace_cursor)
        return entries

    def stats_msg() -> tuple:
        return ("stats", engine.summary(),
                _metrics_with_rings(engine, req, resp), trace_delta())

    def shed(ticket_id: int, qid: int, category: int, reason: str,
             span=None) -> None:
        if span:
            span.end(error=reason)
        resp.push(encode_response(
            ticket_id, _mk_shed(qid, category, reason), keep))

    def shed_outstanding(reason: str) -> None:
        engine.cancel([rid for rid in rid2ticket])
        for rid, (tid, qid, category, span) in list(rid2ticket.items()):
            shed(tid, qid, category, reason, span)
        rid2ticket.clear()
        while retry:
            tid, qid, _level, category, span = retry.popleft()
            shed(tid, qid, category, reason, span)

    def submit_one(ticket_id: int, qid: int, level: ServiceLevel,
                   category: int, span=None) -> None:
        try:
            rid = engine.submit(qid, level, span=span)
        except AdmissionError:
            shed(ticket_id, qid, category, "replica_queue_full", span)
            return
        except CacheOnlyMiss:
            shed(ticket_id, qid, category, "cached_only_miss", span)
            return
        except StaleVersionError:
            # A relay raced between refresh and the staleness check —
            # retry after the next control drain applies the publish.
            retry.append((ticket_id, qid, level, category, span))
            return
        except Exception as e:                    # noqa: BLE001
            shed(ticket_id, qid, category,
                 f"replica_error:{type(e).__name__}", span)
            return
        rid2ticket[rid] = (ticket_id, qid, category, span)
        r = engine.take_response(rid)             # cache hits are inline
        if r is not None:
            push_response(rid, r)

    def submit_block(recs) -> None:
        """Slab submit for an untraced request block: one engine pass
        (vectorized admission + one slab span), per-record status
        reconciliation — same shed semantics as :func:`submit_one`."""
        try:
            rids, statuses = engine.submit_slab(
                recs["qid"], levels=recs["level"])
        except StaleVersionError:
            # Raised before any request id was assigned: the whole
            # block retries after the next control drain.
            for rec in recs:
                retry.append((int(rec["ticket"]), int(rec["qid"]),
                              ServiceLevel(int(rec["level"])),
                              int(rec["category"]), None))
            return
        except Exception:                         # noqa: BLE001
            # Per-record fallback isolates a poisoned request.
            for rec in recs:
                submit_one(int(rec["ticket"]), int(rec["qid"]),
                           ServiceLevel(int(rec["level"])),
                           int(rec["category"]))
            return
        done = []
        for i, rec in enumerate(recs):
            tid, qid, cat = (int(rec["ticket"]), int(rec["qid"]),
                             int(rec["category"]))
            st = int(statuses[i])
            if st == SLAB_ADMISSION_REJECT:
                shed(tid, qid, cat, "replica_queue_full")
            elif st == SLAB_CACHED_ONLY_MISS:
                shed(tid, qid, cat, "cached_only_miss")
            else:
                rid = int(rids[i])
                rid2ticket[rid] = (tid, qid, cat, None)
                r = engine.take_response(rid)     # cache hits are inline
                if r is not None:
                    done.append((rid, r))
        if done:
            push_responses(done)

    def push_response(rid: int, r) -> None:
        tid, _qid, _cat, span = rid2ticket.pop(rid)
        resp.push(encode_response(tid, r, keep))
        if span:
            # The worker span covers decode → response-on-ring; its
            # engine children (queue/batch/execute/respond) are already
            # in the log on the same ticket track.
            span.end(cached=r.cached, u=r.u)

    def push_responses(done: List[Tuple[int, Any]]) -> None:
        """Batch variant: B encoded responses cross the ring with one
        sequence-word publish (`ShmRing.push_many`)."""
        payloads, ended = [], []
        for rid, r in done:
            tid, _qid, _cat, span = rid2ticket.pop(rid)
            payloads.append(encode_response(tid, r, keep))
            if span:
                ended.append((span, r))
        resp.push_many(payloads)
        for span, r in ended:
            span.end(cached=r.cached, u=r.u)

    def handle_control(msg) -> None:
        nonlocal stopping, drain
        kind = msg[0]
        if kind == "policy":
            _, ver, pols, fbs = msg
            if ver > store.version:
                store.publish(pols, fallbacks=fbs, version=ver)
            conn.send(("applied", "policy", store.version))
        elif kind == "epoch":
            _, ver, generation, gen_dir, ops = msg
            head = system.apply_epoch(ver, generation, gen_dir, ops)
            conn.send(("applied", "epoch", head))
        elif kind == "warmup":
            conn.send(("warmed", engine.warmup()))
        elif kind == "stats":
            conn.send(stats_msg())
        elif kind == "ping":
            # Clock handshake: echo the parent's stamp alongside our
            # own clock reading; the parent halves the round trip and
            # keeps the minimum-RTT offset sample (NTP's trick).
            conn.send(("pong", msg[1], time.perf_counter()))
        elif kind == "stop":
            stopping, drain = True, bool(msg[1])

    conn.send(("ready", os.getpid(), engine.policy_version,
               engine.index_epoch))
    last_stats = time.monotonic()

    while True:
        progressed = False
        while conn.poll():
            handle_control(conn.recv())
            progressed = True
        if stopping and not drain:
            # Fast shutdown: abandon with explicit sheds, never serve.
            shed_outstanding("replica_shutdown")
            break
        raw = req.try_pop_records(_DRAIN_LIMIT, REQUEST_BYTES)
        if raw.shape[0]:
            progressed = True
            recs = decode_request_block(raw)
            if (raw.shape[0] > 1 and not tracer.enabled
                    and not recs["trace_root"].any()):
                submit_block(recs)                # slab fast path
            else:
                for rec in recs:
                    trace_root = int(rec["trace_root"])
                    span = (tracer.span("worker",
                                        track=f"ticket #{trace_root}",
                                        qid=int(rec["qid"]))
                            if trace_root and tracer.enabled else None)
                    submit_one(int(rec["ticket"]), int(rec["qid"]),
                               ServiceLevel(int(rec["level"])),
                               int(rec["category"]), span)
        if retry:
            batch = list(retry)
            retry.clear()
            for item in batch:
                submit_one(*item)
        try:
            if req.occupancy() == 0:
                engine.flush()                    # latency path
            else:
                engine.step()                     # full buckets only
            failures = 0
        except StaleVersionError:
            pass                                  # re-served after refresh
        except Exception as e:                    # noqa: BLE001
            failures += 1
            if failures >= max_failures:
                shed_outstanding(f"replica_error:{type(e).__name__}")
                failures = 0
        done = [(rid, r) for rid in list(rid2ticket)
                if (r := engine.take_response(rid)) is not None]
        if done:
            push_responses(done)
            progressed = True
        req.set_depth_hint(engine.queue_depth + engine.inflight
                           + len(retry))
        req.stamp_heartbeat()
        if time.monotonic() - last_stats >= _STATS_INTERVAL_S:
            # Unsolicited: keeps the parent's postmortem view fresh.
            conn.send(stats_msg())
            last_stats = time.monotonic()
        if (stopping and not rid2ticket and not retry
                and req.occupancy() == 0):
            break
        if not progressed:
            # Park on the control pipe: wakes instantly for relays,
            # times out quickly enough to poll the request ring.
            conn.poll(_IDLE_WAIT_S)

    # Final state for the parent: the post-mortem stats/metrics (and
    # the trace tail) the obs plane folds after the worker is gone.
    try:
        conn.send(stats_msg())
        conn.send(("stopped",))
    except Exception:                             # noqa: BLE001
        pass
    req.close()
    resp.close()


def _mk_shed(qid: int, category: int, reason: str):
    from repro.cluster.admission import Shed
    return Shed(qid, category, 0.0, reason)


def _metrics_with_rings(engine, req: ShmRing, resp: ShmRing) -> dict:
    snap = engine.telemetry.registry.snapshot()
    # Ring contention counters ride the same mergeable snapshot: the
    # request ring's consumer side and the response ring's producer
    # side are this worker's (the parent owns the other two halves).
    for ring, ring_label in ((req, "req"), (resp, "resp")):
        for stat, v in ring.park_stats().items():
            snap[f"ring.{stat}{{ring={ring_label}}}"] = {
                "type": "counter", "value": int(v)}
        # Depth-style gauge: fleet ring occupancy sums across workers.
        snap[f"ring.occupancy{{ring={ring_label}}}"] = {
            "type": "gauge", "value": float(ring.occupancy()),
            "max": float(ring.occupancy()), "agg": "sum"}
    return snap
