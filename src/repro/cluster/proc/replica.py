"""`ProcessReplica`: the parent-side handle of one worker process.

Protocol-compatible with `repro.cluster.replica.Replica` — the
`ReplicaSet` talks to both through the same surface (enqueue / depth /
cache_has / warmup / metrics_snapshot / policy_version / index_epoch /
summary) and never notices which backend answers.  The differences
live behind that surface:

- tickets travel as fixed-layout records over a pair of SPSC
  shared-memory rings (`proc.ring` / `proc.messages`) — the enqueue
  hop is a memcpy, not a pickle;
- policy snapshots and index epochs are RELAYED over the worker's
  control pipe and applied by worker-local stores under the producer's
  version numbering (staleness is enforced worker-side);
- `cache_has` answers from a parent-side mirror: the (policy version,
  index epoch) each key's last response was produced under, checked
  against the worker's last-acked versions.  It is approximate the
  same way the thread replica's probe is — an eviction can race it,
  and the worker's `cached_only_miss` shed is the backstop;
- a dead worker (crash, SIGKILL) is respawned with FRESH rings and a
  fresh state snapshot, bounded by ``max_restarts`` exactly like
  `repro.distributed.fault_tolerance.FaultToleranceConfig` bounds
  trainer restarts; outstanding tickets are requeued to the new
  worker, and `ClusterTicket.complete`'s first-wins contract absorbs
  any duplicate answer that slips through.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, Optional, Tuple

from repro.cluster.admission import Shed
from repro.cluster.replica import ClusterTicket, Result
from repro.obs import NULL_TRACER, Tracer, adjust_remote_entries

from .messages import (REQUEST_BYTES, decode_response, encode_request,
                       encode_request_block, response_bytes)
from .ring import RingClosed, ShmRing

__all__ = ["ProcessReplica"]

_READY_TIMEOUT_S = 600.0      # child imports jax + rebuilds the system
_REPLY_TIMEOUT_S = 600.0      # warmup compiles on the worker
_DEAD_DEPTH = 1 << 30         # router poison for an exhausted replica
_N_PINGS = 4                  # clock-handshake samples per (re)spawn
_TRACE_TAIL = 8192            # merged worker trace entries kept parent-side


class ProcessReplica:
    def __init__(self, idx: int, spec_factory: Callable,
                 on_complete: Optional[Callable[[ClusterTicket, Result], None]] = None,
                 *, keep: int, ring_slots: int = 64,
                 max_restarts: int = 2,
                 cache_mirror_capacity: int = 4096,
                 drain_timeout_s: float = 120.0,
                 tracer: Tracer = NULL_TRACER,
                 recorder=None):
        self.idx = idx
        self.spec_factory = spec_factory
        self.on_complete = on_complete
        self.keep = keep
        self.ring_slots = ring_slots
        self.max_restarts = max_restarts
        self.drain_timeout_s = drain_timeout_s
        self.tracer = tracer
        #: obs.FlightRecorder (optional): state-transition events plus
        #: the postmortem bundle written when a dead worker is salvaged.
        self.recorder = recorder

        self._mp = mp.get_context("spawn")        # fork is unsafe with JAX
        self._proc: Optional[mp.process.BaseProcess] = None
        self._req: Optional[ShmRing] = None
        self._resp: Optional[ShmRing] = None
        self._conn = None

        self._mu = threading.Lock()
        self._conn_mu = threading.Lock()
        self._outstanding: Dict[int, ClusterTicket] = {}
        self._next_tid = 0
        self._cache_mirror: "OrderedDict[object, Tuple[int, int]]" = \
            OrderedDict()
        self._mirror_cap = cache_mirror_capacity
        self._stopping = False
        self._dead = False                        # restarts exhausted
        self._worker_stopped = False
        self._policy_version = 0
        self._index_epoch = 0
        self._last_summary: dict = {}
        self._last_metrics: dict = {}
        self._stats_evt = threading.Event()
        self._warm_evt = threading.Event()
        self._warm_result = 0
        self._last_death: Optional[str] = None    # worker's last traceback
        self._collector: Optional[threading.Thread] = None
        self._collector_exit = threading.Event()
        # Cross-process trace collection: worker entry deltas arrive on
        # the control pipe and are rebased here — onto the parent clock
        # via the ping-handshake offset (min-RTT sample wins) and into
        # a per-worker id range so span ids never collide.
        self._clock_offset = 0.0
        self._offset_rtt = float("inf")
        self._trace_tail: deque = deque(maxlen=_TRACE_TAIL)
        self.last_bundle_path = None
        self.n_enqueued = 0
        self.n_completed = 0
        self.n_restarts = 0
        self.worker_pid: Optional[int] = None

    # ------------------------------------------------------------- control
    def start(self) -> "ProcessReplica":
        if self._proc is not None:
            raise RuntimeError(f"process replica {self.idx} already started")
        self._spawn()
        self._collector = threading.Thread(
            target=self._collect_loop, name=f"proc-replica-{self.idx}",
            daemon=True)
        self._collector.start()
        return self

    def _spawn(self) -> None:
        """Create rings + pipe, spawn the worker, block until ready."""
        self._req = ShmRing.create(self.ring_slots, REQUEST_BYTES)
        self._resp = ShmRing.create(self.ring_slots,
                                    response_bytes(self.keep))
        parent_conn, child_conn = self._mp.Pipe()
        self._conn = parent_conn
        spec = self.spec_factory(
            self.idx,
            (self._req.name, self.ring_slots, REQUEST_BYTES),
            (self._resp.name, self.ring_slots, response_bytes(self.keep)))
        from .worker import worker_main
        self._proc = self._mp.Process(
            target=worker_main, args=(spec, child_conn),
            name=f"replica-worker-{self.idx}", daemon=True)
        self._proc.start()
        child_conn.close()                        # parent keeps one end
        deadline = time.monotonic() + _READY_TIMEOUT_S
        while True:
            if self._conn.poll(0.2):
                msg = self._conn.recv()
                if msg[0] == "ready":
                    _, pid, pv, epoch = msg
                    with self._mu:
                        self.worker_pid = pid
                        self._policy_version = pv
                        self._index_epoch = epoch
                        self._worker_stopped = False
                        # Fresh worker, fresh handshake: forget the old
                        # offset sample so a respawn re-estimates.
                        self._offset_rtt = float("inf")
                    if self.tracer.enabled:
                        # Clock handshake (async — pongs land in the
                        # collector): several samples, min RTT wins.
                        for _ in range(_N_PINGS):
                            self._send(("ping", time.perf_counter()))
                    if getattr(self, "_pending_warmup", False):
                        self._pending_warmup = False
                        self._send(("warmup",))   # fire-and-forget pre-start
                    return
                if msg[0] == "died":
                    raise RuntimeError(
                        f"replica {self.idx} worker died during spawn:\n"
                        f"{msg[1]}")
            elif not self._proc.is_alive():
                raise RuntimeError(
                    f"replica {self.idx} worker exited before ready "
                    f"(exitcode {self._proc.exitcode})")
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"replica {self.idx} worker not ready after "
                    f"{_READY_TIMEOUT_S}s")

    def stop(self, drain: bool = True) -> None:
        with self._mu:
            if self._stopping:
                return
            self._stopping = True
        if self._alive():
            self._send(("stop", bool(drain)))
            if drain:
                deadline = time.monotonic() + self.drain_timeout_s
                while time.monotonic() < deadline:
                    with self._mu:
                        if not self._outstanding and self._worker_stopped:
                            break
                    if not self._alive() and not self._conn_has_data():
                        break
                    time.sleep(0.005)
        self._collector_exit.set()
        if self._collector is not None:
            self._collector.join(timeout=30.0)
        self._drain_responses()
        self._drain_conn()
        self._shed_outstanding("replica_shutdown")
        if self._proc is not None:
            self._proc.join(timeout=10.0)
            if self._proc.is_alive():
                self._proc.terminate()
                self._proc.join(timeout=10.0)
        self._close_channels()

    def _close_channels(self) -> None:
        for ring in (self._req, self._resp):
            if ring is not None:
                ring.close()
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass

    # -------------------------------------------------------------- ingest
    def enqueue(self, ticket: ClusterTicket) -> None:
        ticket.replica = self.idx
        tid = None
        with self._mu:
            if self._dead:
                reason = "replica_dead"
            elif self._stopping:
                reason = "replica_shutdown"
            else:
                reason = None
                tid = self._next_tid
                self._next_tid += 1
                self._outstanding[tid] = ticket
                self.n_enqueued += 1
        if tid is None:
            self._finish(ticket, Shed(ticket.qid, ticket.category,
                                      ticket.est_u, reason))
            return
        if ticket.inbox_span:
            # The parent cannot observe worker-side pickup; the inbox
            # span covers route → ring push instead.
            ticket.inbox_span.end()
            ticket.inbox_span = None
        trace_root = 0
        if ticket.span:
            # Trace context rides the data plane: the worker opens its
            # span on track ``ticket #<trace_root>``, so its engine
            # children join this ticket's Perfetto row.  The parent-side
            # ring span (push → response pop) encloses everything the
            # worker records, which keeps the merged stack nested even
            # before clock-offset correction.
            trace_root = ticket.span.span_id
            ticket.ring_span = ticket.span.child("ring", replica=self.idx)
        payload = encode_request(tid, ticket.qid, ticket.level,
                                 ticket.category, trace_root)
        try:
            self._req.push(payload, alive=self._alive)
        except (RingClosed, ValueError, TypeError):
            # Worker died (or rings are being swapped) mid-push: the
            # ticket stays outstanding and the respawn path requeues it
            # on the fresh ring — double answers are absorbed by the
            # ticket's first-completion-wins contract.
            pass

    def enqueue_many(self, tickets) -> None:
        """Batch ingest: register the whole group under one lock, pack
        it as a request slab, and cross the ring in whole-batch
        memcpys (`ShmRing.push_records`).  Same failure contract as
        :meth:`enqueue` — a mid-push death leaves the group
        outstanding for the respawn requeue."""
        if not tickets:
            return
        tids = []
        with self._mu:
            if self._dead:
                reason = "replica_dead"
            elif self._stopping:
                reason = "replica_shutdown"
            else:
                reason = None
                for ticket in tickets:
                    ticket.replica = self.idx
                    tid = self._next_tid
                    self._next_tid += 1
                    self._outstanding[tid] = ticket
                    tids.append(tid)
                self.n_enqueued += len(tickets)
        if reason is not None:
            for ticket in tickets:
                ticket.replica = self.idx
                self._finish(ticket, Shed(ticket.qid, ticket.category,
                                          ticket.est_u, reason))
            return
        roots = None
        for i, ticket in enumerate(tickets):
            if ticket.inbox_span:
                ticket.inbox_span.end()
                ticket.inbox_span = None
            if ticket.span:
                if roots is None:
                    roots = [0] * len(tickets)
                roots[i] = ticket.span.span_id
                ticket.ring_span = ticket.span.child("ring",
                                                     replica=self.idx)
        block = encode_request_block(
            tids, [t.qid for t in tickets],
            [int(t.level) for t in tickets],
            [t.category for t in tickets], roots)
        try:
            self._req.push_records(block, alive=self._alive)
        except (RingClosed, ValueError, TypeError):
            pass                      # respawn requeues the group

    def _finish(self, ticket: ClusterTicket, result: Result) -> None:
        if ticket.ring_span:
            # Ends at response pop (or shed): the parent-side cover for
            # everything the worker recorded about this ticket.
            ticket.ring_span.end()
            ticket.ring_span = None
        if not ticket.complete(result):
            return                    # a requeue's duplicate answer
        with self._mu:
            self.n_completed += 1
        if self.on_complete is not None:
            self.on_complete(ticket, result)

    def depth(self) -> int:
        """Router load signal: records still in the request ring plus
        the worker's last-published engine depth (ring header hint)."""
        with self._mu:
            if self._dead:
                return _DEAD_DEPTH
        req = self._req
        if req is None:
            return 0
        try:
            return req.occupancy() + req.depth_hint()
        except (RingClosed, ValueError, TypeError):
            return 0                  # ring mid-swap during a respawn

    # ----------------------------------------------------------- protocol
    @property
    def policy_version(self) -> int:
        with self._mu:
            return self._policy_version

    @property
    def index_epoch(self) -> int:
        with self._mu:
            return self._index_epoch

    def cache_has(self, base_key) -> bool:
        with self._mu:
            entry = self._cache_mirror.get(base_key)
            return (entry is not None
                    and entry == (self._policy_version, self._index_epoch))

    def warmup(self) -> int:
        if self._proc is None:
            # not started yet — the worker warms right after spawn
            self._pending_warmup = True
            return 0
        self._warm_evt.clear()
        self._send(("warmup",))
        if not self._warm_evt.wait(_REPLY_TIMEOUT_S):
            raise TimeoutError(f"replica {self.idx} warmup timed out")
        return self._warm_result

    def metrics_snapshot(self) -> dict:
        self._refresh_stats()
        with self._mu:
            return dict(self._last_metrics)

    def summary(self) -> dict:
        self._refresh_stats()
        with self._mu:
            out = dict(self._last_summary)
            out.update(replica=self.idx, backend="process",
                       n_enqueued=self.n_enqueued,
                       n_completed=self.n_completed,
                       n_restarts=self.n_restarts,
                       worker_pid=self.worker_pid,
                       depth=0)
        out["depth"] = self.depth()
        return out

    def _refresh_stats(self, timeout_s: float = 10.0) -> None:
        if not self._alive():
            return                    # final pre-exit stats are cached
        self._stats_evt.clear()
        try:
            self._send(("stats",))
        except (OSError, BrokenPipeError):
            return
        self._stats_evt.wait(timeout_s)

    # -------------------------------------------------------------- relays
    def relay_policy(self, version: int, policies, fallbacks) -> None:
        if self._alive():
            self._send(("policy", version, policies, fallbacks))

    def relay_epoch(self, version: int, generation: int, gen_dir: str,
                    ops) -> None:
        if self._alive():
            self._send(("epoch", version, generation, gen_dir, ops))

    # ----------------------------------------------------------- collector
    def _alive(self) -> bool:
        p = self._proc
        return p is not None and p.is_alive()

    def _send(self, msg) -> None:
        with self._conn_mu:
            try:
                self._conn.send(msg)
            except (OSError, BrokenPipeError):
                pass                  # death is handled by the collector

    def _conn_has_data(self) -> bool:
        try:
            return self._conn.poll()
        except (OSError, BrokenPipeError):
            return False

    def _collect_loop(self) -> None:
        while not self._collector_exit.is_set():
            progressed = self._drain_responses()
            progressed |= self._drain_conn()
            if not self._alive():
                with self._mu:
                    stopping = self._stopping
                if stopping:
                    if not progressed:
                        break         # stop() finishes the teardown
                else:
                    self._handle_death()
            if not progressed:
                time.sleep(0.001)

    def _drain_responses(self) -> bool:
        resp = self._resp
        if resp is None:
            return False
        progressed = False
        try:
            for payload in resp.try_pop_batch(limit=self.ring_slots):
                progressed = True
                tid, result = decode_response(payload)
                with self._mu:
                    ticket = self._outstanding.pop(tid, None)
                    if (ticket is not None and ticket.cache_key is not None
                            and not isinstance(result, Shed)):
                        self._mirror_record(ticket.cache_key,
                                            result.policy_version,
                                            result.index_epoch)
                    if not isinstance(result, Shed):
                        # Responses are the freshest version signal the
                        # parent has between control acks.
                        self._policy_version = max(self._policy_version,
                                                   result.policy_version)
                        self._index_epoch = max(self._index_epoch,
                                                result.index_epoch)
                if ticket is not None:
                    self._finish(ticket, result)
        except (RingClosed, ValueError, TypeError):
            pass                      # ring closed mid-swap
        return progressed

    def _mirror_record(self, cache_key, policy_version: int,
                       index_epoch: int) -> None:
        """Note the versions ``cache_key``'s last response was produced
        under (LRU, bounded at ``_mirror_cap``).  Caller holds _mu."""
        self._cache_mirror[cache_key] = (policy_version, index_epoch)
        self._cache_mirror.move_to_end(cache_key)
        while len(self._cache_mirror) > self._mirror_cap:
            self._cache_mirror.popitem(last=False)

    def _drain_conn(self) -> bool:
        progressed = False
        while self._conn_has_data():
            try:
                msg = self._conn.recv()
            except (EOFError, OSError):
                break
            progressed = True
            kind = msg[0]
            if kind == "applied":
                _, what, version = msg
                with self._mu:
                    if what == "policy":
                        self._policy_version = max(self._policy_version,
                                                   version)
                    else:
                        self._index_epoch = max(self._index_epoch, version)
            elif kind == "stats":
                _, summary, snap, trace_entries = msg
                with self._mu:
                    self._last_summary = summary
                    self._last_metrics = snap
                if trace_entries:
                    self._ingest_trace(trace_entries)
                self._stats_evt.set()
            elif kind == "pong":
                # One clock-handshake sample: offset = midpoint of the
                # round trip minus the worker's stamp; the minimum-RTT
                # sample bounds the error by rtt/2 (NTP's estimator).
                _, t0, t_worker = msg
                t1 = time.perf_counter()
                rtt = t1 - t0
                with self._mu:
                    if rtt < self._offset_rtt:
                        self._offset_rtt = rtt
                        self._clock_offset = (t0 + t1) / 2.0 - t_worker
            elif kind == "warmed":
                self._warm_result = msg[1]
                self._warm_evt.set()
            elif kind == "stopped":
                with self._mu:
                    self._worker_stopped = True
            elif kind == "died":
                with self._mu:
                    self._last_death = msg[1]
        return progressed

    def _handle_death(self) -> None:
        """The worker is gone without a drain-stop: salvage whatever it
        pushed before dying, then respawn with fresh rings and requeue
        the rest — or, past ``max_restarts``, shed them explicitly."""
        self._drain_responses()
        self._drain_conn()
        # Postmortem bundle FIRST, while the salvaged state (last stats
        # + trace tail + event ring + traceback) is still coherent.
        if self.recorder is not None:
            self.recorder.record(
                "worker_dead", replica=self.idx, worker_pid=self.worker_pid,
                n_restarts=self.n_restarts,
                n_outstanding=len(self._outstanding))
            self._dump_postmortem("worker_dead")
        with self._mu:
            if self.n_restarts >= self.max_restarts:
                self._dead = True
        if self._dead:
            if self.recorder is not None:
                self.recorder.record("replica_dead", replica=self.idx,
                                     n_restarts=self.n_restarts)
            self._shed_outstanding("replica_dead")
            return
        with self._mu:
            self.n_restarts += 1
            # The new worker starts with an empty cache; mirror entries
            # for the dead one must not price CACHED_ONLY admissions.
            self._cache_mirror.clear()
        old_proc = self._proc
        self._close_channels()
        if old_proc is not None:
            old_proc.join(timeout=5.0)
        try:
            self._spawn()
        except Exception:                         # noqa: BLE001
            with self._mu:
                self._dead = True
            self._shed_outstanding("replica_dead")
            return
        if self.recorder is not None:
            self.recorder.record("worker_restart", replica=self.idx,
                                 worker_pid=self.worker_pid,
                                 n_restarts=self.n_restarts)
        # Requeue in ticket order; duplicate answers (the original
        # response raced the death detection) are absorbed by the
        # first-completion-wins ticket contract.
        with self._mu:
            pending = sorted(self._outstanding.items())
        for tid, ticket in pending:
            try:
                root = ticket.span.span_id if ticket.span else 0
                self._req.push(encode_request(tid, ticket.qid, ticket.level,
                                              ticket.category, root),
                               alive=self._alive)
            except RingClosed:
                return                # died again; next pass handles it

    def _shed_outstanding(self, reason: str) -> None:
        with self._mu:
            pending = list(self._outstanding.items())
            self._outstanding.clear()
        for _tid, ticket in pending:
            self._finish(ticket, Shed(ticket.qid, ticket.category,
                                      ticket.est_u, reason))

    # ---------------------------------------------------- observability
    def _ingest_trace(self, entries) -> None:
        """Rebase one worker trace delta into the parent's frame:
        shift onto the parent clock, move span ids into a per-worker
        range, and tag ticket-track entries with the worker pid (they
        must keep the parent's track name to share its Perfetto row)."""
        pid = self.worker_pid or 0
        with self._mu:
            dt = self._clock_offset
        adjusted = adjust_remote_entries(
            entries, dt=dt, id_offset=(pid & 0xFFFFFFFF) << 32,
            pid=pid, ticket_args={"wpid": pid})
        with self._mu:
            self._trace_tail.extend(adjusted)

    def trace_entries(self) -> list:
        """Rebased worker span entries (bounded tail, oldest first)."""
        with self._mu:
            return list(self._trace_tail)

    def clock_offset(self) -> Tuple[float, float]:
        """(offset_s, rtt_s) of the best handshake sample so far."""
        with self._mu:
            return self._clock_offset, self._offset_rtt

    def _dump_postmortem(self, reason: str):
        rec = self.recorder
        if rec is None:
            return None
        with self._mu:
            payload = {
                "reason": reason,
                "replica": self.idx,
                "backend": "process",
                "worker_pid": self.worker_pid,
                "n_restarts": self.n_restarts,
                "n_outstanding": len(self._outstanding),
                "death_traceback": self._last_death,
                "summary": dict(self._last_summary),
                "metrics": dict(self._last_metrics),
                "trace_tail": list(self._trace_tail),
            }
        path = rec.dump(f"postmortem-r{self.idx}", payload)
        if path is not None:
            self.last_bundle_path = path
        return path

    def health(self) -> dict:
        """Liveness + load signals for the statusz plane.  Heartbeat
        age comes from the ring header the worker stamps every loop
        (``time.monotonic`` — a system-wide clock, so parent-readable);
        ``pending`` folds ring occupancy with the worker's published
        engine depth so the watchdog can tell a parked idle consumer
        (stale heartbeat, nothing to do) from a wedged one."""
        with self._mu:
            dead = self._dead
            n_restarts = self.n_restarts
            pid = self.worker_pid
        alive = self._alive() and not dead
        h = {
            "backend": "process", "replica": self.idx, "alive": alive,
            "worker_pid": pid, "n_restarts": n_restarts,
            "heartbeat_age_s": None, "pending": 0,
        }
        req, resp = self._req, self._resp
        if req is not None and alive:
            try:
                hb = req.heartbeat()
                if hb > 0:
                    h["heartbeat_age_s"] = max(0.0, time.monotonic() - hb)
                occ = req.occupancy()
                hint = req.depth_hint()
                h["pending"] = occ + hint
                h["ring"] = {
                    "req_occupancy": occ, "depth_hint": hint,
                    "req": req.park_stats(),
                    "resp_occupancy": resp.occupancy(),
                    "resp": resp.park_stats(),
                }
            except (RingClosed, ValueError, TypeError):
                pass                  # ring mid-swap during a respawn
        return h
