"""Served-traffic tap: the trainer's window onto what the fleet serves.

The paper's policies were trained from production query streams, not
from a synthetic log sample — the MDP should spend its capacity on the
queries users actually issue, weighted by how often they issue them.
:class:`ServedTrafficTap` closes that loop: the cluster records every
completed ticket (responses AND sheds) into a bounded per-category
recency window, and the :class:`~repro.cluster.trainer.TrainerLoop`
draws its training batches from it instead of sampling the query log.

Two properties fall out of the representation:

- **Popularity weighting is free**: hot queries appear in the window
  once per serve, so sampling the window with replacement reproduces
  the served popularity distribution (including the result-cache's
  view of it — cache hits are served traffic too).
- **Shed awareness**: degraded and shed tickets are recorded with a
  configurable weight boost.  The queries the fleet could NOT afford
  to serve fully are exactly where a better match policy pays —
  upweighting them points the trainer at the pressure.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Optional

import numpy as np

from repro.serving.levels import ServiceLevel

__all__ = ["ServedTrafficTap"]


class ServedTrafficTap:
    """Thread-safe bounded window of served (qid, weight) per category.

    ``record`` is called from replica completion callbacks (and the
    cluster's submit path for immediate sheds); ``sample`` from the
    trainer thread.  The window is a recency ring (deque maxlen), so
    the trainer always learns from the *current* traffic mix, not from
    the whole history.
    """

    def __init__(self, capacity: int = 8192, degraded_boost: float = 2.0,
                 holdout_every: int = 0, holdout_capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if degraded_boost <= 0:
            raise ValueError("degraded_boost must be > 0")
        if holdout_every < 0:
            raise ValueError("holdout_every must be >= 0 (0 disables)")
        self.capacity = int(capacity)
        self.degraded_boost = float(degraded_boost)
        # Every ``holdout_every``-th record per category is diverted to
        # a held-out eval window the trainer's promotion gate probes —
        # evaluation traffic the training sampler never sees (0 = off,
        # the standalone default; the cluster turns it on via
        # ClusterConfig.tap_holdout_every).
        self.holdout_every = int(holdout_every)
        self.holdout_capacity = int(holdout_capacity)
        self._lock = threading.Lock()
        self._window: Dict[int, deque] = {}       # category -> (qid, w, epoch)
        self._holdout: Dict[int, deque] = {}      # category -> qid
        self._seen: Dict[int, int] = {}           # category -> record count
        self.n_recorded = 0
        self.n_held_out = 0
        self.level_counts: Dict[int, int] = {int(l): 0 for l in ServiceLevel}
        # Index-epoch span of the recorded traffic: the trainer trains
        # against the head index, so a wide span warns that the window
        # still carries pre-swap traffic (freshness lag, not an error).
        self.min_epoch_seen: Optional[int] = None
        self.max_epoch_seen: Optional[int] = None

    # -------------------------------------------------------------- feed
    def record(self, qid: int, category: int,
               level: ServiceLevel = ServiceLevel.FULL,
               index_epoch: int = 0) -> None:
        level = ServiceLevel(level)
        w = self.degraded_boost if level.degraded else 1.0
        index_epoch = int(index_epoch)
        with self._lock:
            cat = int(category)
            self.n_recorded += 1
            self.level_counts[int(level)] += 1
            if self.min_epoch_seen is None or index_epoch < self.min_epoch_seen:
                self.min_epoch_seen = index_epoch
            if self.max_epoch_seen is None or index_epoch > self.max_epoch_seen:
                self.max_epoch_seen = index_epoch
            if self.holdout_every:
                n = self._seen[cat] = self._seen.get(cat, 0) + 1
                if n % self.holdout_every == 0:
                    hq = self._holdout.get(cat)
                    if hq is None:
                        hq = self._holdout[cat] = deque(
                            maxlen=self.holdout_capacity)
                    hq.append(int(qid))
                    self.n_held_out += 1
                    return
            dq = self._window.get(cat)
            if dq is None:
                dq = self._window[cat] = deque(maxlen=self.capacity)
            dq.append((int(qid), w, index_epoch))

    # ------------------------------------------------------------ sample
    def size(self, category: Optional[int] = None) -> int:
        with self._lock:
            if category is not None:
                return len(self._window.get(int(category), ()))
            return sum(len(dq) for dq in self._window.values())

    def sample(self, category: int, batch: int,
               rng: np.random.Generator) -> Optional[np.ndarray]:
        """A weighted with-replacement training batch of qids from the
        category's served window, or None while the window is empty
        (the trainer waits or skips — it never falls back to the log)."""
        with self._lock:
            dq = self._window.get(int(category))
            if not dq:
                return None
            qids = np.fromiter((q for q, _, _ in dq), dtype=np.int64,
                               count=len(dq))
            weights = np.fromiter((w for _, w, _ in dq), dtype=np.float64,
                                  count=len(dq))
        return rng.choice(qids, size=int(batch), replace=True,
                          p=weights / weights.sum())

    # ----------------------------------------------------------- holdout
    def holdout_size(self, category: Optional[int] = None) -> int:
        with self._lock:
            if category is not None:
                return len(self._holdout.get(int(category), ()))
            return sum(len(dq) for dq in self._holdout.values())

    def holdout_sample(self, category: int, n: int,
                       rng: np.random.Generator) -> Optional[np.ndarray]:
        """Up to ``n`` *distinct* held-out qids for the category — the
        promotion gate's probe set — or None while the holdout window
        is empty.  Distinct because the gate scores recall per query;
        popularity weighting belongs to training, not evaluation."""
        with self._lock:
            dq = self._holdout.get(int(category))
            if not dq:
                return None
            qids = np.unique(np.fromiter(dq, dtype=np.int64, count=len(dq)))
        if len(qids) <= n:
            return qids
        return rng.choice(qids, size=int(n), replace=False)

    def stats(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "degraded_boost": self.degraded_boost,
                "n_recorded": self.n_recorded,
                "n_held_out": self.n_held_out,
                "holdout_every": self.holdout_every,
                "window_sizes": {c: len(dq)
                                 for c, dq in sorted(self._window.items())},
                "holdout_sizes": {c: len(dq)
                                  for c, dq in sorted(self._holdout.items())},
                "levels": {ServiceLevel(k).name: v
                           for k, v in sorted(self.level_counts.items())},
                "index_epoch_min": self.min_epoch_seen,
                "index_epoch_max": self.max_epoch_seen,
            }
