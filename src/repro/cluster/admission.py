"""u-budget admission control: estimate a query's index cost, shed when hot.

The paper prices query evaluation in u — posting-plane block reads —
and shows it linear in machine time, so u is the honest unit for load
control too: a fleet saturates when the *sum of u being evaluated*
exceeds what the index machines stream, not when some request counter
does.  The :class:`AdmissionController` therefore keeps a reservation
ledger in u: every admitted query reserves its *estimated* cost, every
completion releases it (and feeds the actual u back into the
estimator), and a submit that would push the reserved total past the
fleet budget is rejected with an explicit :class:`Shed` result instead
of being queued into a latency collapse.

Estimates come from the query's *pre-execution* features — the same
ones the paper's query categorizer uses (category, term document
frequencies): rare-term CAT1 queries force deep scans, head-df CAT2
queries satisfy their quotas early.  :class:`UCostEstimator` buckets
queries by (category, df-decile) and tracks an EMA of observed u per
bucket, seeded with a configurable prior so cold buckets are priced
pessimistically rather than admitted for free.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Tuple

import numpy as np

__all__ = ["Shed", "UCostEstimator", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class Shed:
    """Explicit load-shed result (the non-response a caller can act on)."""
    qid: int
    category: int
    est_u: float
    reason: str


class UCostEstimator:
    """(category, df-decile) -> EMA of observed u, with a prior.

    The df feature is the mean body-field document frequency of the
    query's terms as a fraction of the corpus (exactly the signal
    ``data.querylog.classify_query`` categorizes on); bucket edges are
    quantiles of that feature over the whole query log, so buckets are
    equal-mass.
    """

    def __init__(self, system, n_df_bins: int = 8, ema: float = 0.25,
                 prior_u: Optional[float] = None):
        log, index = system.log, system.index
        df_body = index.df[:, 2].astype(np.float64)       # body field
        mean_df = np.zeros(log.n_queries)
        for qi in range(log.n_queries):
            ts = log.terms[qi, : log.n_terms[qi]]
            mean_df[qi] = df_body[ts].mean() if len(ts) else 0.0
        self._df_frac = mean_df / max(index.n_docs, 1)
        qs = np.linspace(0, 1, n_df_bins + 1)[1:-1]
        self._edges = np.quantile(self._df_frac, qs)
        self._category = log.category
        n_cats = int(self._category.max()) + 1
        if prior_u is None:
            # Half the episode budget: pessimistic enough that a cold
            # fleet sheds under a thundering herd, cheap to correct.
            prior_u = system.cfg.u_budget / 2
        self.prior_u = float(prior_u)
        self.ema = float(ema)
        self._table = np.full((n_cats, n_df_bins), self.prior_u)
        self._seen = np.zeros((n_cats, n_df_bins), dtype=np.int64)
        self._lock = threading.Lock()

    def features(self, qid: int) -> Tuple[int, int]:
        cat = int(self._category[qid])
        df_bin = int(np.searchsorted(self._edges, self._df_frac[qid]))
        return cat, df_bin

    def estimate(self, qid: int) -> float:
        cat, df_bin = self.features(qid)
        return float(self._table[cat, df_bin])

    def observe(self, qid: int, u: float) -> None:
        cat, df_bin = self.features(qid)
        with self._lock:
            if self._seen[cat, df_bin] == 0:
                self._table[cat, df_bin] = float(u)   # drop the prior
            else:
                self._table[cat, df_bin] += self.ema * (
                    float(u) - self._table[cat, df_bin])
            self._seen[cat, df_bin] += 1

    def describe(self) -> dict:
        return {
            "n_df_bins": self._table.shape[1],
            "prior_u": self.prior_u,
            "buckets_seen": int((self._seen > 0).sum()),
            "table": self._table.round(1).tolist(),
        }


class AdmissionController:
    """Fleet-wide u reservation ledger with shedding.

    ``try_admit`` reserves the query's estimated u and returns it; when
    the reservation would exceed ``u_inflight_budget`` it returns
    ``None`` (the caller builds the :class:`Shed`).  A query whose
    estimate alone exceeds the budget is still admitted when the fleet
    is idle — otherwise it could never run at all.  ``release`` returns
    the reservation and, given the actual u, improves the estimator.
    """

    def __init__(self, estimator: UCostEstimator,
                 u_inflight_budget: float = float("inf")):
        if u_inflight_budget <= 0:
            raise ValueError("u_inflight_budget must be > 0")
        self.estimator = estimator
        self.u_inflight_budget = float(u_inflight_budget)
        self._lock = threading.Lock()
        self.reserved_u = 0.0
        self.admitted = 0
        self.shed = 0

    def try_admit(self, qid: int) -> Optional[float]:
        est = self.estimator.estimate(qid)
        with self._lock:
            if self.reserved_u > 0 and self.reserved_u + est > self.u_inflight_budget:
                self.shed += 1
                return None
            self.reserved_u += est
            self.admitted += 1
            return est

    def release(self, est_u: float, actual_u: Optional[float] = None,
                qid: Optional[int] = None) -> None:
        with self._lock:
            self.reserved_u = max(0.0, self.reserved_u - est_u)
        if actual_u is not None and qid is not None:
            self.estimator.observe(qid, actual_u)

    def stats(self) -> dict:
        with self._lock:
            return {
                "u_inflight_budget": self.u_inflight_budget,
                "reserved_u": self.reserved_u,
                "admitted": self.admitted,
                "shed": self.shed,
            }
