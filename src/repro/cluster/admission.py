"""Pressure-tiered admission: price queries in u, degrade before shedding.

The paper prices query evaluation in u — posting-plane block reads —
and shows it linear in machine time, so u is the honest unit for load
control too: a fleet saturates when the *sum of u being evaluated*
exceeds what the index machines stream, not when some request counter
does.  The :class:`AdmissionController` keeps a reservation ledger in
u, and instead of the binary admit/shed hammer it walks a **service
ladder** priced from the ledger's headroom (docs/cluster.md):

    FULL         while reservations stay under ``full_watermark`` of
                 the budget — normal serving, live policy.
    SHALLOW      while the (much smaller) shallow estimate still fits
                 the full budget — the snapshot's truncated static
                 plan, u bounded by its summed Δu quotas.
    CACHED_ONLY  when not even a shallow rollout fits but some
                 replica's result cache already holds the key (~zero u).
    SHED         explicit non-response, the valve of last resort.

Estimates come from the query's *pre-execution* features — the same
ones the paper's query categorizer uses (category, term document
frequencies): rare-term CAT1 queries force deep scans, head-df CAT2
queries satisfy their quotas early.  :class:`UCostEstimator` buckets
queries by (category, df-decile) and tracks an EMA of observed u per
bucket **per executed service level and per policy snapshot version**:
every served response feeds its realized u back, so the table is
learned online from the traffic the fleet actually serves — a new
policy version starts from the previous version's estimates as its
prior and re-learns its own costs (a deeper-scanning v7 must not be
priced with v6's numbers).

Live indexes add a second axis: a query whose terms have postings in
the head epoch's **delta segment** scans more (or different) blocks
than the mmapped base alone, so its realized u drifts away from the
base-learned table between merges.  The estimator keeps a per-(level,
category) *delta correction* — an EMA of the realized-u / table-value
ratio learned ONLY from epoch-stamped outcomes observed at the current
head epoch (a stale stamp describes a delta that no longer exists) —
and multiplies it into the estimate whenever the query's terms hit the
head delta.  Base buckets stay base-only; a merge empties the delta,
the hit probe goes false, and pricing falls back to the clean table.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import MetricsRegistry
from repro.serving.levels import EXECUTED_LEVELS, ServiceLevel

__all__ = ["Admission", "Shed", "UCostEstimator", "AdmissionController"]


@dataclasses.dataclass(frozen=True)
class Shed:
    """Explicit load-shed result (the non-response a caller can act on)."""
    qid: int
    category: int
    est_u: float
    reason: str


@dataclasses.dataclass(frozen=True)
class Admission:
    """One ladder decision: the granted level and what it reserved."""
    level: ServiceLevel
    est_u: float          # FULL-level estimate at decision time
    reserved_u: float     # what the ledger now holds for this query


class UCostEstimator:
    """(level, category, df-decile) -> EMA of observed u, versioned per
    policy snapshot.

    The df feature is the mean body-field document frequency of the
    query's terms as a fraction of the corpus (exactly the signal
    ``data.querylog.classify_query`` categorizes on); bucket edges are
    quantiles of that feature over the whole query log, so buckets are
    equal-mass.

    Version semantics: tables are keyed by the policy snapshot version
    that produced the observation.  A version's table is lazily seeded
    from the latest earlier version's *values* (so cold buckets inherit
    a sensible estimate) with its sample counts reset — the first
    observation under the new policy replaces the inherited value, the
    way the first observation replaces the configured prior at version
    0.  ``estimate`` reads the latest version by default, i.e. the
    policy the fleet is converging onto.  Only the last
    ``max_versions`` tables are retained.
    """

    def __init__(self, system, n_df_bins: int = 8, ema: float = 0.25,
                 prior_u: Optional[float] = None,
                 prior_shallow_u: Optional[float] = None,
                 max_versions: int = 4):
        log, index = system.log, system.index
        self._system = system
        self._df_body = index.df[:, 2].astype(np.float64)  # body field
        self._n_docs = int(index.n_docs)
        mean_df = np.zeros(log.n_queries)
        for qi in range(log.n_queries):
            ts = log.terms[qi, : log.n_terms[qi]]
            mean_df[qi] = self._df_body[ts].mean() if len(ts) else 0.0
        self._df_frac = mean_df / max(self._n_docs, 1)
        qs = np.linspace(0, 1, n_df_bins + 1)[1:-1]
        self._edges = np.quantile(self._df_frac, qs)
        self._category = log.category
        n_cats = int(self._category.max()) + 1
        if prior_u is None:
            # Half the episode budget: pessimistic enough that a cold
            # fleet degrades under a thundering herd, cheap to correct.
            prior_u = system.cfg.u_budget / 2
        if prior_shallow_u is None:
            # The shallow fallback has a hard cap (summed Δu quotas of
            # the truncated plan); without one configured, assume a
            # quarter of the full prior.
            prior_shallow_u = prior_u / 4
        self.prior_u = float(prior_u)
        self.prior_shallow_u = float(prior_shallow_u)
        self.ema = float(ema)
        self.max_versions = int(max_versions)
        self._shape = (len(EXECUTED_LEVELS), n_cats, n_df_bins)
        self._tables: Dict[int, np.ndarray] = {}
        self._seen: Dict[int, np.ndarray] = {}
        # Delta-aware pricing (live indexes): multiplicative correction
        # per (level, category) applied when the query's terms have
        # postings in the head epoch's delta; 1.0 = base pricing.
        self._delta_corr = np.ones((len(EXECUTED_LEVELS), n_cats))
        self._delta_seen = np.zeros((len(EXECUTED_LEVELS), n_cats),
                                    dtype=np.int64)
        self._delta_terms: frozenset = frozenset()
        self._delta_terms_version = -1
        self._lock = threading.Lock()
        self._init_version(0)

    # ---------------------------------------------------------- versions
    def _init_version(self, version: int) -> None:
        """Create the table for ``version`` (caller holds no lock for
        version 0; otherwise the estimator lock)."""
        if self._tables:
            base = max((v for v in self._tables if v <= version),
                       default=max(self._tables))
            table = self._tables[base].copy()
        else:
            table = np.empty(self._shape)
            table[int(ServiceLevel.FULL)] = self.prior_u
            table[int(ServiceLevel.SHALLOW)] = self.prior_shallow_u
        self._tables[version] = table
        self._seen[version] = np.zeros(self._shape, dtype=np.int64)
        while len(self._tables) > self.max_versions:
            oldest = min(self._tables)
            del self._tables[oldest], self._seen[oldest]

    @property
    def latest_version(self) -> int:
        return max(self._tables)

    def _resolve(self, version: Optional[int]) -> int:
        if version is None:
            return max(self._tables)
        if version in self._tables:
            return version
        # an evicted (or never-observed) version reads its nearest
        # retained predecessor, falling back to the oldest retained
        older = [v for v in self._tables if v <= version]
        return max(older) if older else min(self._tables)

    # ---------------------------------------------------------- features
    def _extend_features(self, qid: int) -> None:
        """A live query log grows (``append_queries``): price appended
        queries by lazily extending the per-query feature arrays from
        the current log.  Bucket edges stay fixed from the seed log —
        buckets are a stable coordinate system, not a moving target."""
        with self._lock:
            if qid < len(self._df_frac):
                return                   # another thread got here first
            log = self._system.log
            terms, n_terms = log.terms, log.n_terms
            category = log.category
            n = min(len(category), terms.shape[0], len(n_terms))
            old = len(self._df_frac)
            mean_df = np.zeros(max(0, n - old))
            for i, qi in enumerate(range(old, n)):
                ts = terms[qi, : n_terms[qi]]
                mean_df[i] = self._df_body[ts].mean() if len(ts) else 0.0
            self._df_frac = np.concatenate(
                [self._df_frac, mean_df / max(self._n_docs, 1)])
            self._category = category[:n]

    def features(self, qid: int) -> Tuple[int, int]:
        qid = int(qid)
        df_frac, category = self._df_frac, self._category
        if qid >= len(df_frac) or qid >= len(category):
            self._extend_features(qid)
            df_frac, category = self._df_frac, self._category
        cat = int(category[qid])
        df_bin = int(np.searchsorted(self._edges, df_frac[qid]))
        return cat, df_bin

    def features_many(self, qids) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`features`: (categories, df-bins) for a
        whole slab in two gathers and one ``searchsorted``."""
        qids = np.asarray(qids, np.int64).ravel()
        if qids.size:
            top = int(qids.max())
            if (top >= len(self._df_frac) or top >= len(self._category)):
                self._extend_features(top)
        cats = np.asarray(self._category)[qids].astype(np.int64)
        bins = np.searchsorted(self._edges, self._df_frac[qids])
        return cats, bins

    # ------------------------------------------------------- delta pricing
    def _head_delta(self) -> Tuple[int, frozenset]:
        """(head epoch version, delta term set) — cached per epoch; a
        static system answers (-1, ∅) and never prices a correction."""
        store = getattr(self._system, "index_epoch_store", None)
        if store is None:
            return -1, frozenset()
        epoch = store.snapshot()
        with self._lock:
            if epoch.version != self._delta_terms_version:
                self._delta_terms = epoch.view.delta.terms_present()
                self._delta_terms_version = epoch.version
            return self._delta_terms_version, self._delta_terms

    def delta_hit(self, qid: int) -> bool:
        """True when any of the query's terms has postings in the HEAD
        epoch's delta segment — i.e. serving it scans delta blocks the
        base-learned table never saw."""
        _, terms = self._head_delta()
        if not terms:
            return False
        log = self._system.log
        qid = int(qid)
        ts = log.terms[qid, : log.n_terms[qid]]
        return any(int(t) in terms for t in ts)

    def delta_hits_many(self, qids) -> np.ndarray:
        """Vectorized :meth:`delta_hit`: one ``np.isin`` over the
        slab's term matrix against the head delta's term set."""
        qids = np.asarray(qids, np.int64).ravel()
        _, terms = self._head_delta()
        if not terms or qids.size == 0:
            return np.zeros(qids.size, bool)
        log = self._system.log
        tm = np.asarray(log.terms)[qids]
        nt = np.asarray(log.n_terms)[qids]
        present = np.isin(tm, np.fromiter(terms, np.int64, len(terms)))
        valid = np.arange(tm.shape[1])[None, :] < nt[:, None]
        return (present & valid).any(axis=1)

    def estimate(self, qid: int,
                 level: ServiceLevel = ServiceLevel.FULL,
                 version: Optional[int] = None) -> float:
        if level not in EXECUTED_LEVELS:
            raise ValueError(f"no u estimate for non-executed level {level!r}")
        cat, df_bin = self.features(qid)
        hit = self.delta_hit(qid)
        with self._lock:
            est = float(self._tables[self._resolve(version)][
                int(level), cat, df_bin])
            if hit:
                est *= float(self._delta_corr[int(level), cat])
            return est

    def estimates(self, qid: int,
                  version: Optional[int] = None) -> Tuple[float, float]:
        """(FULL, SHALLOW) estimates in one feature lookup and one lock
        acquisition — the admission hot path prices both rungs."""
        cat, df_bin = self.features(qid)
        hit = self.delta_hit(qid)
        with self._lock:
            col = self._tables[self._resolve(version)][:, cat, df_bin]
            corr = self._delta_corr[:, cat] if hit else None
            full = float(col[int(ServiceLevel.FULL)])
            shallow = float(col[int(ServiceLevel.SHALLOW)])
            if corr is not None:
                full *= float(corr[int(ServiceLevel.FULL)])
                shallow *= float(corr[int(ServiceLevel.SHALLOW)])
            return full, shallow

    def estimates_many(self, qids, version: Optional[int] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`estimates`: (FULL, SHALLOW) estimate
        arrays for a whole slab priced under ONE lock acquisition —
        features and delta probes vectorize outside it, the table read
        is a fancy-index gather inside it.  Elementwise identical to a
        loop of scalar ``estimates`` calls (float64 throughout)."""
        cats, bins = self.features_many(qids)
        hits = self.delta_hits_many(qids)
        with self._lock:
            table = self._tables[self._resolve(version)]
            full = table[int(ServiceLevel.FULL), cats, bins].astype(
                np.float64, copy=True)
            shallow = table[int(ServiceLevel.SHALLOW), cats, bins].astype(
                np.float64, copy=True)
            if hits.any():
                hcats = cats[hits]
                full[hits] *= self._delta_corr[int(ServiceLevel.FULL), hcats]
                shallow[hits] *= self._delta_corr[
                    int(ServiceLevel.SHALLOW), hcats]
        return full, shallow

    def observe(self, qid: int, u: float,
                level: ServiceLevel = ServiceLevel.FULL,
                version: Optional[int] = None,
                index_epoch: Optional[int] = None) -> None:
        """Feed one served response's realized u back (online learning
        from the traffic the fleet actually serves).  ``index_epoch``
        is the epoch stamp the response carries; delta-touching
        outcomes train the per-category correction instead of the base
        table, and only when stamped at the current head (a stale
        stamp priced a delta that has since merged or grown)."""
        if level not in EXECUTED_LEVELS:
            return                       # cached/shed responses cost no u
        cat, df_bin = self.features(qid)
        head_epoch, _terms = self._head_delta()
        hit = self.delta_hit(qid)
        with self._lock:
            if version is None:
                version = max(self._tables)
            elif version not in self._tables:
                if version < min(self._tables):
                    return               # older than anything retained
                self._init_version(version)
            idx = (int(level), cat, df_bin)
            table, seen = self._tables[version], self._seen[version]
            if hit:
                # Keep the base table base-only: this outcome includes
                # delta scanning, so it trains the correction ratio —
                # and only when observed AT the head epoch.
                if index_epoch is None or index_epoch != head_epoch:
                    return
                ratio = float(u) / max(float(table[idx]), 1e-9)
                cidx = (int(level), cat)
                if self._delta_seen[cidx] == 0:
                    self._delta_corr[cidx] = ratio
                else:
                    self._delta_corr[cidx] += self.ema * (
                        ratio - self._delta_corr[cidx])
                self._delta_seen[cidx] += 1
                return
            if seen[idx] == 0:
                table[idx] = float(u)    # drop the (inherited) prior
            else:
                table[idx] += self.ema * (float(u) - table[idx])
            seen[idx] += 1

    def describe(self) -> dict:
        with self._lock:
            latest = max(self._tables)
            return {
                "n_df_bins": self._shape[2],
                "prior_u": self.prior_u,
                "prior_shallow_u": self.prior_shallow_u,
                "versions": sorted(self._tables),
                "buckets_seen": int((self._seen[latest] > 0).sum()),
                "table": self._tables[latest].round(1).tolist(),
                "delta_corr": self._delta_corr.round(3).tolist(),
                "delta_obs": int(self._delta_seen.sum()),
                "delta_terms_epoch": self._delta_terms_version,
            }


class AdmissionController:
    """Fleet-wide u reservation ledger pricing the service ladder.

    ``decide`` walks the ladder against the ledger's headroom and
    reserves what the granted level will cost; ``release`` returns the
    reservation and, given the realized u, improves the estimator for
    the (level, snapshot-version) that produced it.  Two shapes:

    - **ladder** (default): FULL while reservations stay under
      ``full_watermark * budget`` (so FULL traffic can never starve the
      degraded tiers of headroom), SHALLOW while the shallow estimate
      fits the whole budget, CACHED_ONLY when the caller reports a
      cache entry exists, SHED last.  An idle fleet always grants FULL
      (otherwise an oversized query could never run at all).
    - **binary** (``ladder=False``): the pre-ladder behaviour — FULL if
      the estimate fits, SHED otherwise — kept as the benchmark
      baseline the degradation sweep compares against.
    """

    def __init__(self, estimator: UCostEstimator,
                 u_inflight_budget: float = float("inf"),
                 ladder: bool = True,
                 full_watermark: float = 0.5,
                 registry: Optional[MetricsRegistry] = None):
        if u_inflight_budget <= 0:
            raise ValueError("u_inflight_budget must be > 0")
        if not 0.0 < full_watermark <= 1.0:
            raise ValueError("full_watermark must be in (0, 1]")
        self.estimator = estimator
        self.u_inflight_budget = float(u_inflight_budget)
        self.ladder = bool(ladder)
        self.full_watermark = float(full_watermark)
        self._lock = threading.Lock()
        self.reserved_u = 0.0
        self.admitted = 0
        self.shed = 0
        self.level_counts: Dict[int, int] = {int(l): 0 for l in ServiceLevel}
        # Mirror the ladder mix and the ledger level into the shared
        # metrics plane (the SLO control loop watches reserved_u's peak
        # against the budget); a standalone controller gets a private
        # registry so the recording code has one shape.
        reg = registry if registry is not None else MetricsRegistry()
        self._decision_counters = {
            int(l): reg.counter("admission.decisions", level=l.name)
            for l in ServiceLevel}
        self._g_reserved = reg.gauge("admission.reserved_u")

    # -------------------------------------------------------------- decide
    def decide(self, qid: int, cache_available: bool = False,
               shallow_available: bool = True) -> Admission:
        """Price one query against the ledger; reserves the granted
        level's estimated u and returns the :class:`Admission`.  The
        caller reports whether some replica's result cache holds the
        query's key (the CACHED_ONLY rung is only real if it does) and
        whether the serving snapshot carries a fallback policy for the
        query's category (no fallback — no SHALLOW rung)."""
        est_full, est_shallow = self.estimator.estimates(qid)
        budget = self.u_inflight_budget
        with self._lock:
            if not self.ladder:
                # binary baseline: PR-4 semantics, verbatim
                if (self.reserved_u > 0
                        and self.reserved_u + est_full > budget):
                    level, reserve = ServiceLevel.SHED, 0.0
                else:
                    level, reserve = ServiceLevel.FULL, est_full
            else:
                # The watermark exists to keep reservation headroom for
                # the SHALLOW rung; with no fallback for this query the
                # FULL rung may use the whole budget (capping it there
                # would make the ladder serve strictly LESS than the
                # binary controller it replaced).  CACHED_ONLY reserves
                # nothing, so it needs no protected headroom.
                full_cap = (self.full_watermark * budget
                            if shallow_available else budget)
                if (self.reserved_u == 0
                        or self.reserved_u + est_full <= full_cap):
                    # idle fleets always serve FULL; busy fleets only
                    # while FULL traffic leaves the degraded tiers
                    # their headroom
                    level, reserve = ServiceLevel.FULL, est_full
                elif (shallow_available
                        and self.reserved_u + est_shallow <= budget):
                    level, reserve = ServiceLevel.SHALLOW, est_shallow
                elif cache_available:
                    level, reserve = ServiceLevel.CACHED_ONLY, 0.0
                else:
                    level, reserve = ServiceLevel.SHED, 0.0
            self.reserved_u += reserve
            self.level_counts[int(level)] += 1
            if level == ServiceLevel.SHED:
                self.shed += 1
            else:
                self.admitted += 1
            self._decision_counters[int(level)].inc()
            self._g_reserved.set(self.reserved_u)
            return Admission(level=level, est_u=est_full, reserved_u=reserve)

    def decide_many(self, qids, cache_available=None,
                    shallow_available=None):
        """Price a whole arrival slab against the ledger under ONE lock
        acquisition; returns ``(levels, reserves, est_full)`` arrays.

        Estimation — the expensive part — vectorizes fully outside the
        lock via :meth:`UCostEstimator.estimates_many`.  The ladder
        walk itself stays a scalar sweep *inside* the lock because each
        decision's headroom depends on every earlier reservation in the
        slab; that sweep is a handful of float compares per query, and
        running it under one acquisition is exactly what makes the
        result bit-identical to a loop of :meth:`decide` calls (the
        B=1 oracle) while paying one lock, one gauge store, and one
        counter pass per slab."""
        qids = np.asarray(qids, np.int64).ravel()
        n = qids.size
        cache_av = (np.zeros(n, bool) if cache_available is None
                    else np.asarray(cache_available, bool).ravel())
        shallow_av = (np.ones(n, bool) if shallow_available is None
                      else np.asarray(shallow_available, bool).ravel())
        est_full, est_shallow = self.estimator.estimates_many(qids)
        budget = self.u_inflight_budget
        levels = np.empty(n, np.int8)
        reserves = np.zeros(n, np.float64)
        with self._lock:
            for i in range(n):
                ef = float(est_full[i])
                if not self.ladder:
                    if (self.reserved_u > 0
                            and self.reserved_u + ef > budget):
                        level, reserve = ServiceLevel.SHED, 0.0
                    else:
                        level, reserve = ServiceLevel.FULL, ef
                else:
                    full_cap = (self.full_watermark * budget
                                if shallow_av[i] else budget)
                    if (self.reserved_u == 0
                            or self.reserved_u + ef <= full_cap):
                        level, reserve = ServiceLevel.FULL, ef
                    elif (shallow_av[i] and self.reserved_u
                          + float(est_shallow[i]) <= budget):
                        level, reserve = (ServiceLevel.SHALLOW,
                                          float(est_shallow[i]))
                    elif cache_av[i]:
                        level, reserve = ServiceLevel.CACHED_ONLY, 0.0
                    else:
                        level, reserve = ServiceLevel.SHED, 0.0
                self.reserved_u += reserve
                self.level_counts[int(level)] += 1
                levels[i] = int(level)
                reserves[i] = reserve
            n_shed = int((levels == int(ServiceLevel.SHED)).sum())
            self.shed += n_shed
            self.admitted += n - n_shed
            self._g_reserved.set(self.reserved_u)
        vals, counts = np.unique(levels, return_counts=True)
        for v, c in zip(vals, counts):
            self._decision_counters[int(v)].inc(int(c))
        return levels, reserves, est_full

    def release(self, reserved_u: float, actual_u: Optional[float] = None,
                qid: Optional[int] = None,
                level: ServiceLevel = ServiceLevel.FULL,
                version: Optional[int] = None,
                index_epoch: Optional[int] = None) -> None:
        """Return a reservation; with the realized u (non-cached
        responses only), feed the estimator for the (level, snapshot
        version) that served it — ``index_epoch`` stamps the outcome
        for the estimator's delta-aware correction."""
        with self._lock:
            self.reserved_u = max(0.0, self.reserved_u - reserved_u)
            self._g_reserved.set(self.reserved_u)
        if actual_u is not None and qid is not None:
            self.estimator.observe(qid, actual_u, level=level,
                                   version=version,
                                   index_epoch=index_epoch)

    def stats(self) -> dict:
        with self._lock:
            return {
                "u_inflight_budget": self.u_inflight_budget,
                "ladder": self.ladder,
                "full_watermark": self.full_watermark,
                "reserved_u": self.reserved_u,
                "admitted": self.admitted,
                "shed": self.shed,
                "levels": {ServiceLevel(k).name: v
                           for k, v in sorted(self.level_counts.items())},
            }
