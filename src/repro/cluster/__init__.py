"""Online learning cluster (docs/cluster.md).

A background `TrainerLoop` publishes versioned policy snapshots into a
shared `PolicyStore` while a `ReplicaSet` of N `ServeEngine` replicas
serves continuously — queue-aware/cache-affinity routing in front,
u-budget admission control (explicit `Shed` results) at the door,
per-response policy-version-lag accounting throughout.
"""
from .admission import AdmissionController, Shed, UCostEstimator
from .cluster import ClusterConfig, ReplicaSet
from .replica import ClusterTicket, Replica
from .router import (QueueAwareRouter, RoundRobinRouter, Router, make_router,
                     stable_query_hash)
from .trainer import TrainerConfig, TrainerLoop, candidate_recall, probe_recall

__all__ = [
    "AdmissionController", "ClusterConfig", "ClusterTicket",
    "QueueAwareRouter", "Replica", "ReplicaSet", "RoundRobinRouter",
    "Router", "Shed", "TrainerConfig", "TrainerLoop", "UCostEstimator",
    "candidate_recall", "make_router", "probe_recall", "stable_query_hash",
]
