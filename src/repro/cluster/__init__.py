"""Online learning cluster (docs/cluster.md).

A background `TrainerLoop` publishes versioned policy snapshots (live
policies + their SHALLOW fallbacks, atomically) into a shared
`PolicyStore` while a `ReplicaSet` of N `ServeEngine` replicas serves
continuously — queue-aware/cache-affinity routing in front, a
pressure-tiered admission ladder (FULL → SHALLOW → CACHED_ONLY →
explicit `Shed`) priced in u at the door, per-response policy-version
lag accounting throughout, and a `ServedTrafficTap` feeding the
trainer the queries the fleet actually served.
"""
from repro.serving.levels import ServiceLevel

from .admission import Admission, AdmissionController, Shed, UCostEstimator
from .cluster import ClusterConfig, ReplicaSet
from .proc import FollowerSystem, ProcessReplica, ShmRing
from .replica import ClusterTicket, Replica
from .router import (QueueAwareRouter, RoundRobinRouter, Router, make_router,
                     stable_query_hash)
from .tap import ServedTrafficTap
from .trainer import TrainerConfig, TrainerLoop, candidate_recall, probe_recall

__all__ = [
    "Admission", "AdmissionController", "ClusterConfig", "ClusterTicket",
    "FollowerSystem", "ProcessReplica", "QueueAwareRouter", "Replica",
    "ReplicaSet", "RoundRobinRouter", "Router", "ServedTrafficTap",
    "ServiceLevel", "Shed", "ShmRing", "TrainerConfig", "TrainerLoop",
    "UCostEstimator", "candidate_recall", "make_router", "probe_recall",
    "stable_query_hash",
]
