"""`ReplicaSet`: the online-learning cluster front door.

Topology (docs/cluster.md has the full diagram):

    TrainerLoop ◄──sample── ServedTrafficTap ◄──record── completions
        │ publish (policies + fallbacks)
        ▼
    PolicyStore ◄──snapshot── Replica 0..N-1
                                  ▲
    submit ─► AdmissionController ─► Router ─► inbox
              (service ladder:       (affinity + depth spill
               FULL/SHALLOW/          + owner-saturation spill)
               CACHED_ONLY/SHED)

One `RetrievalSystem` (the index is process-shared and read-only) backs
N `ServeEngine` replicas, each with its own worker thread, micro-batch
queues, and result cache.  `submit` estimates the query's u-cost from
its category/df features and walks the admission ladder against the
fleet ledger's headroom: FULL while reservations are comfortable,
SHALLOW (the snapshot's bounded-u fallback plan) under pressure,
CACHED_ONLY when not even that fits but a replica's cache holds the
key, and an explicit `Shed` only as the last rung.  Completions
release the u reservation, feed the realized u back into the
(per-level, per-snapshot-version) estimator, record the response's
policy version lag (bounded by the store's staleness check, surfaced
in `stats()`), and land in the `ServedTrafficTap` the trainer samples.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Deque, List, Optional, Sequence, Union

import numpy as np

from repro.obs import (NULL_TRACER, EventLog, FlightRecorder,
                       HeartbeatWatchdog, MetricsRegistry, Tracer,
                       merge_snapshots, write_chrome_entries)
from repro.obs import health as _health
from repro.policies import PolicyStore
from repro.serving import EngineConfig, ServiceLevel
from repro.serving.cache import canonical_query_key
from repro.serving.slab import QueryKeyCache
from repro.serving.engine import ServeResponse

from repro.serving.telemetry import pct as _pct

from .admission import AdmissionController, Shed, UCostEstimator
from .replica import ClusterTicket, Replica
from .router import make_router, stable_query_hash
from .tap import ServedTrafficTap

__all__ = ["ClusterConfig", "ReplicaSet"]

Result = Union[ServeResponse, Shed]


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    n_replicas: int = 2
    # "thread": N ServeEngines on worker threads in this process (the
    # default and the parity oracle).  "process": N worker processes,
    # each mmapping the cell's saved base generation (one physical
    # copy fleet-wide), fed over binary shared-memory rings with
    # policy/epoch publishes relayed per-worker (repro.cluster.proc).
    backend: str = "thread"
    proc_ring_slots: int = 64             # per-direction SPSC ring slots
    proc_storage_dir: Optional[str] = None  # cell dir (tempdir when None)
    max_worker_restarts: int = 2          # respawns before shedding
    routing: str = "queue_aware"          # or "round_robin"
    spill_margin: int = 4                 # depth gap before spilling
    owner_spill_depth: Optional[int] = 32  # sticky-owner saturation gauge
    u_inflight_budget: float = float("inf")   # fleet u budget (inf = no shed)
    ladder: bool = True                   # graceful degradation (False = binary)
    full_watermark: float = 0.5           # budget fraction FULL may reserve
    prior_u: Optional[float] = None       # cold-bucket u estimate (FULL)
    prior_shallow_u: Optional[float] = None   # cold-bucket estimate (SHALLOW)
    n_df_bins: int = 8
    window: int = 65536                   # lag/latency sample window
    affinity_table: int = 65536           # key -> cache-owner LRU entries
    tap_capacity: int = 8192              # served-traffic window per category
    tap_degraded_boost: float = 2.0       # tap weight for non-FULL tickets
    tap_holdout_every: int = 0            # divert every Nth record to the
                                          # eval holdout (0 = off)
    tap_holdout_capacity: int = 1024      # held-out window per category


class ReplicaSet:
    """N replicas + router + admission over one system and store."""

    def __init__(self, system, store: PolicyStore,
                 cfg: ClusterConfig = ClusterConfig(),
                 engine_cfg: EngineConfig = EngineConfig(),
                 tracer: Tracer = NULL_TRACER):
        if cfg.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.system = system
        self.store = store
        self.cfg = cfg
        self.tracer = tracer
        # Cluster-plane instruments (admission/routing); replica-plane
        # metrics live in each engine's registry and fold together in
        # metrics_snapshot().
        self.registry = MetricsRegistry()
        self._c_submitted = self.registry.counter("cluster.submitted")
        self._c_shed = self.registry.counter("cluster.shed",
                                             where="admission")
        self._c_shed_replica = self.registry.counter("cluster.shed",
                                                     where="replica")
        # Flight recorder: bounded structured event ring (publishes,
        # epoch swaps, level transitions, sheds, worker restarts) that
        # ships inside postmortem bundles when a worker dies.
        self.events = EventLog(registry=self.registry)
        self.recorder = FlightRecorder(
            self.events,
            config={"backend": cfg.backend, "n_replicas": cfg.n_replicas,
                    "routing": cfg.routing, "ladder": cfg.ladder,
                    "u_inflight_budget": cfg.u_inflight_budget,
                    "max_worker_restarts": cfg.max_worker_restarts})
        self._last_level: Optional[int] = None
        self._last_generation: Optional[int] = None
        self.router = make_router(cfg.routing, spill_margin=cfg.spill_margin,
                                  owner_spill_depth=cfg.owner_spill_depth,
                                  registry=self.registry)
        self.admission = AdmissionController(
            UCostEstimator(system, n_df_bins=cfg.n_df_bins,
                           prior_u=cfg.prior_u,
                           prior_shallow_u=cfg.prior_shallow_u),
            u_inflight_budget=cfg.u_inflight_budget,
            ladder=cfg.ladder, full_watermark=cfg.full_watermark,
            registry=self.registry)
        # Every completion (responses AND sheds) is recorded here; a
        # TrainerLoop pointed at it learns from served traffic instead
        # of the query log (docs/cluster.md, "trainer tap").
        self.tap = ServedTrafficTap(capacity=cfg.tap_capacity,
                                    degraded_boost=cfg.tap_degraded_boost,
                                    holdout_every=cfg.tap_holdout_every,
                                    holdout_capacity=cfg.tap_holdout_capacity)
        self._engine_cfg = engine_cfg
        self._unsubscribes: List = []
        if cfg.backend == "thread":
            self.replicas: List[Replica] = [
                Replica(i, system, store, engine_cfg,
                        on_complete=self._on_complete, tracer=tracer)
                for i in range(cfg.n_replicas)
            ]
        elif cfg.backend == "process":
            self.replicas = self._build_process_cell(engine_cfg)
        else:
            raise ValueError(
                f"unknown replica backend {cfg.backend!r} "
                "(expected 'thread' or 'process')")
        self._lock = threading.Lock()
        # (key, policy_version, index_epoch) -> replica whose result
        # cache owns it (LRU-bounded); repeats route back there
        # regardless of depth — a hit is nearly free, a balanced miss
        # elsewhere costs a rollout.  Versioned like the cache keys
        # themselves: a policy publish or index epoch swap retires the
        # old entries by never looking them up again (LRU reclaims
        # them), so stale affinity can't pin post-swap traffic to a
        # replica whose entry is already invalid.
        self._key_owner: "OrderedDict" = OrderedDict()
        # qid -> canonical key memo for the slab front door (append-only
        # log keeps it sound; bounded inside).
        self._qkey_cache = QueryKeyCache(system.log)
        self._lags: Deque[int] = deque(maxlen=cfg.window)
        self._epoch_lags: Deque[int] = deque(maxlen=cfg.window)
        self._g_epoch_lag = self.registry.gauge("index.epoch_lag")
        self._latencies: Deque[float] = deque(maxlen=cfg.window)
        self.n_submitted = 0
        self.n_responses = 0
        self.n_shed = 0
        self._started = False

    # -------------------------------------------------------- process cell
    def _build_process_cell(self, engine_cfg: EngineConfig) -> List:
        """Spawn-side of ``backend="process"``: save the base index
        once (every worker ``np.memmap``s that ONE copy), build the
        per-replica spec factory, and subscribe relay fan-outs so each
        policy snapshot / index epoch publish reaches every worker over
        its control pipe."""
        import tempfile
        from pathlib import Path

        from repro.index.live.segments import BaseSegment, MANIFEST_NAME

        from .proc import ProcessReplica

        self._proc_root = Path(self.cfg.proc_storage_dir
                               or tempfile.mkdtemp(prefix="repro-proc-cell-"))
        base_dir = self._proc_root / "base"
        if not (base_dir / MANIFEST_NAME).exists():
            # system.index is the PRISTINE corpus-built index even on a
            # live system (LiveIndex wraps a copy as generation 0) — the
            # workers derive their deterministic query log from it.
            BaseSegment.from_index(self.system.index).save(base_dir)
        self._proc_base_dir = str(base_dir)
        # Postmortem bundles land next to the cell's segments — one
        # durable artifact per salvaged worker death (obs.FlightRecorder).
        self.recorder.bundle_dir = self._proc_root / "postmortem"
        replicas = [
            ProcessReplica(i, self._worker_spec,
                           on_complete=self._on_complete,
                           keep=engine_cfg.keep,
                           ring_slots=self.cfg.proc_ring_slots,
                           max_restarts=self.cfg.max_worker_restarts,
                           cache_mirror_capacity=engine_cfg.cache_capacity,
                           tracer=self.tracer,
                           recorder=self.recorder)
            for i in range(self.cfg.n_replicas)
        ]
        return replicas

    def _epoch_gen_dir(self, epoch) -> str:
        """On-disk home of an epoch's base generation — saved under the
        cell dir once if the live index is storage-less."""
        from repro.index.live.segments import MANIFEST_NAME

        base = epoch.view.base
        if base.path:
            return str(base.path)
        gen_dir = self._proc_root / f"gen-{base.generation:05d}"
        if not (gen_dir / MANIFEST_NAME).exists():
            base.save(gen_dir)
        return str(gen_dir)

    def _worker_spec(self, idx: int, req_info, resp_info):
        """Capture the head serving state for one worker (re)spawn."""
        from .proc import WorkerSpec

        snap = self.store.snapshot()
        index_store = getattr(self.system, "index_epoch_store", None)
        live = index_store is not None
        init_epoch = None
        capacity = None
        index_sb = 64
        if live:
            epoch = index_store.snapshot()
            init_epoch = (epoch.version, epoch.generation,
                          self._epoch_gen_dir(epoch), tuple(epoch.ops))
            capacity = epoch.view.capacity_docs
            index_sb = index_store.staleness_bound
        return WorkerSpec(
            replica_idx=idx,
            sys_cfg=self.system.cfg,
            base_dir=self._proc_base_dir,
            live=live,
            capacity_docs=capacity,
            init_epoch=init_epoch,
            # MappingProxyType snapshots aren't picklable; plain dicts
            # are (policies pickle via their registered pytree leaves).
            init_policy=(snap.version, dict(snap.policies),
                         dict(snap.fallbacks)),
            l1_params=self.system.l1_params,
            bins=self.system.bins,
            qcfg=self.system.qcfg,
            engine_cfg=self._engine_cfg,
            policy_staleness_bound=self.store.staleness_bound,
            index_staleness_bound=index_sb,
            req_ring=req_info,
            resp_ring=resp_info,
            trace=self.tracer.enabled)

    def _subscribe_relays(self) -> None:
        """Fan every publish out to the worker processes.  Deliveries
        run on the publisher's thread; per-worker pipes keep FIFO order,
        so a worker always applies versions monotonically."""
        def relay_policy(snap) -> None:
            policies, fallbacks = dict(snap.policies), dict(snap.fallbacks)
            for r in self.replicas:
                r.relay_policy(snap.version, policies, fallbacks)

        self._unsubscribes.append(self.store.subscribe(relay_policy))
        index_store = getattr(self.system, "index_epoch_store", None)
        if index_store is not None:
            def relay_epoch(epoch) -> None:
                gen_dir = self._epoch_gen_dir(epoch)
                for r in self.replicas:
                    r.relay_epoch(epoch.version, epoch.generation,
                                  gen_dir, tuple(epoch.ops))

            self._unsubscribes.append(index_store.subscribe(relay_epoch))

    def _subscribe_events(self) -> None:
        """Record every publish into the flight recorder (both
        backends): policy publishes, and index epoch swaps split into
        plain swaps vs merges (a merge publishes a NEW base generation
        — the generation bump is the tell)."""
        def on_policy(snap) -> None:
            self.events.record("policy_publish", version=snap.version,
                               n_policies=len(snap.policies),
                               n_fallbacks=len(snap.fallbacks))

        self._unsubscribes.append(self.store.subscribe(on_policy))
        index_store = getattr(self.system, "index_epoch_store", None)
        if index_store is not None:
            with self._lock:
                if self._last_generation is None:
                    self._last_generation = index_store.snapshot().generation

            def on_epoch(epoch) -> None:
                gen = epoch.generation
                with self._lock:
                    merged = (self._last_generation is not None
                              and gen > self._last_generation)
                    self._last_generation = gen
                self.events.record(
                    "index_merge" if merged else "epoch_swap",
                    version=epoch.version, generation=gen,
                    n_ops=len(epoch.ops))

            self._unsubscribes.append(index_store.subscribe(on_epoch))

    # ------------------------------------------------------------ control
    def start(self) -> "ReplicaSet":
        for r in self.replicas:
            r.start()
        if self.cfg.backend == "process":
            self._subscribe_relays()
        self._subscribe_events()
        self._started = True
        return self

    def stop(self, drain: bool = True) -> None:
        for unsub in self._unsubscribes:
            unsub()
        self._unsubscribes = []
        for r in self.replicas:
            r.stop(drain=drain)
        self._started = False

    def __enter__(self) -> "ReplicaSet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    def warmup(self) -> int:
        """Pre-compile every replica's executables (serially, before the
        worker threads race the compiler); returns total compiles."""
        return sum(r.warmup() for r in self.replicas)

    # ------------------------------------------------------------- submit
    def submit(self, qid: int) -> ClusterTicket:
        """Admit one query down the service ladder and route it; always
        returns a ticket that completes with either a ServeResponse or
        an explicit Shed — never drops."""
        qid = int(qid)
        cat = int(self.system.log.category[qid])
        key = canonical_query_key(self.system.log.terms[qid], cat)
        ticket = ClusterTicket(qid, cat, cache_key=key)
        # Affinity is versioned alongside the cache entries it points
        # at: after a policy publish or an index epoch swap, the old
        # (key, version, epoch) rows simply stop matching.
        okey = (key, self.store.version,
                getattr(self.system, "index_epoch", 0))
        # One trace track per ticket: the admit → queue → batch →
        # execute → respond chain lives on it, ended at completion.
        ticket.span = self.tracer.root_span("ticket", qid=qid, category=cat)
        self._c_submitted.inc()
        with self._lock:
            self.n_submitted += 1
            owner = self._key_owner.get(okey)
        # Sticky routing (and the CACHED_ONLY rung) only pay while the
        # owner's result cache still holds a CURRENT entry for the key
        # — cache_has folds in the replica's pinned policy version and
        # index epoch (the repeat is ~free there); once evicted or
        # invalidated by a swap, the request must load-balance like any
        # other miss — pinning dead keys to a busy owner is exactly
        # how tails grow.
        if owner is not None and not self.replicas[owner].cache_has(key):
            owner = None
        # The SHALLOW rung is only real if the head snapshot ships a
        # fallback policy for this category (they travel together).
        adm_span = ticket.span.child("admit")
        adm = self.admission.decide(
            qid, cache_available=owner is not None,
            shallow_available=cat in self.store.snapshot().fallbacks)
        adm_span.end(level=ServiceLevel(adm.level).name, est_u=adm.est_u)
        ticket.est_u = adm.est_u
        ticket.reserved_u = adm.reserved_u
        ticket.level = adm.level
        # Service-level transitions are fleet state changes worth a
        # flight-recorder entry: record when the admitted level CHANGES
        # (FULL→SHALLOW means pressure arrived; back again means it
        # passed), not per ticket — the ring must hold history, not QPS.
        with self._lock:
            level_changed = self._last_level != int(adm.level)
            prev_level = self._last_level
            self._last_level = int(adm.level)
        if level_changed:
            self.events.record(
                "level_transition",
                level=ServiceLevel(adm.level).name,
                prev=(ServiceLevel(prev_level).name
                      if prev_level is not None else None),
                qid=qid)
        if adm.level == ServiceLevel.SHED:
            self._c_shed.inc()
            self.events.record("shed", where="admission",
                               reason="u_budget_hot", qid=qid)
            with self._lock:
                self.n_shed += 1
            self.tap.record(qid, cat, ServiceLevel.SHED,
                            index_epoch=getattr(self.system,
                                                "index_epoch", 0))
            ticket.complete(Shed(qid, cat, adm.est_u, "u_budget_hot"))
            if ticket.span:
                ticket.span.end(level="SHED", reason="u_budget_hot")
            return ticket
        if adm.level == ServiceLevel.CACHED_ONLY:
            # only priced when the owner's cache holds the key; route
            # straight there — no other replica can serve it for ~0 u
            idx = owner
        else:
            # The sticky path (the common case under a hot head) needs
            # only the owner's gauge, so skip the per-replica sweep
            # unless the router itself says it will need real depths
            # (owner absent, or saturated past its spill threshold).
            if (owner is not None
                    and not self.router.wants_full_depths(
                        d_owner := self.replicas[owner].depth())):
                depths = [0] * len(self.replicas)
                depths[owner] = d_owner
            else:
                depths = [r.depth() for r in self.replicas]
                if owner is not None:
                    # keep the router's decision consistent with the
                    # gauge that just crossed the threshold
                    depths[owner] = d_owner
            idx = self.router.pick(stable_query_hash(key), depths, owner)
        if ticket.span:
            ticket.span.instant("route", replica=idx,
                                sticky=owner is not None and idx == owner)
            # Covers route → replica-thread pickup; the replica ends it.
            ticket.inbox_span = ticket.span.child("inbox", replica=idx)
        with self._lock:
            self._key_owner[okey] = idx
            self._key_owner.move_to_end(okey)
            while len(self._key_owner) > self.cfg.affinity_table:
                self._key_owner.popitem(last=False)
        self.replicas[idx].enqueue(ticket)
        return ticket

    def serve(self, qids: Sequence[int],
              timeout_s: float = 120.0) -> List[Result]:
        """Synchronous driver: submit a stream, wait for every ticket,
        return results (ServeResponse | Shed) in submission order."""
        if not self._started:
            raise RuntimeError("ReplicaSet not started (use start() or `with`)")
        tickets = [self.submit(q) for q in qids]
        out = []
        for t in tickets:
            res = t.result(timeout=timeout_s)
            if res is None:
                raise TimeoutError(
                    f"qid {t.qid} not served within {timeout_s}s "
                    f"(replica {t.replica})")
            out.append(res)
        return out

    # ------------------------------------------------------- bulk (slabs)
    def submit_many(self, qids) -> List[ClusterTicket]:
        """Admit a whole arrival slab; returns one ticket per query.

        The batched front door: canonical keys come from the qid memo,
        owner lookups take ONE affinity-table lock, the whole slab is
        priced by :meth:`AdmissionController.decide_many` (one ledger
        lock, vectorized estimation), replica depths are snapshotted
        once and updated locally as the slab routes, and each replica
        receives its share through ``enqueue_many`` (one condition
        acquisition + one wake per replica instead of per ticket).

        Semantics match a loop of :meth:`submit` calls: every ticket
        completes with a ServeResponse or an explicit Shed, admission
        levels are identical to the sequential walk (decide_many is
        bit-parity pinned), and level-transition / shed events land in
        the flight recorder the same way.  Routing may differ from the
        sequential interleaving only through the depth snapshot (one
        sweep per slab, locally incremented, instead of re-reading
        depths between arrivals) — response content is
        replica-independent, so parity tests pin doc ids / scores / u,
        not placement.
        """
        qids = [int(q) for q in qids]
        n = len(qids)
        if n == 0:
            return []
        log = self.system.log
        cats = np.asarray(log.category)[np.asarray(qids, np.int64)]
        key_of = self._qkey_cache.key
        keys = [key_of(q, int(c)) for q, c in zip(qids, cats)]
        version = self.store.version
        epoch = getattr(self.system, "index_epoch", 0)
        tracing = self.tracer.enabled
        slab_span = (self.tracer.span("slab_admit", n=n) if tracing
                     else None)
        tickets = []
        for q, c, k in zip(qids, cats, keys):
            t = ClusterTicket(q, int(c), cache_key=k)
            if tracing:
                t.span = self.tracer.root_span("ticket", qid=q,
                                               category=int(c))
            tickets.append(t)
        self._c_submitted.inc(n)
        with self._lock:
            self.n_submitted += n
            owners = [self._key_owner.get((k, version, epoch))
                      for k in keys]
        replicas = self.replicas
        owners = [o if (o is not None and replicas[o].cache_has(k))
                  else None
                  for o, k in zip(owners, keys)]
        fallbacks = self.store.snapshot().fallbacks
        levels, reserves, est_full = self.admission.decide_many(
            qids,
            cache_available=[o is not None for o in owners],
            shallow_available=[int(c) in fallbacks for c in cats])
        # Flight-recorder bookkeeping: transitions on CHANGE only, same
        # contract as the sequential path.
        transitions = []
        with self._lock:
            for i in range(n):
                lvl = int(levels[i])
                if self._last_level != lvl:
                    transitions.append((lvl, self._last_level, qids[i]))
                    self._last_level = lvl
        for lvl, prev, qid in transitions:
            self.events.record(
                "level_transition", level=ServiceLevel(lvl).name,
                prev=(ServiceLevel(prev).name if prev is not None
                      else None), qid=qid)
        depths = None
        shed_level = int(ServiceLevel.SHED)
        cached_only = int(ServiceLevel.CACHED_ONLY)
        level_of = {int(l): l for l in ServiceLevel}   # skip the enum ctor
        n_shed = 0
        assigned = []                       # (okey, idx) owner updates
        groups: "OrderedDict[int, list]" = OrderedDict()
        for i, ticket in enumerate(tickets):
            lvl = int(levels[i])
            ticket.est_u = float(est_full[i])
            ticket.reserved_u = float(reserves[i])
            ticket.level = level_of[lvl]
            if lvl == shed_level:
                n_shed += 1
                self.events.record("shed", where="admission",
                                   reason="u_budget_hot", qid=ticket.qid)
                self.tap.record(ticket.qid, ticket.category,
                                ServiceLevel.SHED, index_epoch=epoch)
                ticket.complete(Shed(ticket.qid, ticket.category,
                                     ticket.est_u, "u_budget_hot"))
                if ticket.span:
                    ticket.span.end(level="SHED", reason="u_budget_hot")
                continue
            owner = owners[i]
            if lvl == cached_only:
                idx = owner
            else:
                if depths is None:
                    depths = [r.depth() for r in replicas]
                idx = self.router.pick(stable_query_hash(keys[i]),
                                       depths, owner)
                # Local view of the work this slab already placed: the
                # sequential path re-reads depths per arrival and sees
                # its own earlier enqueues the same way.
                depths[idx] += 1
            if ticket.span:
                ticket.span.instant("route", replica=idx,
                                    sticky=owner is not None
                                    and idx == owner)
                ticket.inbox_span = ticket.span.child("inbox", replica=idx)
            assigned.append(((keys[i], version, epoch), idx))
            groups.setdefault(idx, []).append(ticket)
        if n_shed:
            self._c_shed.inc(n_shed)
            with self._lock:
                self.n_shed += n_shed
        if assigned:
            with self._lock:
                for okey, idx in assigned:
                    self._key_owner[okey] = idx
                    self._key_owner.move_to_end(okey)
                while len(self._key_owner) > self.cfg.affinity_table:
                    self._key_owner.popitem(last=False)
        for idx, group in groups.items():
            replicas[idx].enqueue_many(group)
        if slab_span:
            slab_span.end(shed=n_shed, routed=len(assigned))
        return tickets

    def serve_many(self, qids, timeout_s: float = 120.0) -> List[Result]:
        """Synchronous slab driver: bulk-submit, wait for every ticket,
        return results in submission order (the batched sibling of
        :meth:`serve`)."""
        if not self._started:
            raise RuntimeError("ReplicaSet not started (use start() or `with`)")
        tickets = self.submit_many(qids)
        out = []
        for t in tickets:
            res = t.result(timeout=timeout_s)
            if res is None:
                raise TimeoutError(
                    f"qid {t.qid} not served within {timeout_s}s "
                    f"(replica {t.replica})")
            out.append(res)
        return out

    # --------------------------------------------------------- completion
    def _on_complete(self, ticket: ClusterTicket, result: Result) -> None:
        if isinstance(result, ServeResponse):
            # Cached responses replay a previous rollout's u — only a
            # fresh execution is a realized observation the estimator
            # should learn from (at the level+version that produced it).
            self.admission.release(
                ticket.reserved_u,
                actual_u=None if result.cached else result.u,
                qid=ticket.qid, level=result.level,
                version=result.policy_version,
                index_epoch=result.index_epoch)
            lag = max(0, self.store.version - result.policy_version)
            # Freshness lag: epochs between the index that produced the
            # response and the head — how stale the answer's view of
            # the corpus was, the live-index analogue of policy lag.
            head_epoch = getattr(self.system, "index_epoch", 0)
            epoch_lag = max(0, head_epoch - result.index_epoch)
            with self._lock:
                self.n_responses += 1
                self._lags.append(lag)
                self._epoch_lags.append(epoch_lag)
                self._latencies.append(ticket.latency_s)
            self._g_epoch_lag.set(epoch_lag)
            self.tap.record(ticket.qid, ticket.category, ticket.level,
                            index_epoch=result.index_epoch)
            if ticket.span:
                ticket.span.end(level=ServiceLevel(result.level).name,
                                u=result.u, cached=result.cached,
                                version=result.policy_version,
                                index_epoch=result.index_epoch)
        else:  # shed inside the replica (queue full / shutdown / error)
            self.admission.release(ticket.reserved_u)
            self._c_shed_replica.inc()
            self.events.record("shed", where="replica",
                               reason=getattr(result, "reason", None),
                               qid=ticket.qid, replica=ticket.replica)
            with self._lock:
                self.n_shed += 1
            self.tap.record(ticket.qid, ticket.category, ServiceLevel.SHED,
                            index_epoch=getattr(self.system,
                                                "index_epoch", 0))
            if ticket.span:
                ticket.span.end(level="SHED",
                                reason=getattr(result, "reason", None))

    # -------------------------------------------------------------- stats
    @property
    def proc_cell_dir(self):
        """Storage dir shared by the process cell's workers (the mmap'd
        base + generation segments); None on the thread backend."""
        root = getattr(self, "_proc_root", None)
        return str(root) if root is not None else None

    def metrics_snapshot(self) -> dict:
        """The fleet metrics view: every replica registry (request/
        latency/u/queue-wait instruments, cache counters) folded into
        one snapshot with the cluster-plane instruments — counters and
        histograms add, gauges take their declared aggregation (max by
        default, sum for depth-style gauges).  JSON-serializable; this
        is what ``--metrics-json`` writes."""
        return merge_snapshots(
            [r.metrics_snapshot() for r in self.replicas]
            + [self.registry.snapshot()])

    def statusz(self, watchdog: Optional[HeartbeatWatchdog] = None) -> dict:
        """One-page cell introspection JSON (repro.obs.health)."""
        return _health.statusz(self, watchdog)

    def trace_entries(self) -> list:
        """The fleet's merged span entries: the parent tracer's log
        (admit/route/ring spans, thread-replica engine spans) plus every
        process replica's rebased worker tail — one coherent timeline on
        the parent clock."""
        entries: list = []
        if self.tracer.enabled:
            entries.extend(self.tracer.log.snapshot())
        for r in self.replicas:
            entries.extend(r.trace_entries())
        return entries

    def write_trace(self, path, process_name: str = "repro-cluster") -> int:
        """Export the merged fleet timeline as one Chrome/Perfetto
        trace; returns the number of span entries written."""
        entries = self.trace_entries()
        write_chrome_entries(path, entries, process_name=process_name)
        return len(entries)

    def version_lag(self) -> dict:
        """Current per-replica lag vs the store head, plus the response
        window's observed lag distribution."""
        head = self.store.version
        current = [max(0, head - r.policy_version) for r in self.replicas]
        with self._lock:
            lags = list(self._lags)
        return {
            "head_version": head,
            "replica_versions": [r.policy_version for r in self.replicas],
            "current_max": max(current) if current else 0,
            "observed_max": max(lags) if lags else 0,
            "observed_mean": float(np.mean(lags)) if lags else 0.0,
        }

    def stats(self) -> dict:
        with self._lock:
            lat = list(self._latencies)
            n_sub, n_resp, n_shed = (self.n_submitted, self.n_responses,
                                     self.n_shed)
        lag = self.version_lag()
        with self._lock:
            epoch_lags = list(self._epoch_lags)
        return {
            "n_replicas": len(self.replicas),
            "index_epoch_head": getattr(self.system, "index_epoch", 0),
            "replica_index_epochs": [r.index_epoch for r in self.replicas],
            "epoch_lag_observed_max": max(epoch_lags) if epoch_lags else 0,
            "epoch_lag_observed_mean": (float(np.mean(epoch_lags))
                                        if epoch_lags else 0.0),
            "n_submitted": n_sub,
            "n_responses": n_resp,
            "n_shed": n_shed,
            "shed_rate": n_shed / n_sub if n_sub else 0.0,
            "served_fraction": n_resp / n_sub if n_sub else 0.0,
            "latency_p50_ms": _pct(lat, 0.50) * 1e3,
            "latency_p99_ms": _pct(lat, 0.99) * 1e3,
            "version_lag_observed_max": lag["observed_max"],
            "version_lag_observed_mean": lag["observed_mean"],
            "version_lag_current_max": lag["current_max"],
            "head_version": lag["head_version"],
            "router": self.router.stats(),
            "admission": self.admission.stats(),
            "tap": self.tap.stats(),
            "replicas": [r.summary() for r in self.replicas],
        }
