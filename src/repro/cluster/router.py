"""Query routing across serving replicas.

Round-robin is the strawman: it ignores both load (a replica stuck
behind an expensive CAT1 micro-batch keeps receiving its share while
neighbours idle) and locality (a hot navigational query lands on every
replica, paying one result-cache miss per replica instead of one per
fleet).  :class:`QueueAwareRouter` fixes both: a key the cluster has
routed before goes straight back to the replica whose result cache
owns it — the repeat is nearly free there — while a first-seen key
starts at its hash-preferred replica and spills to the least-loaded
one when the preferred depth (queued + inflight, the ``ServeEngine``
gauges) exceeds the minimum by more than ``spill_margin``.
"""
from __future__ import annotations

import itertools
import threading
import zlib
from typing import Optional, Sequence

__all__ = ["stable_query_hash", "Router", "RoundRobinRouter",
           "QueueAwareRouter", "make_router"]


def stable_query_hash(key) -> int:
    """Process-independent hash of a canonical query key (cache
    affinity must survive restarts and not depend on PYTHONHASHSEED)."""
    return zlib.crc32(repr(key).encode())


class Router:
    """Protocol: pick a replica index for a request.

    ``pick(key_hash, depths, owner)`` sees the request's stable
    query-key hash, a per-replica depth snapshot, and — when the
    cluster has routed this key before — the replica whose result cache
    owns it.  Implementations must be thread-safe (the cluster may be
    fed from several submitter threads).
    """

    name: str = ""

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        raise NotImplementedError

    def stats(self) -> dict:
        return {"router": self.name}


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._counter = itertools.count()

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        return next(self._counter) % len(depths)


class QueueAwareRouter(Router):
    """Cache-owner-sticky, depth-balanced routing.

    A key already routed somewhere goes back to that replica regardless
    of depth — its result cache makes the repeat nearly free, while a
    "balanced" miss elsewhere costs a full rollout.  First-seen keys
    start from their hash-preferred replica and spill to the
    least-loaded one when the preferred queue is ``spill_margin``
    deeper; the cluster then records the pick as the key's owner.
    """

    name = "queue_aware"

    def __init__(self, spill_margin: int = 4):
        if spill_margin < 0:
            raise ValueError("spill_margin must be >= 0")
        self.spill_margin = spill_margin
        self._lock = threading.Lock()
        self.affinity_picks = 0
        self.sticky_picks = 0
        self.spills = 0

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        n = len(depths)
        if owner is not None and 0 <= owner < n:
            with self._lock:
                self.sticky_picks += 1
            return owner
        pref = key_hash % n
        best = min(range(n), key=depths.__getitem__)
        if depths[pref] - depths[best] > self.spill_margin:
            with self._lock:
                self.spills += 1
            return best
        with self._lock:
            self.affinity_picks += 1
        return pref

    def stats(self) -> dict:
        total = self.affinity_picks + self.sticky_picks + self.spills
        return {
            "router": self.name,
            "spill_margin": self.spill_margin,
            "affinity_picks": self.affinity_picks,
            "sticky_picks": self.sticky_picks,
            "spills": self.spills,
            "spill_rate": self.spills / total if total else 0.0,
        }


def make_router(name: str, spill_margin: int = 4) -> Router:
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "queue_aware":
        return QueueAwareRouter(spill_margin=spill_margin)
    raise ValueError(
        f"unknown routing policy {name!r}; available: "
        "('queue_aware', 'round_robin')")
