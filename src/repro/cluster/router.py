"""Query routing across serving replicas.

Round-robin is the strawman: it ignores both load (a replica stuck
behind an expensive CAT1 micro-batch keeps receiving its share while
neighbours idle) and locality (a hot navigational query lands on every
replica, paying one result-cache miss per replica instead of one per
fleet).  :class:`QueueAwareRouter` fixes both: a key the cluster has
routed before goes straight back to the replica whose result cache
owns it — the repeat is nearly free there — while a first-seen key
starts at its hash-preferred replica and spills to the least-loaded
one when the preferred depth (queued + inflight, the ``ServeEngine``
gauges) exceeds the minimum by more than ``spill_margin``.
"""
from __future__ import annotations

import itertools
import threading
import zlib
from typing import Optional, Sequence

from repro.obs import MetricsRegistry

__all__ = ["stable_query_hash", "Router", "RoundRobinRouter",
           "QueueAwareRouter", "make_router"]


def stable_query_hash(key) -> int:
    """Process-independent hash of a canonical query key (cache
    affinity must survive restarts and not depend on PYTHONHASHSEED)."""
    return zlib.crc32(repr(key).encode())


class Router:
    """Protocol: pick a replica index for a request.

    ``pick(key_hash, depths, owner)`` sees the request's stable
    query-key hash, a per-replica depth snapshot, and — when the
    cluster has routed this key before — the replica whose result cache
    owns it.  Implementations must be thread-safe (the cluster may be
    fed from several submitter threads).
    """

    name: str = ""

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        raise NotImplementedError

    def wants_full_depths(self, owner_depth: int) -> bool:
        """Whether ``pick`` will need the whole fleet's depth snapshot
        for a request whose cache owner currently carries
        ``owner_depth`` units of work.  The cluster uses this to skip
        the per-replica gauge sweep on the sticky fast path; the rule
        lives HERE so it can never drift from ``pick``'s own
        sticky-vs-spill decision."""
        return False

    def stats(self) -> dict:
        return {"router": self.name}


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._counter = itertools.count()

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        return next(self._counter) % len(depths)


class QueueAwareRouter(Router):
    """Cache-owner-sticky, depth-balanced routing with owner-saturation
    spill.

    A key already routed somewhere goes back to that replica — its
    result cache makes the repeat nearly free, while a "balanced" miss
    elsewhere costs a full rollout — UNLESS the owner is saturated: a
    likely hit queued behind ``owner_spill_depth`` units of pending
    work pays the owner's whole backlog in latency, which is worse than
    one balanced-path rollout on an idle neighbour.  Saturated-owner
    requests therefore fall through to the depth-balanced path (and the
    cluster records the new pick as the key's owner, so the hot key's
    cache footprint migrates off the hot replica instead of feeding it).

    First-seen keys start from their hash-preferred replica and spill
    to the least-loaded one when the preferred queue is ``spill_margin``
    deeper; the cluster then records the pick as the key's owner.
    """

    name = "queue_aware"

    def __init__(self, spill_margin: int = 4,
                 owner_spill_depth: Optional[int] = 32,
                 registry: Optional[MetricsRegistry] = None):
        if spill_margin < 0:
            raise ValueError("spill_margin must be >= 0")
        if owner_spill_depth is not None and owner_spill_depth < 0:
            raise ValueError("owner_spill_depth must be >= 0 (or None)")
        self.spill_margin = spill_margin
        self.owner_spill_depth = owner_spill_depth
        self._lock = threading.Lock()
        self.affinity_picks = 0
        self.sticky_picks = 0
        self.spills = 0
        self.owner_spills = 0
        reg = registry if registry is not None else MetricsRegistry()
        self._pick_counters = {
            kind: reg.counter("router.picks", kind=kind)
            for kind in ("sticky", "affinity", "spill", "owner_spill")}

    def wants_full_depths(self, owner_depth: int) -> bool:
        return (self.owner_spill_depth is not None
                and owner_depth > self.owner_spill_depth)

    def pick(self, key_hash: int, depths: Sequence[int],
             owner: Optional[int] = None) -> int:
        n = len(depths)
        avoid = None
        if owner is not None and 0 <= owner < n:
            if not self.wants_full_depths(depths[owner]):
                with self._lock:
                    self.sticky_picks += 1
                self._pick_counters["sticky"].inc()
                return owner
            # saturated owner: a likely hit is not worth its backlog —
            # fall through to the depth-balanced first-seen path
            with self._lock:
                self.owner_spills += 1
            self._pick_counters["owner_spill"].inc()
            avoid = owner
        pref = key_hash % n
        best = min(range(n), key=depths.__getitem__)
        if avoid is not None and pref == avoid:
            # the hash-preferred replica IS the saturated owner; going
            # back there would make the spill a no-op (unless the whole
            # fleet is even deeper, in which case best == owner and the
            # owner genuinely is the least bad choice) — counted as a
            # spill so stats' pick total stays complete
            with self._lock:
                self.spills += 1
            self._pick_counters["spill"].inc()
            return best
        if depths[pref] - depths[best] > self.spill_margin:
            with self._lock:
                self.spills += 1
            self._pick_counters["spill"].inc()
            return best
        with self._lock:
            self.affinity_picks += 1
        self._pick_counters["affinity"].inc()
        return pref

    def stats(self) -> dict:
        total = self.affinity_picks + self.sticky_picks + self.spills
        return {
            "router": self.name,
            "spill_margin": self.spill_margin,
            "owner_spill_depth": self.owner_spill_depth,
            "affinity_picks": self.affinity_picks,
            "sticky_picks": self.sticky_picks,
            "spills": self.spills,
            "owner_spills": self.owner_spills,
            "spill_rate": self.spills / total if total else 0.0,
        }


def make_router(name: str, spill_margin: int = 4,
                owner_spill_depth: Optional[int] = 32,
                registry: Optional[MetricsRegistry] = None) -> Router:
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "queue_aware":
        return QueueAwareRouter(spill_margin=spill_margin,
                                owner_spill_depth=owner_spill_depth,
                                registry=registry)
    raise ValueError(
        f"unknown routing policy {name!r}; available: "
        "('queue_aware', 'round_robin')")
