"""One serving replica: a `ServeEngine` owned by a worker thread.

`ServeEngine` is single-threaded by design (submit/flush/take_response
mutate the batcher and cache without locks), so the replica gives each
engine exactly one driving thread and a thread-safe inbox in front of
it.  The worker drains the inbox into the engine, flushes when the
inbox runs dry (the latency path) and steps full buckets otherwise
(the throughput path), then fulfils cluster tickets from the engine's
completed responses.  Policy hot-swaps need no extra plumbing: the
engine refreshes to the store head on every submit/drain, so replicas
adopt new snapshots independently — the fleet may briefly serve mixed
versions, bounded by the store's staleness check.

A failed micro-batch is retried (the engine re-queues admitted
requests, FIFO preserved); after ``max_consecutive_failures`` the
replica fails its outstanding tickets with an explicit
:class:`~repro.cluster.admission.Shed` rather than dropping them.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, Optional, Union

from repro.core.versioned import StaleVersionError
from repro.obs import NULL_TRACER, Tracer
from repro.serving import (AdmissionError, CacheOnlyMiss, EngineConfig,
                           ServeEngine, ServiceLevel)
from repro.serving.engine import (SLAB_ADMISSION_REJECT,
                                  SLAB_CACHED_ONLY_MISS, ServeResponse)
from repro.serving.telemetry import Telemetry

from .admission import Shed

__all__ = ["ClusterTicket", "Replica"]

Result = Union[ServeResponse, Shed]


class ClusterTicket:
    """Cluster-level future for one submitted query."""

    def __init__(self, qid: int, category: int, est_u: float = 0.0,
                 cache_key=None,
                 level: ServiceLevel = ServiceLevel.FULL):
        self.qid = qid
        self.category = category
        self.est_u = est_u
        self.cache_key = cache_key
        self.level = level            # admission's ladder decision
        self.reserved_u = 0.0         # what the ledger holds for us
        self.replica: Optional[int] = None
        # Trace context (repro.obs): the cluster opens ``span`` (the
        # ticket's root) at admission and ends it at completion;
        # ``inbox_span`` covers route → replica-thread pickup (or, on
        # the process backend, route → ring push); ``ring_span`` is the
        # process backend's parent-side cover of the worker round trip
        # (ring push → response pop), which encloses every span the
        # worker records for this ticket.
        self.span = None
        self.inbox_span = None
        self.ring_span = None
        self.t_submit = Telemetry.now()
        self.t_done: Optional[float] = None
        # The Event is created LAZILY, only when a waiter arrives before
        # completion: on the cache-hot slab path nearly every ticket
        # completes inline at submit, and an eager Event costs an Event
        # + Condition + two locks + a waiter deque per ticket — pure
        # allocation/GC pressure that the ratio benches see directly.
        self._event: Optional[threading.Event] = None
        self._done = False
        self._done_lock = threading.Lock()
        self._result: Optional[Result] = None
        self._inbox_work = 0          # 1 while counted as a likely miss

    def complete(self, result: Result) -> bool:
        """Install the result; the FIRST completion wins.  Returns False
        for late duplicates — e.g. the original response of a ticket
        that was requeued after a worker death and already answered by
        the respawned worker.  Callers that do per-completion accounting
        (telemetry, tap records, ledger releases) must gate on the
        return value, or a retried ticket is double-counted."""
        with self._done_lock:
            if self._done:
                return False
            self.t_done = Telemetry.now()
            self._result = result
            self._done = True
            if self._event is not None:
                self._event.set()
            return True

    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None) -> Optional[Result]:
        """The ServeResponse or Shed; None only on timeout."""
        if self._done:
            return self._result
        with self._done_lock:
            if self._done:
                return self._result
            ev = self._event
            if ev is None:
                ev = self._event = threading.Event()
        if not ev.wait(timeout):
            return None
        return self._result

    @property
    def shed(self) -> bool:
        return isinstance(self._result, Shed)

    @property
    def latency_s(self) -> float:
        if self.t_done is None:
            raise RuntimeError("ticket not completed yet")
        return self.t_done - self.t_submit


class Replica:
    def __init__(self, idx: int, system, store,
                 engine_cfg: EngineConfig = EngineConfig(),
                 on_complete: Optional[Callable[[ClusterTicket, Result], None]] = None,
                 max_consecutive_failures: int = 3,
                 poll_s: float = 0.005,
                 tracer: Tracer = NULL_TRACER):
        self.idx = idx
        self.engine = ServeEngine(system, store, engine_cfg, tracer=tracer)
        self.on_complete = on_complete
        self.max_consecutive_failures = max_consecutive_failures
        self.poll_s = poll_s
        self._inbox: deque = deque()
        self._inbox_work = 0          # likely-miss tickets in the inbox
        self._cond = threading.Condition()
        self._rid2ticket: Dict[int, ClusterTicket] = {}
        self._stopping = False
        self._abandon = False         # stop(drain=False): shed, don't serve
        self._thread: Optional[threading.Thread] = None
        self.n_enqueued = 0
        self.n_completed = 0

    # ------------------------------------------------------------- control
    def start(self) -> "Replica":
        if self._thread is not None:
            raise RuntimeError(f"replica {self.idx} already started")
        self._thread = threading.Thread(
            target=self._run, name=f"replica-{self.idx}", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker; with ``drain`` (default) everything already
        enqueued is served first, otherwise pending tickets are failed
        with an explicit Shed."""
        with self._cond:
            self._stopping = True
            self._abandon = not drain
            if not drain or self._thread is None:
                # no worker will ever drain these: shed, don't strand
                while self._inbox:
                    t = self._inbox.popleft()
                    self._inbox_work -= t._inbox_work
                    t._inbox_work = 0
                    self._finish(t, Shed(t.qid, t.category, t.est_u,
                                         "replica_shutdown"))
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()

    # -------------------------------------------------------------- ingest
    def enqueue(self, ticket: ClusterTicket) -> None:
        ticket.replica = self.idx
        # Work-weighted depth accounting: a ticket whose key is already
        # in this replica's result cache costs ~nothing (it completes
        # inline at submit), so only likely misses count toward the
        # router's load signal.
        # cache_has composes the engine's pinned (policy version, index
        # epoch) into the lookup — a stale-epoch entry is a miss here
        # exactly as it will be at submit.
        likely_hit = (ticket.cache_key is not None
                      and self.engine.cache_has(ticket.cache_key))
        with self._cond:
            if self._stopping:
                self._finish(ticket, Shed(ticket.qid, ticket.category,
                                          ticket.est_u, "replica_shutdown"))
                return
            if not likely_hit:
                ticket._inbox_work = 1
                self._inbox_work += 1
            self._inbox.append(ticket)
            self.n_enqueued += 1
            self._cond.notify()

    def enqueue_many(self, tickets) -> None:
        """Batch ingest: the likely-hit probes (engine-cache reads, safe
        under the GIL) run outside the lock, then the whole group lands
        in the inbox under ONE condition acquisition with ONE wake."""
        if not tickets:
            return
        for t in tickets:
            t.replica = self.idx
        likely = [t.cache_key is not None
                  and self.engine.cache_has(t.cache_key)
                  for t in tickets]
        with self._cond:
            if self._stopping:
                for t in tickets:
                    self._finish(t, Shed(t.qid, t.category, t.est_u,
                                         "replica_shutdown"))
                return
            for t, hit in zip(tickets, likely):
                if not hit:
                    t._inbox_work = 1
                    self._inbox_work += 1
                self._inbox.append(t)
            self.n_enqueued += len(tickets)
            self._cond.notify()

    def depth(self) -> int:
        """Router load signal in units of WORK, not requests: likely
        cache misses waiting in the inbox, plus everything queued or
        executing in the engine (queued engine requests are misses by
        construction — hits complete inline at submit).  Safe to call
        from the router thread: ``inflight`` is a plain int and
        ``queue_depth`` snapshots the batcher's queues before
        counting."""
        return self._inbox_work + self.engine.queue_depth + self.engine.inflight

    @property
    def policy_version(self) -> int:
        return self.engine.policy_version

    @property
    def index_epoch(self) -> int:
        return self.engine.index_epoch

    # Replica protocol (shared with cluster.proc.ProcessReplica): the
    # ReplicaSet talks to replicas only through these, never through
    # ``.engine`` directly — a process-backed replica has no in-process
    # engine to reach into.
    def cache_has(self, base_key) -> bool:
        return self.engine.cache_has(base_key)

    def warmup(self) -> int:
        return self.engine.warmup()

    def metrics_snapshot(self) -> dict:
        return self.engine.telemetry.registry.snapshot()

    def summary(self) -> dict:
        out = self.engine.summary()
        out.update(replica=self.idx, n_enqueued=self.n_enqueued,
                   n_completed=self.n_completed, depth=self.depth())
        return out

    def health(self) -> dict:
        """Statusz liveness signals, shape-compatible with
        `ProcessReplica.health`.  A thread replica shares the parent's
        fault domain, so liveness is just the worker thread's and the
        heartbeat age is definitionally zero while it runs."""
        alive = self._thread is not None and self._thread.is_alive()
        return {
            "backend": "thread", "replica": self.idx, "alive": alive,
            "worker_pid": None, "n_restarts": 0,
            "heartbeat_age_s": 0.0 if alive else None,
            "pending": self.depth(),
        }

    def trace_entries(self) -> list:
        """Protocol parity with `ProcessReplica`: a thread replica's
        spans land directly in the shared tracer's log — nothing to
        merge."""
        return []

    # -------------------------------------------------------------- worker
    def _take_inbox(self):
        """Wait for work.  Returns (tickets, exit) — tickets may be
        empty on a timeout wake-up (used to re-try engine-queued work)."""
        with self._cond:
            if not self._inbox and (self._abandon or not self._rid2ticket):
                if self._stopping:
                    return [], True
                self._cond.wait(timeout=self.poll_s)
            tickets = list(self._inbox)
            self._inbox.clear()
            for t in tickets:
                self._inbox_work -= t._inbox_work
                t._inbox_work = 0
        return tickets, False

    def _submit_one(self, ticket: ClusterTicket) -> None:
        if ticket.inbox_span:
            ticket.inbox_span.end()
            ticket.inbox_span = None      # idempotent across retries
        try:
            rid = self.engine.submit(ticket.qid, ticket.level,
                                     span=ticket.span)
        except AdmissionError:
            self._finish(ticket, Shed(ticket.qid, ticket.category,
                                      ticket.est_u, "replica_queue_full"))
            return
        except CacheOnlyMiss:
            # An eviction raced the cluster's CACHED_ONLY routing
            # decision; there is no u reservation to roll out with, so
            # the ladder's last rung applies.
            self._finish(ticket, Shed(ticket.qid, ticket.category,
                                      ticket.est_u, "cached_only_miss"))
            return
        except StaleVersionError:
            # A publish (policy snapshot OR index epoch) raced between
            # the submit-time refresh and the staleness check; put the
            # ticket back and retry after the next refresh.
            with self._cond:
                ticket._inbox_work = 1
                self._inbox_work += 1
                self._inbox.appendleft(ticket)
            return
        except Exception as e:                    # noqa: BLE001
            # Any other submit failure must not kill the worker thread
            # (enqueue would keep feeding an undrained inbox): fail the
            # one ticket explicitly and keep serving.
            self._finish(ticket, Shed(ticket.qid, ticket.category,
                                      ticket.est_u,
                                      f"replica_error:{type(e).__name__}"))
            return
        self._rid2ticket[rid] = ticket
        resp = self.engine.take_response(rid)     # cache hits are inline
        if resp is not None:
            self._finish(self._rid2ticket.pop(rid), resp)

    def _submit_batch(self, tickets) -> None:
        """Feed a drained inbox group to the engine as ONE slab
        (`ServeEngine.submit_slab`): one refresh/validate, bulk cache
        probes and telemetry, per-ticket outcomes reconciled from the
        status array.  Used on the untraced path; per-ticket spans keep
        the scalar path so trace structure is unchanged when tracing."""
        for t in tickets:
            if t.inbox_span:
                t.inbox_span.end()
                t.inbox_span = None
        try:
            rids, statuses = self.engine.submit_slab(
                [t.qid for t in tickets],
                levels=[int(t.level) for t in tickets])
        except StaleVersionError:
            # Same retry contract as the scalar path: back to the inbox
            # front, FIFO preserved, served after the next refresh.
            with self._cond:
                for t in reversed(tickets):
                    t._inbox_work = 1
                    self._inbox_work += 1
                    self._inbox.appendleft(t)
            return
        except Exception:                         # noqa: BLE001
            # Slab-level failure: fall back to per-ticket submits so a
            # single poisoned arrival sheds alone instead of taking the
            # whole group down with it.
            for t in tickets:
                self._submit_one(t)
            return
        for t, rid, status in zip(tickets, rids, statuses):
            if status == SLAB_ADMISSION_REJECT:
                self._finish(t, Shed(t.qid, t.category, t.est_u,
                                     "replica_queue_full"))
            elif status == SLAB_CACHED_ONLY_MISS:
                self._finish(t, Shed(t.qid, t.category, t.est_u,
                                     "cached_only_miss"))
            else:
                rid = int(rid)
                self._rid2ticket[rid] = t
                resp = self.engine.take_response(rid)   # inline hits
                if resp is not None:
                    self._finish(self._rid2ticket.pop(rid), resp)

    def _collect(self) -> None:
        for rid in list(self._rid2ticket):
            resp = self.engine.take_response(rid)
            if resp is not None:
                self._finish(self._rid2ticket.pop(rid), resp)

    def _finish(self, ticket: ClusterTicket, result: Result) -> None:
        if not ticket.complete(result):
            return                    # a retry already answered it
        self.n_completed += 1
        if self.on_complete is not None:
            self.on_complete(ticket, result)

    def _fail_outstanding(self, reason: str) -> None:
        rids = list(self._rid2ticket)
        # Also cancel them inside the engine: a failed batch was
        # requeued there, and leaving it would retry the same poisoned
        # FIFO-front batch forever (or, for transient failures, later
        # produce responses nobody claims).
        self.engine.cancel(rids)
        for rid in rids:
            t = self._rid2ticket.pop(rid)
            self._finish(t, Shed(t.qid, t.category, t.est_u, reason))

    def _run(self) -> None:
        failures = 0
        while True:
            tickets, exit_ = self._take_inbox()
            if exit_:
                if self._rid2ticket:
                    # stop(drain=False): work already inside the engine
                    # is abandoned with an explicit Shed, not served —
                    # a fast shutdown must not wait out rollouts.
                    self._fail_outstanding("replica_shutdown")
                break
            if len(tickets) > 1 and not self.engine.tracer.enabled:
                self._submit_batch(tickets)
            else:
                for t in tickets:
                    self._submit_one(t)
            try:
                with self._cond:
                    inbox_empty = not self._inbox
                if inbox_empty:
                    self.engine.flush()           # latency path
                else:
                    self.engine.step()            # full buckets only
                failures = 0
            except StaleVersionError:
                # A publish (policy or index epoch) raced the drain past
                # the staleness bound; the engine re-queued the batch
                # and the next submit / flush serves it from the
                # refreshed head.
                continue
            except Exception as e:                # noqa: BLE001
                failures += 1
                if failures >= self.max_consecutive_failures:
                    self._fail_outstanding(f"replica_error:{type(e).__name__}")
                    failures = 0
                continue
            self._collect()
