"""`ServeEngine`: the online query-serving front door.

Request flow (docs/serving.md has the full diagram):

    submit → admission → result cache → per-category shape bucket
           → pre-compiled rollout executable (per shard, scatter–gather)
           → L1 prune → respond (+ cache fill, telemetry)

The engine wraps an already-trained `RetrievalSystem` (L1 ranker, state
bins) plus one Q-table per query category.  `serve()` is the
synchronous driver used by benchmarks and the CLI: it submits a stream,
force-flushes the queues, and returns responses in submission order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.batcher import (
    BucketConfig, MicroBatch, PendingRequest, ShapeBucketBatcher,
)
from repro.serving.cache import LRUResultCache, canonical_query_key
from repro.serving.executor import ShardedExecutor
from repro.serving.telemetry import Telemetry

__all__ = ["EngineConfig", "ServeResponse", "AdmissionError", "ServeEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    min_bucket: int = 8
    max_bucket: int = 64
    cache_capacity: int = 4096
    n_shards: int = 1
    keep: int = 100                # L1 prune depth (paper's NCG@100 cut)
    admission_limit: int = 4096    # max queued requests before shedding
    max_completed: int = 65536     # unclaimed-response bound (oldest evicted)


class AdmissionError(RuntimeError):
    """Raised when the pending queue is at admission_limit (load shed)."""


@dataclasses.dataclass
class ServeResponse:
    request_id: int
    qid: int
    category: int
    doc_ids: np.ndarray        # (keep,) int32, -1 pad
    scores: np.ndarray         # (keep,) float32
    u: int                     # index blocks accessed (summed over shards)
    cand_cnt: int
    cached: bool
    latency_s: float


@dataclasses.dataclass
class _CachedResult:
    doc_ids: np.ndarray
    scores: np.ndarray
    u: int
    cand_cnt: int


class ServeEngine:
    def __init__(self, system, policies: Dict[int, "np.ndarray"],
                 cfg: EngineConfig = EngineConfig()):
        self.system = system
        self.policies = dict(policies)
        self.cfg = cfg
        self.bucket_cfg = BucketConfig(cfg.min_bucket, cfg.max_bucket)
        self.batcher = ShapeBucketBatcher(self.bucket_cfg)
        self.cache = LRUResultCache(cfg.cache_capacity)
        self.executor = ShardedExecutor(system, n_shards=cfg.n_shards,
                                        keep=cfg.keep)
        self.telemetry = Telemetry()
        self._next_id = 0
        # Responses wait here until take_response(); bounded so callers
        # that fire-and-forget don't leak result arrays forever.
        self._completed: Dict[int, ServeResponse] = {}

    def _complete(self, resp: ServeResponse) -> None:
        self._completed[resp.request_id] = resp
        while len(self._completed) > self.cfg.max_completed:
            self._completed.pop(next(iter(self._completed)))

    # ------------------------------------------------------------ warmup
    def warmup(self) -> int:
        """Pre-compile every bucket executable; returns compile count."""
        self.executor.warmup(self.bucket_cfg.buckets())
        return self.executor.compile_count

    @property
    def compile_count(self) -> int:
        return self.executor.compile_count

    # ------------------------------------------------------------ submit
    def submit(self, qid: int) -> int:
        """Admit one query-log query; returns its request id.

        Cache hits complete immediately; misses queue for the next
        micro-batch.  Raises AdmissionError when the queue is full.
        """
        if self.batcher.pending() >= self.cfg.admission_limit:
            self.telemetry.record_rejection()
            raise AdmissionError(
                f"pending={self.batcher.pending()} >= {self.cfg.admission_limit}")
        t0 = Telemetry.now()
        rid = self._next_id
        self._next_id += 1
        log = self.system.log
        cat = int(log.category[qid])
        key = canonical_query_key(log.terms[qid], cat)
        hit = self.cache.get(key)
        if hit is not None:
            t1 = Telemetry.now()
            self._complete(ServeResponse(
                request_id=rid, qid=int(qid), category=cat,
                doc_ids=hit.doc_ids, scores=hit.scores, u=hit.u,
                cand_cnt=hit.cand_cnt, cached=True, latency_s=t1 - t0))
            self.telemetry.record_request(category=cat, latency_s=t1 - t0,
                                          u=hit.u, cached=True, t_done=t1)
            return rid
        self.batcher.enqueue(PendingRequest(
            request_id=rid, qid=int(qid), category=cat, cache_key=key,
            t_submit=t0))
        return rid

    # ------------------------------------------------------------- batch
    def _execute_batch(self, mb: MicroBatch) -> None:
        t0 = Telemetry.now()
        qids = mb.padded_qids()
        occ, scores, tp = self.system.batch_inputs(qids)
        t1 = Telemetry.now()
        ids, sc, u, cnt = self.executor.execute(
            self.policies[mb.category], occ, scores, tp)
        t2 = Telemetry.now()
        self.telemetry.record_batch(category=mb.category, bucket=mb.bucket,
                                    n_real=mb.n_real, t_inputs_s=t1 - t0,
                                    t_execute_s=t2 - t1)
        # Padded lanes (>= n_real) are dropped here: never cached, never
        # answered — the bucket-padding invariant the tests pin down.
        for lane, req in enumerate(mb.requests):
            result = _CachedResult(doc_ids=ids[lane], scores=sc[lane],
                                   u=int(u[lane]), cand_cnt=int(cnt[lane]))
            self.cache.put(req.cache_key, result)
            latency = t2 - req.t_submit
            self._complete(ServeResponse(
                request_id=req.request_id, qid=req.qid,
                category=mb.category, doc_ids=result.doc_ids,
                scores=result.scores, u=result.u, cand_cnt=result.cand_cnt,
                cached=False, latency_s=latency))
            self.telemetry.record_request(category=mb.category,
                                          latency_s=latency, u=result.u,
                                          cached=False, t_done=t2)

    def step(self) -> int:
        """Drain every full bucket; returns micro-batches executed."""
        n = 0
        for cat in self.batcher.categories():
            while True:
                mb = self.batcher.drain(cat, force=False)
                if mb is None:
                    break
                self._execute_batch(mb)
                n += 1
        return n

    def flush(self) -> int:
        """Force-drain everything (partial buckets padded up)."""
        n = self.step()
        for cat in self.batcher.categories():
            while True:
                mb = self.batcher.drain(cat, force=True)
                if mb is None:
                    break
                self._execute_batch(mb)
                n += 1
        return n

    # ----------------------------------------------------------- respond
    def take_response(self, request_id: int) -> Optional[ServeResponse]:
        return self._completed.pop(request_id, None)

    def serve(self, qids: Sequence[int]) -> List[ServeResponse]:
        """Synchronous driver: submit a stream, flush, return responses
        in submission order."""
        rids = [self.submit(int(q)) for q in qids]
        self.flush()
        return [self._completed.pop(r) for r in rids]

    def summary(self) -> dict:
        out = self.telemetry.summary(compile_count=self.compile_count)
        out.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        return out
