"""`ServeEngine`: the online query-serving front door.

Request flow (docs/serving.md has the full diagram):

    submit → admission → result cache → per-category shape bucket
           → pre-compiled rollout executable (per shard, scatter–gather)
           → L1 prune → respond (+ cache fill, telemetry)

The engine wraps an already-trained `RetrievalSystem` (L1 ranker, state
bins) plus per-category `Policy` objects consumed from a versioned
`PolicyStore` (docs/policies.md).  Passing a plain `{category: Policy}`
dict wraps it in a single-snapshot store; raw Q-table ndarrays are
rejected — wrap them with `TabularQPolicy`.  A trainer can keep
publishing snapshots to the store while the engine serves: the engine
refreshes to the head snapshot at each drain (flushing the result
cache on a version change, since cached responses embody the old
policy) and refuses to serve a snapshot older than the store's
staleness bound.  `serve()` is the synchronous driver used by
benchmarks and the CLI: it submits a stream, force-flushes the queues,
and returns responses in submission order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.policies import Policy, PolicyStore
from repro.serving.batcher import (
    BucketConfig, MicroBatch, PendingRequest, ShapeBucketBatcher,
)
from repro.serving.cache import LRUResultCache, canonical_query_key
from repro.serving.executor import ShardedExecutor
from repro.serving.telemetry import Telemetry

__all__ = ["EngineConfig", "ServeResponse", "AdmissionError", "ServeEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    min_bucket: int = 8
    max_bucket: int = 64
    cache_capacity: int = 4096
    n_shards: int = 1
    keep: int = 100                # L1 prune depth (paper's NCG@100 cut)
    admission_limit: int = 4096    # max queued requests before shedding
    max_completed: int = 65536     # unclaimed-response bound (oldest evicted)
    backend: str = "xla"           # rollout backend (see executor)
    auto_refresh: bool = True      # pull the head policy snapshot per drain


class AdmissionError(RuntimeError):
    """Raised when the pending queue is at admission_limit (load shed)."""


@dataclasses.dataclass
class ServeResponse:
    request_id: int
    qid: int
    category: int
    doc_ids: np.ndarray        # (keep,) int32, -1 pad
    scores: np.ndarray         # (keep,) float32
    u: int                     # index blocks accessed (summed over shards)
    cand_cnt: int
    cached: bool
    latency_s: float
    policy_version: int = 0    # snapshot version that produced the result


@dataclasses.dataclass
class _CachedResult:
    doc_ids: np.ndarray
    scores: np.ndarray
    u: int
    cand_cnt: int


class ServeEngine:
    def __init__(self, system,
                 policies: Union[PolicyStore, Dict[int, Policy]],
                 cfg: EngineConfig = EngineConfig()):
        self.system = system
        self.cfg = cfg
        if isinstance(policies, PolicyStore):
            self.store = policies
        elif isinstance(policies, dict):
            # publish() validates entries and rejects raw ndarrays with
            # a pointer at TabularQPolicy.
            self.store = PolicyStore(staleness_bound=0)
            self.store.publish(policies)
        else:
            raise TypeError(
                "ServeEngine expects a PolicyStore or a {category: Policy} "
                f"dict, got {type(policies).__name__}")
        self._snapshot = self.store.snapshot()
        self.bucket_cfg = BucketConfig(cfg.min_bucket, cfg.max_bucket)
        self.batcher = ShapeBucketBatcher(self.bucket_cfg)
        self.cache = LRUResultCache(cfg.cache_capacity)
        self.executor = ShardedExecutor(system, n_shards=cfg.n_shards,
                                        keep=cfg.keep, backend=cfg.backend)
        self.telemetry = Telemetry()
        self._next_id = 0
        # Requests drained from the queue and currently executing; with
        # queue_depth this is the load signal a cross-replica router
        # balances on.
        self._inflight = 0
        # Responses wait here until take_response(); bounded so callers
        # that fire-and-forget don't leak result arrays forever.
        self._completed: Dict[int, ServeResponse] = {}

    def _complete(self, resp: ServeResponse) -> None:
        self._completed[resp.request_id] = resp
        while len(self._completed) > self.cfg.max_completed:
            self._completed.pop(next(iter(self._completed)))

    # ------------------------------------------------------------- gauges
    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet drained into a micro-batch."""
        return self.batcher.pending()

    @property
    def inflight(self) -> int:
        """Real lanes of the micro-batch currently executing (0 idle)."""
        return self._inflight

    # ---------------------------------------------------------- policies
    @property
    def policy_version(self) -> int:
        """Version of the snapshot currently being served."""
        return self._snapshot.version

    def refresh_policies(self) -> bool:
        """Adopt the store's head snapshot.  Returns True on a version
        change; the result cache is flushed then, because cached
        responses were produced by the previous policy."""
        snap = self.store.snapshot()
        if snap.version == self._snapshot.version:
            return False
        self._snapshot = snap
        self.cache.clear()
        return True

    def _policy_for(self, category: int) -> Policy:
        self.store.validate(self._snapshot.version)
        try:
            return self._snapshot.policies[category]
        except KeyError:
            raise KeyError(
                f"policy snapshot v{self._snapshot.version} has no policy "
                f"for category {category}") from None

    # ------------------------------------------------------------ warmup
    def warmup(self) -> int:
        """Pre-compile every (bucket, policy-structure) executable for
        the current snapshot; returns the compile count."""
        self.executor.warmup(self.bucket_cfg.buckets(),
                             self._snapshot.policies.values())
        return self.executor.compile_count

    @property
    def compile_count(self) -> int:
        return self.executor.compile_count

    # ------------------------------------------------------------ submit
    def submit(self, qid: int) -> int:
        """Admit one query-log query; returns its request id.

        Cache hits complete immediately; misses queue for the next
        micro-batch.  Raises AdmissionError when the queue is full.
        """
        if self.cfg.auto_refresh:
            # A publish between drains must not leave old-policy cache
            # entries answering new submissions.
            self.refresh_policies()
        if self.batcher.pending() >= self.cfg.admission_limit:
            self.telemetry.record_rejection()
            raise AdmissionError(
                f"pending={self.batcher.pending()} >= {self.cfg.admission_limit}")
        t0 = Telemetry.now()
        rid = self._next_id
        self._next_id += 1
        log = self.system.log
        cat = int(log.category[qid])
        key = canonical_query_key(log.terms[qid], cat)
        # Cached responses embody the pinned snapshot's policy, so the
        # staleness bound applies to hits exactly as to rollouts.
        self.store.validate(self._snapshot.version)
        hit = self.cache.get(key)
        if hit is not None:
            t1 = Telemetry.now()
            # The cache is flushed on every version change, so a hit
            # always embodies the currently pinned snapshot.
            self._complete(ServeResponse(
                request_id=rid, qid=int(qid), category=cat,
                doc_ids=hit.doc_ids, scores=hit.scores, u=hit.u,
                cand_cnt=hit.cand_cnt, cached=True, latency_s=t1 - t0,
                policy_version=self._snapshot.version))
            self.telemetry.record_request(category=cat, latency_s=t1 - t0,
                                          u=hit.u, cached=True, t_done=t1)
            return rid
        self.batcher.enqueue(PendingRequest(
            request_id=rid, qid=int(qid), category=cat, cache_key=key,
            t_submit=t0))
        self.telemetry.observe_gauges(self.queue_depth, self._inflight)
        return rid

    # ------------------------------------------------------------- batch
    def _execute_batch(self, mb: MicroBatch) -> None:
        t0 = Telemetry.now()
        self._inflight = mb.n_real
        self.telemetry.observe_gauges(self.queue_depth, self._inflight)
        try:
            qids = mb.padded_qids()
            occ, scores, tp = self.system.batch_inputs(qids)
            t1 = Telemetry.now()
            ids, sc, u, cnt = self.executor.execute(
                self._policy_for(mb.category), occ, scores, tp)
            t2 = Telemetry.now()
        finally:
            self._inflight = 0
            self.telemetry.observe_gauges(self.queue_depth, 0)
        version = self._snapshot.version
        self.telemetry.record_batch(category=mb.category, bucket=mb.bucket,
                                    n_real=mb.n_real, t_inputs_s=t1 - t0,
                                    t_execute_s=t2 - t1)
        # Padded lanes (>= n_real) are dropped here: never cached, never
        # answered — the bucket-padding invariant the tests pin down.
        for lane, req in enumerate(mb.requests):
            result = _CachedResult(doc_ids=ids[lane], scores=sc[lane],
                                   u=int(u[lane]), cand_cnt=int(cnt[lane]))
            self.cache.put(req.cache_key, result)
            latency = t2 - req.t_submit
            self._complete(ServeResponse(
                request_id=req.request_id, qid=req.qid,
                category=mb.category, doc_ids=result.doc_ids,
                scores=result.scores, u=result.u, cand_cnt=result.cand_cnt,
                cached=False, latency_s=latency, policy_version=version))
            self.telemetry.record_request(category=mb.category,
                                          latency_s=latency, u=result.u,
                                          cached=False, t_done=t2)

    def _drain_category(self, cat: int, force: bool) -> int:
        n = 0
        while True:
            mb = self.batcher.drain(cat, force=force)
            if mb is None:
                break
            try:
                self._execute_batch(mb)
            except Exception:
                # A failed batch (stale snapshot, missing category,
                # backend error) must not lose admitted requests: put
                # them back at the front of the queue, FIFO preserved,
                # before propagating.
                self.batcher.requeue(mb.requests)
                raise
            n += 1
        return n

    def step(self) -> int:
        """Drain every full bucket; returns micro-batches executed."""
        if self.cfg.auto_refresh:
            self.refresh_policies()
        return sum(self._drain_category(cat, force=False)
                   for cat in self.batcher.categories())

    def flush(self) -> int:
        """Force-drain everything (partial buckets padded up)."""
        n = self.step()
        return n + sum(self._drain_category(cat, force=True)
                       for cat in self.batcher.categories())

    # ----------------------------------------------------------- respond
    def take_response(self, request_id: int) -> Optional[ServeResponse]:
        return self._completed.pop(request_id, None)

    def cancel(self, request_ids) -> int:
        """Abandon admitted requests: drop them from the pending queues
        (including requeued failed batches) and discard any unclaimed
        responses.  Returns how many were still queued."""
        request_ids = list(request_ids)
        for rid in request_ids:
            self._completed.pop(rid, None)
        return self.batcher.remove(request_ids)

    def serve(self, qids: Sequence[int]) -> List[ServeResponse]:
        """Synchronous driver: submit a stream, flush, return responses
        in submission order."""
        rids = [self.submit(int(q)) for q in qids]
        self.flush()
        return [self._completed.pop(r) for r in rids]

    def summary(self) -> dict:
        out = self.telemetry.summary(compile_count=self.compile_count)
        out.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        out["policy_version"] = self.policy_version
        return out
