"""`ServeEngine`: the online query-serving front door.

Request flow (docs/serving.md has the full diagram):

    submit → admission → result cache → per-category shape bucket
           → pre-compiled rollout executable (per shard, scatter–gather)
           → L1 prune → respond (+ cache fill, telemetry)

The engine wraps an already-trained `RetrievalSystem` (L1 ranker, state
bins) plus per-category `Policy` objects consumed from a versioned
`PolicyStore` (docs/policies.md).  Passing a plain `{category: Policy}`
dict wraps it in a single-snapshot store; raw Q-table ndarrays are
rejected — wrap them with `TabularQPolicy`.  A trainer can keep
publishing snapshots to the store while the engine serves: the engine
refreshes to the head snapshot at each drain (flushing the result
cache on a version change, since cached responses embody the old
policy) and refuses to serve a snapshot older than the store's
staleness bound.  `serve()` is the synchronous driver used by
benchmarks and the CLI: it submits a stream, force-flushes the queues,
and returns responses in submission order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.obs import NULL_TRACER, Tracer
from repro.policies import Policy, PolicyStore
from repro.serving.array_cache import ArrayResultCache
from repro.serving.batcher import (
    BucketConfig, MicroBatch, PendingRequest, ShapeBucketBatcher,
)
from repro.serving.cache import (LRUResultCache, canonical_query_key,
                                 versioned_key)
from repro.serving.executor import ShardedExecutor
from repro.serving.levels import ServiceLevel
from repro.serving.slab import QueryKeyCache, TicketSlab
from repro.serving.telemetry import Telemetry

__all__ = ["EngineConfig", "ServeResponse", "AdmissionError",
           "CacheOnlyMiss", "ServeEngine", "SLAB_OK",
           "SLAB_ADMISSION_REJECT", "SLAB_CACHED_ONLY_MISS"]

# Per-request statuses returned by ``submit_slab`` (it never raises for
# an individual arrival — a slab is all-or-nothing only for *systemic*
# failures like a stale snapshot, so callers that mapped ids to tickets
# before submitting can always reconcile every lane).
SLAB_OK = 0
SLAB_ADMISSION_REJECT = 1
SLAB_CACHED_ONLY_MISS = 2


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    min_bucket: int = 8
    max_bucket: int = 64
    cache_capacity: int = 4096
    n_shards: int = 1
    keep: int = 100                # L1 prune depth (paper's NCG@100 cut)
    admission_limit: int = 4096    # max queued requests before shedding
    max_completed: int = 65536     # unclaimed-response bound (oldest evicted)
    backend: str = "xla"           # rollout backend (see executor)
    auto_refresh: bool = True      # pull the head policy snapshot per drain
    cache_impl: str = "array"      # "array" (hot path) | "lru" (dict oracle)


class AdmissionError(RuntimeError):
    """Raised when the pending queue is at admission_limit (load shed)."""


class CacheOnlyMiss(RuntimeError):
    """A CACHED_ONLY submission found no usable cache entry.  The
    cluster normally prevents this (it only prices CACHED_ONLY when the
    owner replica's cache holds the key), so hitting it means an
    eviction raced the routing decision; the caller sheds explicitly."""


@dataclasses.dataclass
class ServeResponse:
    request_id: int
    qid: int
    category: int
    doc_ids: np.ndarray        # (keep,) int32, -1 pad
    scores: np.ndarray         # (keep,) float32
    u: int                     # index blocks accessed (summed over shards)
    cand_cnt: int
    cached: bool
    latency_s: float
    policy_version: int = 0    # snapshot version that produced the result
    index_epoch: int = 0       # index epoch the result was scanned at
                               # (0 = static index, no live tier)
    # The service level that PRODUCED the candidates (result quality):
    # FULL for live-policy rollouts and hits on FULL-filled entries,
    # SHALLOW for fallback-plan rollouts and hits on SHALLOW fills.  A
    # CACHED_ONLY admission therefore reports the level of whatever the
    # cache held; the *admission* decision lives on the cluster ticket.
    level: ServiceLevel = ServiceLevel.FULL


@dataclasses.dataclass
class _CachedResult:
    doc_ids: np.ndarray
    scores: np.ndarray
    u: int
    cand_cnt: int
    level: ServiceLevel = ServiceLevel.FULL


class ServeEngine:
    def __init__(self, system,
                 policies: Union[PolicyStore, Dict[int, Policy]],
                 cfg: EngineConfig = EngineConfig(),
                 tracer: Tracer = NULL_TRACER):
        self.system = system
        self.cfg = cfg
        self.tracer = tracer
        if isinstance(policies, PolicyStore):
            self.store = policies
        elif isinstance(policies, dict):
            # publish() validates entries and rejects raw ndarrays with
            # a pointer at TabularQPolicy.
            self.store = PolicyStore(staleness_bound=0)
            self.store.publish(policies)
        else:
            raise TypeError(
                "ServeEngine expects a PolicyStore or a {category: Policy} "
                f"dict, got {type(policies).__name__}")
        self._snapshot = self.store.snapshot()
        self.bucket_cfg = BucketConfig(cfg.min_bucket, cfg.max_bucket)
        self.telemetry = Telemetry()
        # Live-index integration: systems with a tiered live index
        # (repro.index.live.LiveRetrievalSystem) expose an
        # IndexEpochStore; static systems expose None and everything
        # below degrades to a constant epoch 0.  The engine pins one
        # epoch like it pins one policy snapshot, and threads it into
        # batch_inputs so a hot swap mid-batch can't mix two indexes.
        self._index_store = getattr(system, "index_epoch_store", None)
        self._index_epoch_snap = (self._index_store.snapshot()
                                  if self._index_store is not None else None)
        self._c_epoch_swaps = self.telemetry.registry.counter(
            "index.epoch_swaps")
        self._g_epoch = self.telemetry.registry.gauge("index.epoch")
        self._g_epoch.set(self.index_epoch)
        self.batcher = ShapeBucketBatcher(self.bucket_cfg)
        # The cache shares the engine's registry so its hit/miss/
        # eviction counters ride the same mergeable snapshot.  "array"
        # is the production hot path (open addressing over preallocated
        # slabs, CLOCK eviction); "lru" keeps the dict/object oracle.
        if cfg.cache_impl == "array":
            self.cache = ArrayResultCache(cfg.cache_capacity, keep=cfg.keep,
                                          registry=self.telemetry.registry)
        elif cfg.cache_impl == "lru":
            self.cache = LRUResultCache(cfg.cache_capacity,
                                        registry=self.telemetry.registry)
        else:
            raise ValueError(f"unknown cache_impl {cfg.cache_impl!r} "
                             "(expected 'array' or 'lru')")
        # qid -> canonical key memo shared by submit and submit_slab
        # (the log is append-only, so memoized keys never go stale).
        self._key_cache = QueryKeyCache(system.log)
        self.executor = ShardedExecutor(system, n_shards=cfg.n_shards,
                                        keep=cfg.keep, backend=cfg.backend)
        self.executor.tracer = tracer
        self._next_id = 0
        # Requests drained from the queue and currently executing; with
        # queue_depth this is the load signal a cross-replica router
        # balances on.
        self._inflight = 0
        # Responses wait here until take_response(); bounded so callers
        # that fire-and-forget don't leak result arrays forever.
        self._completed: Dict[int, ServeResponse] = {}

    def _complete(self, resp: ServeResponse) -> None:
        self._completed[resp.request_id] = resp
        while len(self._completed) > self.cfg.max_completed:
            self._completed.pop(next(iter(self._completed)))

    # ------------------------------------------------------------- gauges
    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet drained into a micro-batch."""
        return self.batcher.pending()

    @property
    def inflight(self) -> int:
        """Real lanes of the micro-batch currently executing (0 idle)."""
        return self._inflight

    # ---------------------------------------------------------- policies
    @property
    def policy_version(self) -> int:
        """Version of the snapshot currently being served."""
        return self._snapshot.version

    def refresh_policies(self) -> bool:
        """Adopt the store's head snapshot.  Returns True on a version
        change; the result cache is flushed then, because cached
        responses were produced by the previous policy."""
        snap = self.store.snapshot()
        if snap.version == self._snapshot.version:
            return False
        self._snapshot = snap
        # Entries filled under the old version are unreachable anyway
        # (the cache key embeds the policy version); clearing is pure
        # memory hygiene so dead entries don't squat LRU capacity.
        self.cache.clear()
        return True

    # -------------------------------------------------------- index epoch
    @property
    def index_epoch(self) -> int:
        """Index epoch currently pinned (0 on a static index)."""
        snap = self._index_epoch_snap
        return snap.version if snap is not None else 0

    def refresh_index(self) -> bool:
        """Adopt the index store's head epoch.  Returns True on a swap.

        Unlike a policy swap, the cache is NOT flushed: the cache key
        embeds the index epoch, so a swap invalidates exactly the
        entries scanned against the old index — fills that raced the
        swap included — while the epoch gauge and swap counter land in
        the metrics plane."""
        if self._index_store is None:
            return False
        head = self._index_store.snapshot()
        snap = self._index_epoch_snap
        if snap is not None and head.version == snap.version:
            return False
        self._index_epoch_snap = head
        self._c_epoch_swaps.inc()
        self._g_epoch.set(head.version)
        return True

    def _versioned_key(self, base_key) -> tuple:
        """The full cache key for a base query key under the currently
        pinned (policy version, index epoch)."""
        return versioned_key(base_key, self._snapshot.version,
                             self.index_epoch)

    def cache_has(self, base_key) -> bool:
        """Does this engine's cache hold a CURRENT entry for the base
        query key — i.e. one filled under the pinned policy version and
        index epoch?  Stats-free and thread-safe like
        ``cache.contains``; the cluster router's owner probe uses this
        so CACHED_ONLY is never priced against an entry a hot swap
        already invalidated."""
        return self.cache.contains(self._versioned_key(base_key))

    def _policy_for(self, category: int,
                    level: ServiceLevel = ServiceLevel.FULL) -> Policy:
        self.store.validate(self._snapshot.version)
        mapping = (self._snapshot.policies if level == ServiceLevel.FULL
                   else self._snapshot.fallbacks)
        try:
            return mapping[category]
        except KeyError:
            role = "policy" if level == ServiceLevel.FULL else "fallback policy"
            raise KeyError(
                f"policy snapshot v{self._snapshot.version} has no {role} "
                f"for category {category}") from None

    # ------------------------------------------------------------ warmup
    def warmup(self) -> int:
        """Pre-compile every (bucket, policy-structure, level)
        executable for the current snapshot — fallbacks included, so
        the first degraded micro-batch under pressure never pays a
        compile; returns the compile count."""
        self.executor.warmup(self.bucket_cfg.buckets(),
                             self._snapshot.policies.values(),
                             level=int(ServiceLevel.FULL))
        if self._snapshot.fallbacks:
            self.executor.warmup(self.bucket_cfg.buckets(),
                                 self._snapshot.fallbacks.values(),
                                 level=int(ServiceLevel.SHALLOW))
        return self.executor.compile_count

    @property
    def compile_count(self) -> int:
        return self.executor.compile_count

    # ------------------------------------------------------------ submit
    def submit(self, qid: int,
               level: ServiceLevel = ServiceLevel.FULL,
               span=None) -> int:
        """Admit one query-log query at a service level; returns its
        request id.

        Cache hits complete immediately — but only when the cached
        entry's level is at least as good as the request's (a SHALLOW
        fill never silently answers a FULL request; a FULL fill answers
        anyone).  Misses queue for the next micro-batch of their
        (category, level); a CACHED_ONLY miss raises
        :class:`CacheOnlyMiss` instead (it has no u budget to roll out
        with).  Raises AdmissionError when the queue is full.

        ``span`` is the ticket's trace context: the cluster passes the
        root span it opened at admission and keeps ownership (it ends
        the span in its completion callback).  Without one, the engine
        opens — and ends — its own per-ticket root span when tracing
        is enabled.
        """
        level = ServiceLevel(level)
        if level == ServiceLevel.SHED:
            raise ValueError("SHED is not a servable level — the caller "
                             "sheds instead of submitting")
        if self.cfg.auto_refresh:
            # A publish between drains must not leave old-policy cache
            # entries answering new submissions; same for index epochs.
            self.refresh_policies()
            self.refresh_index()
        own_span = span is None
        if own_span:
            span = self.tracer.root_span("ticket", qid=int(qid),
                                         level=int(level))
        t0 = Telemetry.now()
        rid = self._next_id
        self._next_id += 1
        log = self.system.log
        cat = int(log.category[qid])
        key = canonical_query_key(log.terms[qid], cat)
        sub = span.child("submit", category=cat) if span else span
        # Cached responses embody the pinned snapshot's policy AND the
        # pinned index epoch, so both staleness bounds apply to hits
        # exactly as to rollouts.
        self.store.validate(self._snapshot.version)
        if self._index_store is not None:
            self._index_store.validate(self.index_epoch)
        # Peek first: a degraded fill must not answer a better-level
        # request, and a rejected entry must count as a MISS (not a
        # hit) nor be promoted in LRU order — the FULL execution below
        # will overwrite it.  The lookup key embeds (policy version,
        # index epoch): an entry filled at epoch N can never answer a
        # request routed at epoch N+1 (tests/test_live_index.py pins
        # this regression).
        vkey = self._versioned_key(key)
        entry = self.cache.peek(vkey)
        if entry is not None and int(entry.level) <= int(level):
            hit = self.cache.get(vkey)     # counts the hit, refreshes LRU
        else:
            hit = None
            self.cache.record_miss()
        if hit is not None:
            span.instant("cache_hit", level=int(hit.level))
            t1 = Telemetry.now()
            # The key embeds both versions, so a hit always embodies
            # the currently pinned snapshot and epoch.
            self._complete(ServeResponse(
                request_id=rid, qid=int(qid), category=cat,
                doc_ids=hit.doc_ids, scores=hit.scores, u=hit.u,
                cand_cnt=hit.cand_cnt, cached=True, latency_s=t1 - t0,
                policy_version=self._snapshot.version,
                index_epoch=self.index_epoch, level=hit.level))
            self.telemetry.record_request(category=cat, latency_s=t1 - t0,
                                          u=hit.u, cached=True, t_done=t1,
                                          level=int(hit.level))
            sub.end()
            if own_span:
                span.end(cached=True, level=int(hit.level))
            return rid
        span.instant("cache_miss")
        if level == ServiceLevel.CACHED_ONLY:
            sub.end()
            if own_span:
                span.end(error="cache_only_miss")
            raise CacheOnlyMiss(f"qid {qid}: no cache entry for {key}")
        # The queue cap guards the PENDING queue only — a cache hit
        # completes inline without queueing, so it must never be
        # rejected for queue fullness (under saturation, hits are
        # exactly the traffic the CACHED_ONLY rung relies on).
        if self.batcher.pending() >= self.cfg.admission_limit:
            self.telemetry.record_rejection()
            sub.end()
            if own_span:
                span.end(error="admission_limit")
            raise AdmissionError(
                f"pending={self.batcher.pending()} >= {self.cfg.admission_limit}")
        sub.end()
        self.batcher.enqueue(PendingRequest(
            request_id=rid, qid=int(qid), category=cat, cache_key=key,
            t_submit=t0, level=int(level), span=span,
            queue_span=span.child("queue", category=cat,
                                  level=int(level)) if span else span,
            own_span=own_span))
        self.telemetry.observe_gauges(self.queue_depth, self._inflight)
        return rid

    # ----------------------------------------------------- bulk (slabs)
    def submit_slab(self, qids, level: ServiceLevel = ServiceLevel.FULL,
                    levels=None, spans=None):
        """Admit a whole arrival slab; returns ``(rids, statuses)``.

        The batch-granular front door: one refresh + one staleness
        validation per slab, categories gathered in one fancy-index,
        canonical keys through the qid memo, cache hits completed as a
        group (bulk counters, one telemetry slab per (level, category)
        cell), misses enqueued with ``enqueue_many``.  Unlike
        :meth:`submit` it never raises for an *individual* arrival —
        per-request outcomes come back in ``statuses`` (``SLAB_OK`` /
        ``SLAB_ADMISSION_REJECT`` / ``SLAB_CACHED_ONLY_MISS``) so a
        caller that pre-registered tickets can reconcile every lane.
        Systemic failures (stale snapshot/epoch) still raise before any
        request id is assigned.

        ``spans``, when given, carries one trace context per arrival
        (cluster tickets); when absent and tracing is on, the whole
        slab shares ONE "slab" span instead of per-ticket roots — the
        slab-scoped batching that keeps tracing overhead off the
        per-request path.  Bit parity with a loop of :meth:`submit`
        calls on the same starting state is pinned in tier-1 tests
        (the per-ticket path is the B=1 oracle).
        """
        if isinstance(qids, TicketSlab):
            slab = qids
        else:
            slab = TicketSlab.build(self.system.log, qids, level=int(level),
                                    levels=levels)
        n = len(slab)
        lv = slab.levels
        if n and int(lv.max(initial=0)) >= int(ServiceLevel.SHED):
            raise ValueError("SHED is not a servable level — the caller "
                             "sheds instead of submitting")
        if self.cfg.auto_refresh:
            self.refresh_policies()
            self.refresh_index()
        self.store.validate(self._snapshot.version)
        if self._index_store is not None:
            self._index_store.validate(self.index_epoch)
        slab_span = (self.tracer.span("slab", n=n) if spans is None
                     else None)
        t0 = Telemetry.now()
        rid0 = self._next_id
        self._next_id += n
        rids = np.arange(rid0, rid0 + n, dtype=np.int64)
        statuses = np.zeros(n, np.uint8)
        version = self._snapshot.version
        epoch = self.index_epoch
        key_of = self._key_cache.key
        cache = self.cache
        pend0 = self.batcher.pending()
        limit = self.cfg.admission_limit
        cached_only = int(ServiceLevel.CACHED_ONLY)
        hits = []                       # (i, category, entry)
        pending: List[PendingRequest] = []
        queued = 0
        n_rej = 0
        for i in range(n):
            qid = int(slab.qids[i])
            cat = int(slab.categories[i])
            req_level = int(lv[i])
            key = key_of(qid, cat)
            entry = cache.peek((key, version, epoch))
            if entry is not None and int(entry.level) <= req_level:
                cache.touch((key, version, epoch))
                hits.append((i, cat, entry))
                continue
            if req_level == cached_only:
                statuses[i] = SLAB_CACHED_ONLY_MISS
                continue
            if pend0 + queued >= limit:
                statuses[i] = SLAB_ADMISSION_REJECT
                n_rej += 1
                continue
            queued += 1
            span = spans[i] if spans is not None else None
            pending.append(PendingRequest(
                request_id=int(rids[i]), qid=qid, category=cat,
                cache_key=key, t_submit=t0, level=req_level, span=span,
                queue_span=span.child("queue", category=cat,
                                      level=req_level) if span else None,
                own_span=False))
        t1 = Telemetry.now()
        # Hits complete as a group: same responses a scalar loop would
        # produce (identical doc ids / scores / u — latency is the slab
        # probe's), telemetry recorded one (level, category) cell at a
        # time through pre-resolved handles.
        if hits:
            groups: Dict[tuple, list] = {}
            for i, cat, entry in hits:
                self._complete(ServeResponse(
                    request_id=int(rids[i]), qid=int(slab.qids[i]),
                    category=cat, doc_ids=entry.doc_ids,
                    scores=entry.scores, u=entry.u,
                    cand_cnt=entry.cand_cnt, cached=True,
                    latency_s=t1 - t0, policy_version=version,
                    index_epoch=epoch, level=entry.level))
                groups.setdefault((int(entry.level), cat),
                                  []).append(entry.u)
            for (lvl, cat), us in groups.items():
                self.telemetry.record_requests(
                    category=cat, level=lvl,
                    latencies_s=np.full(len(us), t1 - t0), us=us,
                    cached=True, t_done=t1)
        cache.add_stats(hits=len(hits), misses=n - len(hits))
        if n_rej:
            self.telemetry.record_rejection(n_rej)
        if pending:
            self.batcher.enqueue_many(pending)
        self.telemetry.observe_gauges(self.queue_depth, self._inflight)
        if slab_span:
            slab_span.end(hits=len(hits), queued=queued, rejected=n_rej)
        return rids, statuses

    def submit_many(self, qids,
                    level: ServiceLevel = ServiceLevel.FULL,
                    levels=None) -> List[int]:
        """Raising wrapper over :meth:`submit_slab` for callers with
        the per-ticket error contract: any rejected lane raises
        :class:`AdmissionError`, any CACHED_ONLY miss raises
        :class:`CacheOnlyMiss`, otherwise every request id is live."""
        rids, statuses = self.submit_slab(qids, level=level, levels=levels)
        if statuses.any():
            n_rej = int((statuses == SLAB_ADMISSION_REJECT).sum())
            if n_rej:
                raise AdmissionError(
                    f"{n_rej} of {len(rids)} arrivals rejected at "
                    f"admission_limit={self.cfg.admission_limit}")
            raise CacheOnlyMiss(
                f"{int((statuses == SLAB_CACHED_ONLY_MISS).sum())} "
                f"CACHED_ONLY arrivals found no cache entry")
        return [int(r) for r in rids]

    def serve_many(self, qids,
                   level: ServiceLevel = ServiceLevel.FULL
                   ) -> List[ServeResponse]:
        """Synchronous slab driver: bulk-submit, flush, return
        responses in submission order (the batched sibling of
        :meth:`serve`)."""
        rids = self.submit_many(qids, level=level)
        self.flush()
        return [self._completed.pop(r) for r in rids]

    # ------------------------------------------------------------- batch
    def _execute_batch(self, mb: MicroBatch) -> None:
        level = ServiceLevel(mb.level)
        try:
            policy = self._policy_for(mb.category, level)
        except KeyError:
            if level != ServiceLevel.SHALLOW:
                raise
            # A publish cleared the fallbacks while SHALLOW-admitted
            # requests sat in the queue.  Upgrade the batch to FULL
            # (better results, more u) rather than poisoning the
            # FIFO front and shedding the replica's in-flight window.
            level = ServiceLevel.FULL
            policy = self._policy_for(mb.category, level)
            self.tracer.instant("level_upgrade", category=mb.category,
                                n=mb.n_real)
        # Worker-thread view of the batch; each ticket additionally gets
        # batch/execute/respond children on its own track below.
        mb_span = self.tracer.span("microbatch", category=mb.category,
                                   bucket=mb.bucket, n_real=mb.n_real,
                                   level=int(level))
        t0 = Telemetry.now()
        for req in mb.requests:
            if req.queue_span:
                req.queue_span.end(t1=t0)
            self.telemetry.record_queue_wait(category=mb.category,
                                             level=int(level),
                                             wait_s=t0 - req.t_submit)
        self._inflight = mb.n_real
        self.telemetry.observe_gauges(self.queue_depth, self._inflight)
        # Pin the epoch for the whole batch: occupancy, the cache fill
        # key, and the response all report the SAME epoch even if a
        # merge publishes mid-execution (the next drain adopts it).
        epoch_snap = self._index_epoch_snap
        epoch_version = epoch_snap.version if epoch_snap is not None else 0
        if self._index_store is not None:
            self._index_store.validate(epoch_version)
        try:
            qids = mb.padded_qids()
            occ, scores, tp = self.system.batch_inputs(qids,
                                                       epoch=epoch_snap)
            t1 = Telemetry.now()
            ids, sc, u, cnt = self.executor.execute(
                policy, occ, scores, tp, level=int(level))
            t2 = Telemetry.now()
        except Exception as err:
            mb_span.end(error=type(err).__name__)
            raise
        finally:
            self._inflight = 0
            self.telemetry.observe_gauges(self.queue_depth, 0)
        if mb_span:
            mb_span.child_at("batch_inputs", t0, t1)
            mb_span.child_at("execute", t1, t2)
        version = self._snapshot.version
        self.telemetry.record_batch(category=mb.category, bucket=mb.bucket,
                                    n_real=mb.n_real, t_inputs_s=t1 - t0,
                                    t_execute_s=t2 - t1)
        # Padded lanes (>= n_real) are dropped here: never cached, never
        # answered — the bucket-padding invariant the tests pin down.
        for lane, req in enumerate(mb.requests):
            result = _CachedResult(doc_ids=ids[lane], scores=sc[lane],
                                   u=int(u[lane]), cand_cnt=int(cnt[lane]),
                                   level=level)
            # Fill under the versions that PRODUCED the result: the
            # pending request carries the base query key, the versioned
            # key is composed at use time, so a swap between submit and
            # drain can never file a new-epoch result under an old key
            # (or vice versa).
            vkey = versioned_key(req.cache_key, version, epoch_version)
            prior = self.cache.contains(vkey)
            # A SHALLOW fill never downgrades an existing (necessarily
            # >=-quality) entry; FULL fills always win.
            if level == ServiceLevel.FULL or not prior:
                self.cache.put(vkey, result)
            latency = t2 - req.t_submit
            self._complete(ServeResponse(
                request_id=req.request_id, qid=req.qid,
                category=mb.category, doc_ids=result.doc_ids,
                scores=result.scores, u=result.u, cand_cnt=result.cand_cnt,
                cached=False, latency_s=latency, policy_version=version,
                index_epoch=epoch_version, level=level))
            self.telemetry.record_request(category=mb.category,
                                          latency_s=latency, u=result.u,
                                          cached=False, t_done=t2,
                                          level=int(level))
            if req.span:
                # batch covers drain → inputs assembled; execute the
                # rollout; respond the host-side completion.
                req.span.child_at("batch", t0, t1, bucket=mb.bucket)
                req.span.child_at("execute", t1, t2, u=result.u)
                t3 = Telemetry.now()
                req.span.child_at("respond", t2, t3)
                if req.own_span:
                    req.span.end(t1=t3, level=int(level), u=result.u)
        mb_span.end()

    def _drain_queue(self, key: tuple, force: bool) -> int:
        n = 0
        while True:
            mb = self.batcher.drain(key, force=force)
            if mb is None:
                break
            try:
                self._execute_batch(mb)
            except Exception:
                # A failed batch (stale snapshot, missing category,
                # backend error) must not lose admitted requests: put
                # them back at the front of the queue, FIFO preserved,
                # before propagating.
                self.batcher.requeue(mb.requests)
                raise
            n += 1
        return n

    def step(self) -> int:
        """Drain every full bucket; returns micro-batches executed."""
        if self.cfg.auto_refresh:
            self.refresh_policies()
            self.refresh_index()
        return sum(self._drain_queue(key, force=False)
                   for key in self.batcher.queue_keys())

    def flush(self) -> int:
        """Force-drain everything (partial buckets padded up)."""
        n = self.step()
        return n + sum(self._drain_queue(key, force=True)
                       for key in self.batcher.queue_keys())

    # ----------------------------------------------------------- respond
    def take_response(self, request_id: int) -> Optional[ServeResponse]:
        return self._completed.pop(request_id, None)

    def cancel(self, request_ids) -> int:
        """Abandon admitted requests: drop them from the pending queues
        (including requeued failed batches) and discard any unclaimed
        responses.  Returns how many were still queued."""
        request_ids = list(request_ids)
        for rid in request_ids:
            self._completed.pop(rid, None)
        return self.batcher.remove(request_ids)

    def serve(self, qids: Sequence[int],
              level: ServiceLevel = ServiceLevel.FULL) -> List[ServeResponse]:
        """Synchronous driver: submit a stream, flush, return responses
        in submission order."""
        rids = [self.submit(int(q), level) for q in qids]
        self.flush()
        return [self._completed.pop(r) for r in rids]

    def summary(self) -> dict:
        out = self.telemetry.summary(compile_count=self.compile_count)
        out.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        out["policy_version"] = self.policy_version
        out["index_epoch"] = self.index_epoch
        out["index_epoch_swaps"] = self._c_epoch_swaps.value
        return out
