"""Shape-bucketed micro-batching for the serving engine.

JAX retraces a jitted rollout for every distinct input shape, so a
serving loop that forwards whatever batch composition arrives — the
seed driver's per-category mask split produced a different split size
almost every batch — recompiles continuously.  The batcher quantizes:
per-category FIFO queues are drained into fixed power-of-two bucket
sizes in [min_bucket, max_bucket]; short drains are padded by
replicating a real lane, and the engine drops every lane past
``n_real`` before responding or caching.  In steady state every
micro-batch therefore hits one of a handful of pre-compiled
executables (see executor.py) and the compile count stops growing.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

__all__ = ["BucketConfig", "PendingRequest", "MicroBatch", "ShapeBucketBatcher",
           "bucket_size_for"]


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    min_bucket: int = 8
    max_bucket: int = 64

    def __post_init__(self):
        if self.min_bucket < 1 or self.max_bucket < self.min_bucket:
            raise ValueError(f"bad bucket range [{self.min_bucket}, {self.max_bucket}]")
        for b in (self.min_bucket, self.max_bucket):
            if b & (b - 1):
                raise ValueError(f"bucket bounds must be powers of two, got {b}")

    def buckets(self) -> List[int]:
        """All bucket sizes this config can emit (the compile universe)."""
        out, b = [], self.min_bucket
        while b <= self.max_bucket:
            out.append(b)
            b *= 2
        return out


def bucket_size_for(n: int, cfg: BucketConfig) -> int:
    """Smallest power-of-two bucket ≥ n, clamped to the config range."""
    if n < 1:
        raise ValueError("empty micro-batch")
    b = cfg.min_bucket
    while b < n and b < cfg.max_bucket:
        b *= 2
    return b


@dataclasses.dataclass
class PendingRequest:
    request_id: int
    qid: int               # id into the query log
    category: int
    cache_key: object
    t_submit: float
    level: int = 0         # ServiceLevel value (FULL=0, SHALLOW=1)
    # Ticket-scoped trace context (repro.obs).  ``span`` is the
    # ticket's root span; ``queue_span`` is its open "queue" child,
    # ended when the request drains into a micro-batch.  ``own_span``
    # marks spans the engine created itself (standalone serving) and
    # must therefore end at response time; cluster-provided spans are
    # ended by the cluster's completion callback.
    span: object = None
    queue_span: object = None
    own_span: bool = False


@dataclasses.dataclass
class MicroBatch:
    category: int
    bucket: int
    requests: List[PendingRequest]     # the real lanes, in FIFO order
    level: int = 0         # every lane shares the micro-batch's level

    @property
    def n_real(self) -> int:
        return len(self.requests)

    def padded_qids(self) -> np.ndarray:
        """(bucket,) qids with padded lanes replicating the first real
        lane — its rollout result is discarded, so any valid qid works."""
        qids = np.full(self.bucket, self.requests[0].qid, np.int64)
        qids[: self.n_real] = [r.qid for r in self.requests]
        return qids


class ShapeBucketBatcher:
    """Per-(category, service-level) FIFO queues drained into shape
    buckets.  Levels never mix inside one micro-batch: a SHALLOW lane
    runs the snapshot's fallback policy through a different executable
    than its FULL neighbour, so they batch separately by construction.
    """

    def __init__(self, cfg: BucketConfig = BucketConfig()):
        self.cfg = cfg
        self._queues: Dict[tuple, Deque[PendingRequest]] = {}

    @staticmethod
    def _key(req: PendingRequest) -> tuple:
        return (req.category, int(req.level))

    def enqueue(self, req: PendingRequest) -> None:
        self._queues.setdefault(self._key(req), deque()).append(req)

    def enqueue_many(self, reqs: List[PendingRequest]) -> None:
        """Append a slab of admitted requests in order — same FIFO the
        scalar loop would produce, one queue resolve per run of equal
        (category, level)."""
        queues = self._queues
        last_key, q = None, None
        for req in reqs:
            key = (req.category, int(req.level))
            if key != last_key:
                q = queues.get(key)
                if q is None:
                    q = queues.setdefault(key, deque())
                last_key = key
            q.append(req)

    def requeue(self, reqs: List[PendingRequest]) -> None:
        """Put a drained (but unexecuted) micro-batch back at the FRONT
        of its queues, preserving FIFO order for the retry."""
        for req in reversed(reqs):
            self._queues.setdefault(self._key(req), deque()).appendleft(req)

    def remove(self, request_ids) -> int:
        """Drop queued requests by id (cancellation — e.g. a caller
        giving up on a repeatedly failing batch); returns the count."""
        request_ids = set(request_ids)
        n = 0
        for q in self._queues.values():
            kept = [r for r in q if r.request_id not in request_ids]
            n += len(q) - len(kept)
            q.clear()
            q.extend(kept)
        return n

    def pending(self, key: Optional[tuple] = None) -> int:
        if key is not None:
            return len(self._queues.get(key, ()))
        # list() snapshots the values atomically under the GIL (single
        # C-level call, no bytecode boundary), so this stays safe when
        # a router thread polls while the owning thread enqueues a
        # first-of-its-queue request (which inserts a dict key); a
        # plain generator over .values() can raise "dictionary changed
        # size during iteration" there.
        return sum(len(q) for q in list(self._queues.values()))

    def queue_keys(self) -> List[tuple]:
        """Non-empty (category, level) queues."""
        return [k for k, q in self._queues.items() if q]

    def drain(self, key: tuple, force: bool = False) -> Optional[MicroBatch]:
        """Pop up to max_bucket requests of one (category, level) queue
        into a micro-batch.

        Without ``force``, only a full max_bucket batch is released (the
        throughput-optimal shape); with ``force`` a partial batch drains
        into the smallest fitting bucket — the flush/latency path.
        """
        q = self._queues.get(key)
        if not q:
            return None
        if not force and len(q) < self.cfg.max_bucket:
            return None
        take = min(len(q), self.cfg.max_bucket)
        reqs = [q.popleft() for _ in range(take)]
        category, level = key
        return MicroBatch(category=category,
                          bucket=bucket_size_for(take, self.cfg),
                          requests=reqs, level=level)
