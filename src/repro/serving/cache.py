"""LRU result cache for the online serving engine.

Keys are canonicalized query term sets (category, sorted unique valid
term ids) so syntactic duplicates — repeated hot navigational queries,
the head of the Zipf popularity curve — hit the same entry regardless
of term order or padding.  Values are fully materialized host-side
responses (doc ids, L1 scores, u), so a hit bypasses occupancy
gathering, the rollout, and L1 pruning entirely.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional, Tuple

import numpy as np

from repro.obs import Counter, MetricsRegistry

__all__ = ["canonical_query_key", "versioned_key", "LRUResultCache"]


def canonical_query_key(terms, category: int) -> Tuple[int, Tuple[int, ...]]:
    """(category, sorted deduped valid term ids) — padding (-1) stripped."""
    t = np.asarray(terms).ravel()
    t = t[t >= 0]
    return (int(category), tuple(sorted({int(x) for x in t})))


def versioned_key(base_key: Hashable, policy_version: int,
                  index_epoch: int) -> Tuple[Hashable, int, int]:
    """Full cache key: a cached response embodies BOTH the policy
    snapshot that rolled it out and the index epoch it scanned, so the
    entry key carries both versions.  A policy publish or an index
    epoch swap then invalidates exactly the stale entries — the new
    version simply never looks them up — without flushing results that
    are still current on the other axis.  Static systems pass
    ``index_epoch=0`` forever and the scheme degrades to per-policy
    keying."""
    return (base_key, int(policy_version), int(index_epoch))


class LRUResultCache:
    """Plain OrderedDict LRU with hit/miss accounting.

    ``capacity <= 0`` disables caching (every lookup is a miss), which
    keeps the engine's control flow identical with and without a cache.
    """

    def __init__(self, capacity: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        # With a registry, the counters land in the shared metrics
        # plane (mergeable across replicas, visible in --metrics-json);
        # standalone caches get private instruments.  Either way the
        # hits/misses/evictions attributes below read through.
        reg = registry.counter if registry is not None else (
            lambda name: Counter())
        self._hits = reg("cache.hits")
        self._misses = reg("cache.misses")
        self._evictions = reg("cache.evictions")

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: Hashable) -> Optional[Any]:
        if self.capacity > 0 and key in self._entries:
            self._entries.move_to_end(key)
            self._hits.inc()
            return self._entries[key]
        self._misses.inc()
        return None

    def contains(self, key: Hashable) -> bool:
        """Membership probe without touching LRU order or hit/miss
        stats (used by the cluster router's cache-owner check; safe to
        call from another thread — a stale answer only misroutes one
        request, it cannot corrupt the dict under the GIL)."""
        return self.capacity > 0 and key in self._entries

    def peek(self, key: Hashable) -> Optional[Any]:
        """The entry without touching LRU order or hit/miss stats —
        for callers that must inspect an entry before deciding whether
        it counts as a hit (e.g. the engine's service-level check)."""
        if self.capacity > 0:
            return self._entries.get(key)
        return None

    def record_miss(self) -> None:
        """Count a lookup the caller rejected after ``peek`` (absent or
        incompatible entry) without promoting anything."""
        self._misses.inc()

    def touch(self, key: Hashable) -> None:
        """Recency-only promotion for a caller that already ``peek``ed
        and accepted the entry (the slab hit path): refresh LRU order
        without re-counting a hit."""
        if self.capacity > 0 and key in self._entries:
            self._entries.move_to_end(key)

    def add_stats(self, hits: int = 0, misses: int = 0) -> None:
        """Bulk hit/miss accounting for slab probes (one counter lock
        per slab instead of one per request)."""
        if hits:
            self._hits.inc(int(hits))
        if misses:
            self._misses.inc(int(misses))

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity <= 0:
            return
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._evictions.inc()

    def clear(self) -> None:
        """Drop every entry but keep the hit/miss/eviction counters
        (used on policy hot-swaps; telemetry must span versions)."""
        self._entries.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }
