"""Online query-serving engine (docs/serving.md, docs/policies.md).

submit → admission → result cache → shape-bucketed micro-batch →
pre-compiled per-(bucket, policy-structure) rollout → scatter–gather
merge → L1 prune → respond, with per-request latency/u telemetry.
Policies come from a versioned `repro.policies.PolicyStore` snapshot.
"""
from repro.serving.array_cache import ArrayResultCache
from repro.serving.batcher import (BucketConfig, MicroBatch, PendingRequest,
                                   ShapeBucketBatcher, bucket_size_for)
from repro.serving.cache import LRUResultCache, canonical_query_key
from repro.serving.engine import (SLAB_ADMISSION_REJECT,
                                  SLAB_CACHED_ONLY_MISS, SLAB_OK,
                                  AdmissionError, CacheOnlyMiss, EngineConfig,
                                  ServeEngine, ServeResponse)
from repro.serving.executor import (ShardedExecutor, available_backends,
                                    register_rollout_backend)
from repro.serving.levels import EXECUTED_LEVELS, ServiceLevel
from repro.serving.slab import QueryKeyCache, TicketSlab
from repro.serving.telemetry import Telemetry

__all__ = [
    "AdmissionError", "ArrayResultCache", "BucketConfig", "CacheOnlyMiss",
    "EXECUTED_LEVELS", "EngineConfig", "LRUResultCache", "MicroBatch",
    "PendingRequest", "QueryKeyCache", "SLAB_ADMISSION_REJECT",
    "SLAB_CACHED_ONLY_MISS", "SLAB_OK", "ServeEngine", "ServeResponse",
    "ServiceLevel", "ShapeBucketBatcher", "ShardedExecutor", "Telemetry",
    "TicketSlab", "available_backends", "bucket_size_for",
    "canonical_query_key", "register_rollout_backend",
]
