"""Online query-serving engine (docs/serving.md).

submit → admission → result cache → shape-bucketed micro-batch →
pre-compiled per-shard rollout → scatter–gather merge → L1 prune →
respond, with per-request latency/u telemetry.
"""
from repro.serving.batcher import (BucketConfig, MicroBatch, PendingRequest,
                                   ShapeBucketBatcher, bucket_size_for)
from repro.serving.cache import LRUResultCache, canonical_query_key
from repro.serving.engine import (AdmissionError, EngineConfig, ServeEngine,
                                  ServeResponse)
from repro.serving.executor import ShardedExecutor
from repro.serving.telemetry import Telemetry

__all__ = [
    "AdmissionError", "BucketConfig", "EngineConfig", "LRUResultCache",
    "MicroBatch", "PendingRequest", "ServeEngine", "ServeResponse",
    "ShapeBucketBatcher", "ShardedExecutor", "Telemetry",
    "bucket_size_for", "canonical_query_key",
]
