"""Rollout executor: pre-compiled per-bucket executables and multi-shard
scatter–gather.

The full L0→L1 serve step — greedy policy rollout per index shard,
candidate scatter to global doc ids, static-rank merge across shards
(`merge_shard_candidates`), and L1 rank/prune — is fused into one
function and AOT-compiled (``jit(...).lower(...).compile()``) per
bucket size.  The policy table and state bins are runtime *arguments*,
so one executable serves every query category at that shape; in steady
state the compile count is exactly ``len(BucketConfig.buckets())``.

Sharding here is the logical split of the paper's multi-machine index:
the block axis is cut into ``n_shards`` equal slices, each running its
own rollout under a per-shard u budget ("the same policy is applied on
every machine, which may lead to executing different sequences of match
rules"), then per-shard candidates are gathered and merged by static
rank before L1 — mirroring launch/steps.py's shard_map serve cell but
driven from a single host process.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlearning import greedy_rollout
from repro.core.telescope import l1_prune, merge_shard_candidates
from repro.index.corpus import N_FIELDS

__all__ = ["ShardedExecutor"]


class ShardedExecutor:
    def __init__(self, system, n_shards: int = 1, keep: int = 100):
        if system.bins is None or system.qcfg is None:
            raise ValueError("system needs fit_state_bins() before serving")
        nb = system.env_cfg.n_blocks
        if n_shards < 1 or nb % n_shards:
            raise ValueError(f"n_shards={n_shards} must divide n_blocks={nb}")
        self.system = system
        self.n_shards = n_shards
        self.keep = keep
        self.blocks_per_shard = nb // n_shards
        self.docs_per_shard = self.blocks_per_shard * system.env_cfg.block_docs
        # Each shard scans its slice under the full per-machine u budget.
        self.shard_env_cfg = dataclasses.replace(
            system.env_cfg, n_blocks=self.blocks_per_shard)
        self._jit = jax.jit(self._serve_fn)
        self._compiled: Dict[int, jax.stages.Compiled] = {}
        self.compile_count = 0
        self.execute_count = 0

    # ----------------------------------------------------------- the step
    def _serve_fn(self, bins, q_table, occ, scores, term_present):
        """(B, NB, T, F, W) occupancy → (ids, scores, u, cand_cnt)."""
        sys_ = self.system
        s, ds = self.n_shards, self.docs_per_shard
        b = occ.shape[0]
        occ_sh = occ.reshape(b, s, self.blocks_per_shard, *occ.shape[2:])
        occ_sh = jnp.moveaxis(occ_sh, 1, 0)               # (S, B, nb/S, T, F, W)
        scores_sh = jnp.moveaxis(scores.reshape(b, s, ds), 1, 0)  # (S, B, ds)

        roll = partial(greedy_rollout, self.shard_env_cfg, sys_.qcfg,
                       sys_.ruleset, bins, q_table)
        final, _ = jax.vmap(roll, in_axes=(0, 0, None))(
            occ_sh, scores_sh, term_present)

        shard_base = (jnp.arange(s, dtype=jnp.int32) * ds)[:, None, None]
        global_cand = jnp.where(final.cand >= 0, final.cand + shard_base, -1)
        merged = merge_shard_candidates(
            global_cand, keep=sys_.env_cfg.max_candidates)   # (B, K)
        ids, sc = l1_prune(scores, merged, keep=self.keep)
        u_tot = jnp.sum(final.u, axis=0)
        cand_cnt = jnp.sum((merged >= 0).astype(jnp.int32), axis=1)
        return ids, sc, u_tot, cand_cnt

    # ------------------------------------------------------------ compile
    def _abstract_args(self, bucket: int):
        sys_ = self.system
        cfg = sys_.env_cfg
        t = sys_.log.terms.shape[1]
        f = N_FIELDS
        w = cfg.words_per_block
        sd = jax.ShapeDtypeStruct
        occ = sd((bucket, cfg.n_blocks, t, f, w), jnp.uint32)
        scores = sd((bucket, cfg.n_blocks * cfg.block_docs), jnp.float32)
        tp = sd((bucket, t), jnp.bool_)
        bins = jax.tree_util.tree_map(
            lambda x: sd(x.shape, x.dtype), sys_.bins)
        q_abs = sd((sys_.qcfg.p, sys_.qcfg.n_actions), jnp.float32)
        return bins, q_abs, occ, scores, tp

    def compiled_for(self, bucket: int) -> jax.stages.Compiled:
        exe = self._compiled.get(bucket)
        if exe is None:
            exe = self._jit.lower(*self._abstract_args(bucket)).compile()
            self._compiled[bucket] = exe
            self.compile_count += 1
        return exe

    def warmup(self, buckets: Iterable[int]) -> None:
        for b in buckets:
            self.compiled_for(b)

    # ------------------------------------------------------------ execute
    def execute(self, q_table, occ, scores, term_present
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Run one micro-batch through its pre-compiled executable."""
        exe = self.compiled_for(occ.shape[0])
        ids, sc, u, cnt = exe(self.system.bins, q_table, occ, scores,
                              term_present)
        jax.block_until_ready(ids)
        self.execute_count += 1
        return (np.asarray(ids), np.asarray(sc), np.asarray(u),
                np.asarray(cnt))
