"""Rollout executor: pre-compiled per-(bucket, policy-structure)
executables, a pluggable rollout backend, and multi-shard
scatter–gather.

The full L0→L1 serve step — policy rollout per index shard through
``unified_rollout``, candidate scatter to global doc ids, static-rank
merge across shards (`merge_shard_candidates`), and L1 rank/prune — is
fused into one function and AOT-compiled (``jit(...).lower(...)
.compile()``) per (bucket size, policy structure).  Policy *parameters*
(Q-tables, plan entries, ε) and the state bins are runtime arguments,
so one executable serves every query category sharing a policy
structure, and publishing a new snapshot through a ``PolicyStore``
never retraces; in steady state the compile count is
``len(BucketConfig.buckets()) × n_policy_structures``.

The rollout inner loop is a *backend* chosen at construction and baked
into the AOT compile key: any name registered in the core scan-backend
registry (``repro.core.scan_backends`` — ``"xla"`` block-at-a-time
scanning, ``"pallas_block_scan"`` chunked plane-pruned Pallas, both
bit-identical) runs through ``unified_rollout(..., backend=...)``;
serving-only rollout strategies can additionally be registered here
with ``register_rollout_backend``.

Sharding here is the logical split of the paper's multi-machine index:
the block axis is cut into ``n_shards`` equal slices, each running its
own rollout under a per-shard u budget ("the same policy is applied on
every machine, which may lead to executing different sequences of match
rules"), then per-shard candidates are gathered and merged by static
rank before L1 — mirroring launch/steps.py's shard_map serve cell but
driven from a single host process.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from repro.core.rollout import unified_rollout
from repro.core.scan_backends import available_backends as scan_backends
from repro.core.telescope import l1_prune, merge_shard_candidates
from repro.index.corpus import N_FIELDS
from repro.obs import NULL_TRACER
from repro.policies import Policy

__all__ = ["ShardedExecutor", "available_backends",
           "register_rollout_backend", "resolve_rollout_backend"]


# ------------------------------------------------------------------ backends
# A rollout backend runs one policy rollout over one index shard slice:
#   backend(cfg, ruleset, bins, policy, t_max, occ, scores, tp) -> EnvState
# Every core scan backend (repro.core.scan_backends) is automatically a
# rollout backend via unified_rollout(..., backend=name); this registry
# holds serving-only overrides/extensions.
ROLLOUT_BACKENDS: Dict[str, Callable] = {}


def register_rollout_backend(name: str):
    def deco(fn: Callable) -> Callable:
        ROLLOUT_BACKENDS[name] = fn
        return fn
    return deco


def available_backends() -> Tuple[str, ...]:
    """Serving-selectable rollout backends: the core scan-backend
    registry plus any serving-level registrations."""
    return tuple(sorted(set(ROLLOUT_BACKENDS) | set(scan_backends())))


def _scan_backend_rollout(name, cfg, ruleset, bins, policy, t_max, occ,
                          scores, tp):
    return unified_rollout(cfg, ruleset, bins, policy, t_max,
                           occ, scores, tp, backend=name).final_state


def resolve_rollout_backend(name: str) -> Callable:
    if name in ROLLOUT_BACKENDS:
        return ROLLOUT_BACKENDS[name]
    if name in scan_backends():
        return partial(_scan_backend_rollout, name)
    raise ValueError(
        f"unknown rollout backend {name!r}; available: "
        f"{available_backends()}")


class ShardedExecutor:
    def __init__(self, system, n_shards: int = 1, keep: int = 100,
                 backend: str = "xla"):
        if system.bins is None or system.qcfg is None:
            raise ValueError("system needs fit_state_bins() before serving")
        nb = system.env_cfg.n_blocks
        if n_shards < 1 or nb % n_shards:
            raise ValueError(f"n_shards={n_shards} must divide n_blocks={nb}")
        self.system = system
        self.n_shards = n_shards
        self.keep = keep
        self.backend = backend
        self._backend_fn = resolve_rollout_backend(backend)
        self.blocks_per_shard = nb // n_shards
        self.docs_per_shard = self.blocks_per_shard * system.env_cfg.block_docs
        # Each shard scans its slice under the full per-machine u budget.
        self.shard_env_cfg = dataclasses.replace(
            system.env_cfg, n_blocks=self.blocks_per_shard)
        self._jit = jax.jit(self._serve_fn)
        self._compiled: Dict[tuple, jax.stages.Compiled] = {}
        self.compile_count = 0
        self.execute_count = 0
        # Set by the owning engine when tracing is on; compiles are the
        # dominant cold-start latency, so each gets its own span.
        self.tracer = NULL_TRACER

    # ----------------------------------------------------------- the step
    def _serve_fn(self, bins, policy, occ, scores, term_present):
        """(B, NB, T, F, W) occupancy → (ids, scores, u, cand_cnt)."""
        sys_ = self.system
        s, ds = self.n_shards, self.docs_per_shard
        b = occ.shape[0]
        t_max = policy.horizon or sys_.qcfg.t_max
        occ_sh = occ.reshape(b, s, self.blocks_per_shard, *occ.shape[2:])
        occ_sh = jnp.moveaxis(occ_sh, 1, 0)               # (S, B, nb/S, T, F, W)
        scores_sh = jnp.moveaxis(scores.reshape(b, s, ds), 1, 0)  # (S, B, ds)

        def one_shard(o, sc):
            return self._backend_fn(self.shard_env_cfg, sys_.ruleset, bins,
                                    policy, t_max, o, sc, term_present)

        final = jax.vmap(one_shard)(occ_sh, scores_sh)

        shard_base = (jnp.arange(s, dtype=jnp.int32) * ds)[:, None, None]
        global_cand = jnp.where(final.cand >= 0, final.cand + shard_base, -1)
        merged = merge_shard_candidates(
            global_cand, keep=sys_.env_cfg.max_candidates)   # (B, K)
        ids, sc = l1_prune(scores, merged, keep=self.keep)
        u_tot = jnp.sum(final.u, axis=0)
        cand_cnt = jnp.sum((merged >= 0).astype(jnp.int32), axis=1)
        return ids, sc, u_tot, cand_cnt

    # ------------------------------------------------------------ compile
    @staticmethod
    def _policy_key(policy: Policy) -> tuple:
        leaves, treedef = jax.tree_util.tree_flatten(policy)
        return (treedef,
                tuple((tuple(l.shape), str(l.dtype)) for l in leaves))

    def _abstract_args(self, bucket: int, policy: Policy):
        sys_ = self.system
        cfg = sys_.env_cfg
        t = sys_.log.terms.shape[1]
        f = N_FIELDS
        w = cfg.words_per_block
        sd = jax.ShapeDtypeStruct
        occ = sd((bucket, cfg.n_blocks, t, f, w), jnp.uint32)
        scores = sd((bucket, cfg.n_blocks * cfg.block_docs), jnp.float32)
        tp = sd((bucket, t), jnp.bool_)
        bins = jax.tree_util.tree_map(
            lambda x: sd(x.shape, x.dtype), sys_.bins)
        pol_abs = jax.tree_util.tree_map(
            lambda x: sd(x.shape, x.dtype), policy)
        return bins, pol_abs, occ, scores, tp

    def compiled_for(self, bucket: int, policy: Policy,
                     level: int = 0) -> jax.stages.Compiled:
        if not isinstance(policy, Policy):
            raise TypeError(
                f"expected a repro.policies.Policy, got {type(policy).__name__}; "
                "raw Q-table arrays are no longer accepted — wrap with "
                "TabularQPolicy(q)")
        # The backend AND the service level are part of the compile key:
        # each scan strategy lowers to a distinct executable even at
        # equal bucket/policy, and a degraded (SHALLOW) execution never
        # shares an executable with FULL serving — even if a future
        # fallback happens to share the live policy's structure, the
        # ladder keeps its own compile row.
        key = (bucket, self.backend, int(level), self._policy_key(policy))
        exe = self._compiled.get(key)
        if exe is None:
            with self.tracer.span("compile", bucket=bucket,
                                  backend=self.backend, level=int(level)):
                exe = self._jit.lower(
                    *self._abstract_args(bucket, policy)).compile()
            self._compiled[key] = exe
            self.compile_count += 1
        return exe

    def warmup(self, buckets: Iterable[int], policies: Iterable[Policy],
               level: int = 0) -> None:
        policies = list(policies)
        for b in buckets:
            for pol in policies:
                self.compiled_for(b, pol, level)

    # ------------------------------------------------------------ execute
    def execute(self, policy: Policy, occ, scores, term_present,
                level: int = 0
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Run one micro-batch through its pre-compiled executable."""
        exe = self.compiled_for(occ.shape[0], policy, level)
        ids, sc, u, cnt = exe(self.system.bins, policy, occ, scores,
                              term_present)
        jax.block_until_ready(ids)
        self.execute_count += 1
        return (np.asarray(ids), np.asarray(sc), np.asarray(u),
                np.asarray(cnt))
