"""Array-backed result cache: the hot-path replacement for the dict LRU.

`LRUResultCache` stores one `_CachedResult` object per entry in an
``OrderedDict`` — every hit allocates nothing but every fill allocates
an object + two array refs, every eviction churns the dict, and the
LRU `move_to_end` rewrites linkage per probe.  At cluster QPS the cache
probe is on the critical path of *every* request, hit or miss, so this
module trades the pointer-chasing structure for preallocated parallel
arrays:

- **Open-addressing index** (linear probing over a power-of-two table,
  tombstones for evictions, stored hashes so most collisions resolve
  without touching the key list).  The table is rebuilt in place when
  tombstones would degrade probe lengths.
- **Value slabs**: doc ids / scores / u / cand_cnt / level live in
  preallocated 2-D arrays indexed by slot — a fill is a row write, not
  an allocation.
- **CLOCK (second-chance) eviction** instead of strict LRU: a hit sets
  a reference bit (one store); eviction sweeps a hand clearing bits
  until it finds an unreferenced victim.  This keeps the *incremental*
  cost of recency maintenance O(1) without `move_to_end`'s dict
  surgery, at the price of approximating LRU — acceptable because the
  cache key already embeds (policy version, index epoch), so
  correctness never depends on eviction order, only hit rate does.

The class is protocol-compatible with `LRUResultCache` (get / peek /
contains / touch / record_miss / add_stats / put / clear / stats /
hits / misses / evictions / hit_rate / ``capacity <= 0`` disables), so
`EngineConfig.cache_impl` can flip between the two and the per-ticket
path stays available as the parity oracle.  ``get``/``peek`` return a
:class:`CacheEntry` whose arrays are *copies* — a caller must never
alias a slot row that a later fill may overwrite.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable, List, Optional

import numpy as np

from repro.obs import Counter, MetricsRegistry
from repro.serving.levels import ServiceLevel

__all__ = ["ArrayResultCache", "CacheEntry"]

_EMPTY = -1       # open-addressing cell states
_TOMB = -2

#: int -> ServiceLevel member; the enum ctor is ~0.5us per call, a
#: dict hit is ~50ns, and _entry runs once per cache hit.
_LEVEL_OF = {int(l): l for l in ServiceLevel}


@dataclasses.dataclass
class CacheEntry:
    """Materialized view of one cached result (field-compatible with
    the engine's `_CachedResult`); arrays are owned copies."""
    doc_ids: np.ndarray
    scores: np.ndarray
    u: int
    cand_cnt: int
    level: ServiceLevel = ServiceLevel.FULL


class ArrayResultCache:
    """Open-addressing + CLOCK result cache over preallocated arrays.

    ``keep`` (the per-entry doc count) may be given up front or learned
    from the first ``put`` — the serving engine always fills rows of
    its configured L1 prune depth, so the slabs never reallocate after
    warmup.
    """

    def __init__(self, capacity: int = 4096, keep: int = 0,
                 registry: Optional[MetricsRegistry] = None):
        self.capacity = int(capacity)
        reg = registry.counter if registry is not None else (
            lambda name: Counter())
        self._hits = reg("cache.hits")
        self._misses = reg("cache.misses")
        self._evictions = reg("cache.evictions")
        self._size = 0
        self._hand = 0
        self._keep = int(keep)
        if self.capacity > 0:
            self._alloc_table()
            if self._keep > 0:
                self._alloc_values()

    # ------------------------------------------------------------- layout
    def _alloc_table(self) -> None:
        # Plain Python lists, not numpy: the index is touched one cell
        # at a time on every probe, and list indexing is ~10x cheaper
        # than numpy scalar indexing.  Only the value slabs (row reads/
        # writes) benefit from being arrays.
        t = 4
        while t < 2 * self.capacity:
            t <<= 1
        self._tmask = t - 1
        self._table = [_EMPTY] * t                   # cell -> slot | state
        self._thash = [0] * t                        # stored key hashes
        self._tombs = 0

    def _alloc_values(self) -> None:
        cap, keep = self.capacity, self._keep
        self._ids = np.full((cap, keep), -1, np.int32)
        self._scores = np.zeros((cap, keep), np.float32)
        self._u = [0] * cap
        self._cand = [0] * cap
        self._level = [0] * cap
        self._ndocs = [0] * cap
        self._ref = [0] * cap                        # CLOCK reference bits
        self._tpos = [-1] * cap                      # slot -> table cell
        self._keys: List[Any] = [None] * cap

    def _grow_keep(self, keep: int) -> None:
        ids = np.full((self.capacity, keep), -1, np.int32)
        sc = np.zeros((self.capacity, keep), np.float32)
        ids[:, :self._keep] = self._ids
        sc[:, :self._keep] = self._scores
        self._ids, self._scores, self._keep = ids, sc, keep

    # -------------------------------------------------------------- index
    def _find(self, key: Hashable):
        """-> (slot | -1, insertion cell, hash).  The insertion cell is
        the first tombstone on the probe path (reuse) or the empty cell
        that terminated it."""
        h = hash(key) & 0x7FFFFFFFFFFFFFFF
        i = h & self._tmask
        table, thash, keys = self._table, self._thash, self._keys
        ins = -1
        while True:
            s = table[i]
            if s == _EMPTY:
                return -1, (i if ins < 0 else ins), h
            if s == _TOMB:
                if ins < 0:
                    ins = i
            elif thash[i] == h and keys[s] == key:
                return s, i, h
            i = (i + 1) & self._tmask

    def _rebuild(self) -> None:
        """Reinsert live slots into a clean table (drops tombstones)."""
        table = self._table = [_EMPTY] * (self._tmask + 1)
        self._tombs = 0
        for s in range(self._size):
            key = self._keys[s]
            if key is None:
                continue
            h = hash(key) & 0x7FFFFFFFFFFFFFFF
            i = h & self._tmask
            while table[i] != _EMPTY:
                i = (i + 1) & self._tmask
            table[i] = s
            self._thash[i] = h
            self._tpos[s] = i

    def _evict(self) -> int:
        """CLOCK sweep: clear reference bits until an unreferenced slot
        turns up; detach it from the index and hand it to the caller."""
        ref = self._ref
        while True:
            s = self._hand
            self._hand = (self._hand + 1) % self.capacity
            if ref[s]:
                ref[s] = 0
                continue
            cell = self._tpos[s]
            self._table[cell] = _TOMB
            self._tombs += 1
            self._keys[s] = None
            self._evictions.inc()
            return s

    def _entry(self, s: int) -> CacheEntry:
        # Bypasses the dataclass __init__ (signature binding alone is
        # most of a microsecond); the row copies are the contract — a
        # caller must never alias a slot a later fill may overwrite.
        n = self._ndocs[s]
        e = CacheEntry.__new__(CacheEntry)
        e.doc_ids = self._ids[s, :n].copy()
        e.scores = self._scores[s, :n].copy()
        e.u = self._u[s]
        e.cand_cnt = self._cand[s]
        e.level = _LEVEL_OF[self._level[s]]
        return e

    # ----------------------------------------------------------- protocol
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def __len__(self) -> int:
        return self._size if self.capacity > 0 else 0

    def get(self, key: Hashable) -> Optional[CacheEntry]:
        if self.capacity > 0 and self._size:
            s, _, _ = self._find(key)
            if s >= 0:
                self._ref[s] = 1
                self._hits.inc()
                return self._entry(s)
        self._misses.inc()
        return None

    def peek(self, key: Hashable) -> Optional[CacheEntry]:
        """Entry without recency or hit/miss side effects."""
        if self.capacity > 0 and self._size:
            s, _, _ = self._find(key)
            if s >= 0:
                return self._entry(s)
        return None

    def contains(self, key: Hashable) -> bool:
        return (self.capacity > 0 and self._size > 0
                and self._find(key)[0] >= 0)

    def touch(self, key: Hashable) -> None:
        """Recency-only promotion for a caller that already ``peek``ed
        and accepted the entry (the slab hit path): sets the CLOCK bit
        without re-probing stats."""
        if self.capacity > 0 and self._size:
            s, _, _ = self._find(key)
            if s >= 0:
                self._ref[s] = 1

    def record_miss(self) -> None:
        self._misses.inc()

    def add_stats(self, hits: int = 0, misses: int = 0) -> None:
        """Bulk hit/miss accounting for slab probes (one counter lock
        per slab instead of one per request)."""
        if hits:
            self._hits.inc(int(hits))
        if misses:
            self._misses.inc(int(misses))

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity <= 0:
            return
        ids = np.asarray(value.doc_ids, np.int32).ravel()
        scores = np.asarray(value.scores, np.float32).ravel()
        n = int(ids.size)
        if self._keep == 0:
            self._keep = max(n, 1)
            self._alloc_values()
        elif n > self._keep:
            self._grow_keep(n)
        s, cell, h = self._find(key)
        if s < 0:
            # Amortized hygiene: rebuild before tombstones stretch probe
            # chains (live + tombs capped at ~70% of the table).
            if (self._size + self._tombs) * 10 >= (self._tmask + 1) * 7:
                self._rebuild()
                _, cell, h = self._find(key)
            if self._size < self.capacity:
                s = self._size
                self._size += 1
            else:
                # Eviction turns the victim's cell into a tombstone; the
                # insertion cell found above stays valid (it was empty
                # or already a tombstone on this key's probe path).
                s = self._evict()
            if self._table[cell] == _TOMB:
                self._tombs -= 1
            self._table[cell] = s
            self._thash[cell] = h
            self._tpos[s] = cell
            self._keys[s] = key
        self._ids[s, :n] = ids
        self._scores[s, :n] = scores
        if n < self._keep:                # pad only when the row is short
            self._ids[s, n:] = -1
            self._scores[s, n:] = 0.0
        self._u[s] = int(value.u)
        self._cand[s] = int(value.cand_cnt)
        self._level[s] = int(value.level)
        self._ndocs[s] = n
        self._ref[s] = 1

    def clear(self) -> None:
        """Drop every entry, keep counters (policy hot-swap hygiene)."""
        if self.capacity <= 0:
            return
        self._table = [_EMPTY] * (self._tmask + 1)
        self._tombs = 0
        self._size = 0
        self._hand = 0
        if self._keep > 0:
            self._keys = [None] * self.capacity
            self._ref = [0] * self.capacity

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }
