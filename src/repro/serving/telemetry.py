"""Serving telemetry: per-request and per-batch accounting.

Latency is wall time from admission to response; u is the paper's index
blocks-accessed unit (shown linear in machine time), so both views of
"cost" are recorded per request.  ``summary()`` aggregates into the
p50/p99 + QPS shape every later scaling PR reports against.

Per-request records live in a bounded sliding window (the engine is a
long-running process; an unbounded list grows by one dict per request
forever), while totals — request/cached/rejected counts — are plain
counters, so summary percentiles are over the window but counts are
lifetime-accurate.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

__all__ = ["Telemetry", "pct"]


def pct(xs, q: float) -> float:
    """Quantile with the empty-input-is-zero policy every serving
    surface (engine summary, cluster stats, benches) shares."""
    return float(np.quantile(xs, q)) if len(xs) else 0.0


_pct = pct


class Telemetry:
    def __init__(self, window: int = 65536):
        self.requests: Deque[dict] = deque(maxlen=window)
        self.batches: Deque[dict] = deque(maxlen=window)
        self.total_requests = 0
        self.total_cached = 0
        self.rejected = 0
        # ServiceLevel value -> lifetime count of served requests (the
        # degradation-ladder mix; sheds never reach the engine).
        self.level_counts: Dict[int, int] = {}
        # Load gauges (current + lifetime peak), fed by the engine on
        # every enqueue/drain — the router's balancing signal.
        self.queue_depth = 0
        self.inflight = 0
        self.peak_queue_depth = 0
        self.peak_inflight = 0
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------- clocks
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def _touch(self, t: float) -> None:
        if self._t_start is None:
            self._t_start = t
        self._t_last = t

    # ------------------------------------------------------------ records
    def record_request(self, *, category: int, latency_s: float, u: int,
                       cached: bool, t_done: float, level: int = 0) -> None:
        self._touch(t_done)
        self.total_requests += 1
        self.total_cached += bool(cached)
        self.level_counts[int(level)] = self.level_counts.get(int(level), 0) + 1
        self.requests.append({
            "category": int(category),
            "latency_s": float(latency_s),
            "u": int(u),
            "cached": bool(cached),
            "level": int(level),
        })

    def record_batch(self, *, category: int, bucket: int, n_real: int,
                     t_inputs_s: float, t_execute_s: float) -> None:
        self.batches.append({
            "category": int(category),
            "bucket": int(bucket),
            "n_real": int(n_real),
            "n_padded": int(bucket - n_real),
            "t_inputs_s": float(t_inputs_s),
            "t_execute_s": float(t_execute_s),
        })

    def record_rejection(self) -> None:
        self.rejected += 1

    def observe_gauges(self, queue_depth: int, inflight: int) -> None:
        self.queue_depth = int(queue_depth)
        self.inflight = int(inflight)
        self.peak_queue_depth = max(self.peak_queue_depth, self.queue_depth)
        self.peak_inflight = max(self.peak_inflight, self.inflight)

    # ------------------------------------------------------------ summary
    def summary(self, compile_count: int = 0) -> Dict[str, float]:
        lat = np.array([r["latency_s"] for r in self.requests], np.float64)
        us = np.array([r["u"] for r in self.requests], np.float64)
        cached = np.array([r["cached"] for r in self.requests], bool)
        span = ((self._t_last - self._t_start)
                if self._t_start is not None and self._t_last is not None
                and self._t_last > self._t_start else 0.0)
        lanes = sum(b["bucket"] for b in self.batches)
        padded = sum(b["n_padded"] for b in self.batches)
        return {
            "n_requests": self.total_requests,
            "n_rejected": self.rejected,
            "n_batches": len(self.batches),
            "n_cached": self.total_cached,
            "cache_hit_rate": float(cached.mean()) if len(cached) else 0.0,
            "qps": (len(self.requests) / span) if span > 0 else 0.0,
            "latency_p50_ms": _pct(lat, 0.50) * 1e3,
            "latency_p99_ms": _pct(lat, 0.99) * 1e3,
            "latency_mean_ms": float(lat.mean()) * 1e3 if len(lat) else 0.0,
            "mean_u": float(us.mean()) if len(us) else 0.0,
            "p99_u": _pct(us, 0.99),
            "padding_overhead": (padded / lanes) if lanes else 0.0,
            "level_counts": dict(sorted(self.level_counts.items())),
            "compile_count": int(compile_count),
            "queue_depth": self.queue_depth,
            "inflight": self.inflight,
            "peak_queue_depth": self.peak_queue_depth,
            "peak_inflight": self.peak_inflight,
        }
