"""Serving telemetry: per-request and per-batch accounting.

Latency is wall time from admission to response; u is the paper's index
blocks-accessed unit (shown linear in machine time), so both views of
"cost" are recorded per request.  ``summary()`` aggregates into the
p50/p99 + QPS shape every later scaling PR reports against.

Storage is split by what each consumer needs:

- **Counters / gauges / per-(level, category) histograms** live in a
  :class:`repro.obs.MetricsRegistry` — mergeable across replicas (fleet
  stats are a fold over snapshots) and JSON-serializable for
  ``--metrics-json``.  The legacy attributes (``total_requests``,
  ``rejected``, ``level_counts``, ``queue_depth`` …) are read-through
  views onto those instruments.
- **Per-request / per-batch records** stay in bounded sliding windows
  (the engine is a long-running process; an unbounded list grows by one
  dict per request forever) because summary percentiles are *exact*
  ``np.quantile`` over the window — fixed histogram buckets are for the
  merged fleet view, not for the benches that compare p99s to fractions
  of a millisecond.

QPS is the windowed request count over the *window's own* time span
(first to last ``t_done`` currently in the deque).  Dividing by the
lifetime span — as an earlier version did — underestimates QPS once the
window wraps, because the numerator saturates at ``maxlen`` while the
denominator keeps growing.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

from repro.obs import Counter, MetricsRegistry

__all__ = ["Telemetry", "pct", "LATENCY_MS_EDGES", "U_EDGES"]


class _RequestRecorder:
    """Pre-resolved instrument handles for one (level, category) cell.

    Hot paths fetch this bundle once (single tuple-keyed dict lookup)
    and then touch raw instruments — no ``metric_key`` label hashing,
    no per-histogram cache probes, per request."""

    __slots__ = ("level_counter", "lat_hist", "u_hist", "qwait_hist")

    def __init__(self, registry: MetricsRegistry, level_counter: Counter,
                 level: int, category: int):
        self.level_counter = level_counter
        self.lat_hist = registry.histogram(
            "serve.latency_ms", LATENCY_MS_EDGES,
            level=level, category=category)
        self.u_hist = registry.histogram(
            "serve.u", U_EDGES, level=level, category=category)
        self.qwait_hist = registry.histogram(
            "serve.queue_wait_ms", LATENCY_MS_EDGES,
            level=level, category=category)


def pct(xs, q: float) -> float:
    """Quantile with the empty-input-is-zero policy every serving
    surface (engine summary, cluster stats, benches) shares."""
    return float(np.quantile(xs, q)) if len(xs) else 0.0


_pct = pct

# Fixed bucket layouts shared by every replica so snapshots merge
# elementwise (see docs/observability.md for the rationale).
#: Latency / queue-wait edges in ms: 1-2-5 decades, 100 µs … 10 s.
LATENCY_MS_EDGES = (0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                    100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0, 10000.0)
#: u (index blocks accessed) edges: powers of two up to 128 Ki blocks.
U_EDGES = tuple(float(2 ** i) for i in range(18))


class Telemetry:
    def __init__(self, window: int = 65536,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.requests: Deque[dict] = deque(maxlen=window)
        self.batches: Deque[dict] = deque(maxlen=window)
        # Instrument handles — resolved once, recorded through on the
        # hot path without re-deriving (name, labels) keys per event.
        self._c_requests = self.registry.counter("serve.requests")
        self._c_cached = self.registry.counter("serve.cached")
        self._c_rejected = self.registry.counter("serve.rejected")
        # Depth gauges fold as SUMS across replicas: the fleet's merged
        # queue depth is total pending work (capacity math), not the
        # hottest replica's — peak-style gauges keep the max default.
        self._g_queue_depth = self.registry.gauge("serve.queue_depth",
                                                  agg="sum")
        self._g_inflight = self.registry.gauge("serve.inflight", agg="sum")
        self._level_counters: Dict[int, Counter] = {}
        self._hists: Dict[tuple, object] = {}
        # Pre-resolved per-(level, category) handle bundles: one dict
        # lookup on the hot path instead of three, and no label-dict
        # hashing per request (satellite of the batched data plane).
        self._recorders: Dict[tuple, "_RequestRecorder"] = {}
        # summary() memo: every record_* flips the dirty bit; a clean
        # summary is a cached-dict copy instead of a full window pass.
        self._summary_dirty = True
        self._summary_cache: Optional[Dict[str, float]] = None
        self._summary_compile_count = -1

    # ------------------------------------------------------------- clocks
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    # ------------------------------------------- registry handle caches
    def _level_counter(self, level: int) -> Counter:
        c = self._level_counters.get(level)
        if c is None:
            c = self._level_counters[level] = self.registry.counter(
                "serve.requests_by_level", level=level)
        return c

    def _hist(self, name: str, edges, level: int, category: int):
        key = (name, level, category)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = self.registry.histogram(
                name, edges, level=level, category=category)
        return h

    def recorder(self, level: int, category: int) -> _RequestRecorder:
        """Handle bundle for one (level, category) cell — resolve once
        at construction / first sight, record through raw instruments
        thereafter."""
        key = (level, category)
        r = self._recorders.get(key)
        if r is None:
            r = self._recorders[key] = _RequestRecorder(
                self.registry, self._level_counter(level), level, category)
        return r

    # --------------------------------------------- legacy attribute views
    @property
    def total_requests(self) -> int:
        return self._c_requests.value

    @property
    def total_cached(self) -> int:
        return self._c_cached.value

    @property
    def rejected(self) -> int:
        return self._c_rejected.value

    @property
    def level_counts(self) -> Dict[int, int]:
        """ServiceLevel value -> lifetime count of served requests (the
        degradation-ladder mix; sheds never reach the engine)."""
        return {lvl: c.value for lvl, c in self._level_counters.items()}

    @property
    def queue_depth(self) -> int:
        return int(self._g_queue_depth.value)

    @property
    def inflight(self) -> int:
        return int(self._g_inflight.value)

    @property
    def peak_queue_depth(self) -> int:
        return int(self._g_queue_depth.max)

    @property
    def peak_inflight(self) -> int:
        return int(self._g_inflight.max)

    # ------------------------------------------------------------ records
    def record_request(self, *, category: int, latency_s: float, u: int,
                       cached: bool, t_done: float, level: int = 0) -> None:
        category = int(category)
        level = int(level)
        rec = self.recorder(level, category)
        self._c_requests.inc()
        if cached:
            self._c_cached.inc()
        rec.level_counter.inc()
        rec.lat_hist.record(latency_s * 1e3)
        rec.u_hist.record(u)
        self.requests.append({
            "category": category,
            "latency_s": float(latency_s),
            "u": int(u),
            "cached": bool(cached),
            "level": level,
            "t_done": float(t_done),
        })
        self._summary_dirty = True

    def record_requests(self, *, category: int, level: int,
                        latencies_s, us, cached: bool,
                        t_done: float) -> None:
        """Batch form of :meth:`record_request` for one (level,
        category) group: counters bump by ``n`` and histograms take the
        whole slab under one lock each, but the sliding window gets the
        same per-request rows a scalar loop would append."""
        category = int(category)
        level = int(level)
        lat = np.asarray(latencies_s, np.float64).ravel()
        uarr = np.asarray(us, np.float64).ravel()
        n = int(lat.size)
        if n == 0:
            return
        rec = self.recorder(level, category)
        self._c_requests.inc(n)
        if cached:
            self._c_cached.inc(n)
        rec.level_counter.inc(n)
        rec.lat_hist.record_many(lat * 1e3)
        rec.u_hist.record_many(uarr)
        cached = bool(cached)
        t_done = float(t_done)
        self.requests.extend(
            {"category": category, "latency_s": float(lat[i]),
             "u": int(uarr[i]), "cached": cached, "level": level,
             "t_done": t_done}
            for i in range(n))
        self._summary_dirty = True

    def record_queue_wait(self, *, category: int, level: int,
                          wait_s: float) -> None:
        """Admission-to-drain wait — the slice of latency the batcher
        owns, recorded separately so the SLO loop can tell queueing
        pressure from execution cost."""
        self.recorder(int(level), int(category)).qwait_hist.record(
            wait_s * 1e3)

    def record_batch(self, *, category: int, bucket: int, n_real: int,
                     t_inputs_s: float, t_execute_s: float) -> None:
        self.batches.append({
            "category": int(category),
            "bucket": int(bucket),
            "n_real": int(n_real),
            "n_padded": int(bucket - n_real),
            "t_inputs_s": float(t_inputs_s),
            "t_execute_s": float(t_execute_s),
        })
        self._summary_dirty = True

    def record_rejection(self, n: int = 1) -> None:
        self._c_rejected.inc(n)
        self._summary_dirty = True

    def observe_gauges(self, queue_depth: int, inflight: int) -> None:
        self._g_queue_depth.set(int(queue_depth))
        self._g_inflight.set(int(inflight))
        self._summary_dirty = True

    # ------------------------------------------------------------ summary
    def summary(self, compile_count: int = 0) -> Dict[str, float]:
        """Aggregate view; computed once per dirty window.  Repeated
        calls between records return a copy of the cached dict instead
        of re-running the O(window) percentile pass each time."""
        if (not self._summary_dirty and self._summary_cache is not None
                and self._summary_compile_count == int(compile_count)):
            out = dict(self._summary_cache)
            out["level_counts"] = dict(self._summary_cache["level_counts"])
            return out
        out = self._compute_summary(compile_count)
        self._summary_cache = out
        self._summary_compile_count = int(compile_count)
        self._summary_dirty = False
        return dict(out, level_counts=dict(out["level_counts"]))

    def _compute_summary(self, compile_count: int = 0) -> Dict[str, float]:
        lat = np.array([r["latency_s"] for r in self.requests], np.float64)
        us = np.array([r["u"] for r in self.requests], np.float64)
        cached = np.array([r["cached"] for r in self.requests], bool)
        span = ((self.requests[-1]["t_done"] - self.requests[0]["t_done"])
                if len(self.requests) >= 2 else 0.0)
        lanes = sum(b["bucket"] for b in self.batches)
        padded = sum(b["n_padded"] for b in self.batches)
        return {
            "n_requests": self.total_requests,
            "n_rejected": self.rejected,
            "n_batches": len(self.batches),
            "n_cached": self.total_cached,
            "cache_hit_rate": float(cached.mean()) if len(cached) else 0.0,
            "qps": (len(self.requests) / span) if span > 0 else 0.0,
            "latency_p50_ms": _pct(lat, 0.50) * 1e3,
            "latency_p99_ms": _pct(lat, 0.99) * 1e3,
            "latency_mean_ms": float(lat.mean()) * 1e3 if len(lat) else 0.0,
            "mean_u": float(us.mean()) if len(us) else 0.0,
            "p99_u": _pct(us, 0.99),
            "padding_overhead": (padded / lanes) if lanes else 0.0,
            "level_counts": dict(sorted(self.level_counts.items())),
            "compile_count": int(compile_count),
            "queue_depth": self.queue_depth,
            "inflight": self.inflight,
            "peak_queue_depth": self.peak_queue_depth,
            "peak_inflight": self.peak_inflight,
        }
