"""Service levels: the graceful-degradation ladder's vocabulary.

The paper prices query evaluation in u (index blocks accessed), and the
cluster's admission ledger reserves u per query.  Under pressure the
honest alternative to queueing into a latency collapse is not a binary
admit/shed, but a *ladder* of progressively cheaper ways to answer:

    FULL        the live learned policy, full horizon (normal serving)
    SHALLOW     the snapshot's fallback policy — a truncated static
                plan whose u is bounded by the plan's summed Δu quotas
    CACHED_ONLY answer only if some replica's result cache already
                holds the key (costs ~zero u); otherwise shed
    SHED        explicit non-response (the pressure valve of last resort)

Levels are ordered by degradation: a cached result produced at level L
may answer a request admitted at any level >= L (a FULL result serves
everyone; a SHALLOW result must never silently answer a FULL request).
``EXECUTED_LEVELS`` are the two that run a rollout and therefore carry
their own (category, df-decile) u-estimate rows and their own entry in
the AOT compile key.
"""
from __future__ import annotations

import enum

__all__ = ["ServiceLevel", "EXECUTED_LEVELS"]


class ServiceLevel(enum.IntEnum):
    FULL = 0
    SHALLOW = 1
    CACHED_ONLY = 2
    SHED = 3

    @property
    def degraded(self) -> bool:
        return self is not ServiceLevel.FULL


#: Levels that execute a rollout (and so have a learnable u cost).
EXECUTED_LEVELS = (ServiceLevel.FULL, ServiceLevel.SHALLOW)
