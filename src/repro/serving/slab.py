"""Ticket slabs: packed struct-of-arrays arrivals for the bulk APIs.

A slab carries a batch of arrivals as parallel NumPy arrays instead of
per-ticket Python objects — the `submit_many` spine (engine, cluster,
shm rings) moves these around and only materializes per-request
objects where a response must exist.  The slab is deliberately *dumb*:
it owns no behavior beyond construction, so every layer interprets the
same five columns (qid, category, level, epoch, trace root).

`QueryKeyCache` memoizes qid → canonical cache key.  The query log is
append-only (a qid's term set never mutates), so memoized keys stay
valid for the log's lifetime; the memo is capacity-bounded with a
wholesale reset because a per-entry LRU would reintroduce exactly the
bookkeeping the slab path removes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serving.cache import canonical_query_key

__all__ = ["TicketSlab", "QueryKeyCache"]


@dataclasses.dataclass
class TicketSlab:
    """One batch of arrivals, struct-of-arrays."""
    qids: np.ndarray                      # (n,) int64
    categories: np.ndarray                # (n,) int32
    levels: np.ndarray                    # (n,) int8 ServiceLevel values
    epoch: int = 0                        # index epoch at admission
    trace_roots: Optional[np.ndarray] = None   # (n,) uint64; None = off

    def __len__(self) -> int:
        return int(self.qids.size)

    @classmethod
    def build(cls, log, qids, level: int = 0, levels=None,
              epoch: int = 0, trace_roots=None) -> "TicketSlab":
        """Gather categories from the query log in one fancy-index."""
        q = np.asarray(qids, np.int64).ravel()
        cats = np.asarray(log.category)[q].astype(np.int32)
        if levels is None:
            lv = np.full(q.size, int(level), np.int8)
        else:
            lv = np.asarray(levels, np.int8).ravel()
            if lv.size != q.size:
                raise ValueError(f"levels has {lv.size} entries for "
                                 f"{q.size} qids")
        roots = (None if trace_roots is None
                 else np.asarray(trace_roots, np.uint64).ravel())
        return cls(qids=q, categories=cats, levels=lv, epoch=int(epoch),
                   trace_roots=roots)


class QueryKeyCache:
    """qid → canonical (category, sorted term ids) key memo.

    Sound because the query log is append-only; bounded by wholesale
    reset so a long tail of distinct qids cannot grow the memo forever.
    Safe under the GIL without a lock: a racing duplicate computation
    lands the same value.
    """

    def __init__(self, log, capacity: int = 262144):
        self._log = log
        self.capacity = int(capacity)
        self._memo: Dict[int, Tuple[int, Tuple[int, ...]]] = {}

    def __len__(self) -> int:
        return len(self._memo)

    def key(self, qid: int, category: Optional[int] = None):
        qid = int(qid)
        k = self._memo.get(qid)
        if k is None:
            cat = (int(self._log.category[qid]) if category is None
                   else int(category))
            k = canonical_query_key(self._log.terms[qid], cat)
            if len(self._memo) >= self.capacity:
                self._memo.clear()
            self._memo[qid] = k
        return k
