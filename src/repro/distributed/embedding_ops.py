"""Distributed embedding lookup (the sharded EmbeddingBag).

Tables row-shard over `model`; a shard_map local mask-gather + psum
implements the lookup without ever all-gathering the table — grads
transpose to scatter-adds that stay sharded.  This is the TPU analogue
of a parameter-server embedding shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["sharded_lookup", "sharded_lookup_rs", "sharded_bag_sum"]


def sharded_lookup(table: jnp.ndarray, idx: jnp.ndarray, mesh,
                   data_axes=("data",), model_axis: str = "model") -> jnp.ndarray:
    """table (V, E) sharded P(model, None); idx (B, F) sharded over data.
    Returns (B, F, E) embeddings sharded over data."""
    v = table.shape[0]
    m = mesh.shape[model_axis]
    vloc = v // m

    def local(tbl, ids):
        shard = lax.axis_index(model_axis)
        loc = ids - shard * vloc
        ok = (loc >= 0) & (loc < vloc)
        rows = jnp.take(tbl, jnp.clip(loc, 0, vloc - 1), axis=0)
        rows = rows * ok[..., None].astype(rows.dtype)
        return lax.psum(rows, model_axis)

    ispec = P(data_axes, None) if data_axes else P()
    ospec = P(data_axes, None, None) if data_axes else P()
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis, None), ispec),
        out_specs=ospec,
        check_rep=False,
    )(table, idx)


def sharded_bag_sum(table: jnp.ndarray, idx: jnp.ndarray, mesh,
                    data_axes=("data",), model_axis: str = "model") -> jnp.ndarray:
    """EmbeddingBag(sum) over row-sharded table: (B, L) ids → (B, E)."""
    v = table.shape[0]
    m = mesh.shape[model_axis]
    vloc = v // m

    def local(tbl, ids):
        shard = lax.axis_index(model_axis)
        loc = ids - shard * vloc
        ok = (loc >= 0) & (loc < vloc) & (ids >= 0)
        rows = jnp.take(tbl, jnp.clip(loc, 0, vloc - 1), axis=0)
        rows = rows * ok[..., None].astype(rows.dtype)
        return lax.psum(rows.sum(1), model_axis)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis, None), P(data_axes, None)),
        out_specs=P(data_axes, None),
        check_rep=False,
    )(table, idx)


def sharded_lookup_rs(table: jnp.ndarray, idx: jnp.ndarray, mesh,
                      data_axes=("data",), model_axis: str = "model") -> jnp.ndarray:
    """Reduce-scatter lookup: output batch shards over `model` too.

    The plain psum moves the full (B_loc, F, E) partial per shard even
    though 15/16 of each shard's entries are zeros (a table row lives on
    exactly one shard).  psum_scatter moves half the bytes of the
    all-reduce AND leaves the batch sharded over `model`, so the dense
    tower downstream runs on B/(dp·model) rows per device — 16x less
    compute/memory than the replicated-over-model baseline
    (EXPERIMENTS.md §Perf hillclimb #2).
    idx (B, F) sharded over data -> (B, F, E) sharded over data+model.
    """
    v = table.shape[0]
    m = mesh.shape[model_axis]
    vloc = v // m

    def local(tbl, ids):
        shard = lax.axis_index(model_axis)
        loc = ids - shard * vloc
        ok = (loc >= 0) & (loc < vloc)
        rows = jnp.take(tbl, jnp.clip(loc, 0, vloc - 1), axis=0)
        rows = rows * ok[..., None].astype(rows.dtype)           # (B_loc, F, E)
        return lax.psum_scatter(rows, model_axis, scatter_dimension=0, tiled=True)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis, None), P(data_axes, None)),
        out_specs=P(data_axes + (model_axis,), None, None),
        check_rep=False,
    )(table, idx)
