from .sharding_rules import (
    data_axes, lm_param_specs, zero1_state_specs, kv_cache_specs,
    gnn_param_specs, recsys_param_specs, spec_tree,
)
from .checkpoint import CheckpointManager, save, restore, latest_step
from .fault_tolerance import FaultToleranceConfig, FailureInjector, run_resilient_loop
from .collectives import (
    compress_with_feedback, decompress_accumulate, compressed_psum_grads,
    zeros_like_residual,
)
from .elastic import plan_mesh, plan_mesh_shape, validate_specs, reshard_tree
from .embedding_ops import sharded_lookup, sharded_bag_sum
