"""Elastic scaling: re-mesh and re-shard when the device pool changes.

Checkpoints store LOGICAL (unsharded) arrays (checkpoint.py), so a job
preempted on 2×16×16 can resume on 16×16 (or any factorization): build
the new mesh, re-derive PartitionSpecs from the same rules, device_put.
Divisibility is validated up front so a bad pool fails fast with a
report instead of an XLA error mid-restore.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["plan_mesh", "plan_mesh_shape", "validate_specs", "reshard_tree"]


def plan_mesh_shape(n_devices: int, prefer_model: int = 16):
    """Largest model-axis ≤ prefer_model that divides n_devices."""
    for m in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % m == 0:
            return (n_devices // m, m)
    raise ValueError(f"cannot factor {n_devices} devices")


def plan_mesh(n_devices: int, prefer_model: int = 16):
    """Pick a (data, model) mesh for an arbitrary device count."""
    return jax.make_mesh(plan_mesh_shape(n_devices, prefer_model), ("data", "model"))


def validate_specs(tree: Any, specs: Any, mesh) -> List[str]:
    """Return human-readable problems (empty list = clean)."""
    problems = []
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    for leaf, spec in zip(flat, flat_s):
        if not isinstance(spec, P):
            continue
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim >= len(leaf.shape) or leaf.shape[dim] % size != 0:
                problems.append(
                    f"dim {dim} of shape {leaf.shape} not divisible by "
                    f"{axes}={size}")
    return problems


def reshard_tree(tree: Any, specs: Any, mesh) -> Any:
    """device_put every leaf with its spec on the (new) mesh."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    out = [jax.device_put(l, NamedSharding(mesh, s)) if isinstance(s, P)
           else jax.device_put(l) for l, s in zip(flat, flat_s)]
    return jax.tree_util.tree_unflatten(treedef, out)
