"""Sharded, atomic, fault-tolerant checkpoints (no orbax in the env).

Layout:
    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, leaf->file map
        leaf_00000.npy ...   # one .npy per leaf (host-local shards on
                             # multi-host; full arrays on single-host)
    <dir>/step_000123.COMMIT # written LAST -> a checkpoint without a
                             # COMMIT marker is torn and ignored

Properties the fault-tolerance tests exercise:
 - atomicity: COMMIT marker after fsync'd leaf writes + dir rename
 - keep-last-k garbage collection
 - async save (background thread; `wait()` joins before the next save)
 - elastic restore: leaves are saved with LOGICAL (unsharded) shapes and
   can be restored onto any mesh/sharding (`restore(..., shardings=)`)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
            for kp, _ in flat]


def save(directory: str | Path, step: int, tree: Any) -> Path:
    """Atomic checkpoint write. Returns the committed directory."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": _leaf_paths(tree),
        "leaves": [],
        "time": time.time(),
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:
            arr = arr.view(np.uint16)        # numpy can't serialize bf16
        fname = f"leaf_{i:05d}.npy"
        with open(tmp / fname, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": logical_dtype})
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    commit = directory / f"step_{step:09d}.COMMIT"
    commit.write_text(str(time.time()))
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    """Newest COMMITTED step (torn checkpoints are skipped)."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for marker in directory.glob("step_*.COMMIT"):
        s = int(marker.stem.split("_")[1])
        if (directory / f"step_{s:09d}" / "manifest.json").exists():
            steps.append(s)
    return max(steps) if steps else None


def restore(directory: str | Path, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`; optionally placing each leaf
    with `shardings` (a matching pytree of Shardings) — this is the
    elastic-rescale path: logical shapes are mesh-independent."""
    d = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"expected {len(leaves_like)}")
    arrs = []
    for rec in manifest["leaves"]:
        a = np.load(d / rec["file"])
        if rec["dtype"] == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        arrs.append(a)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        arrs = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                for a, s in zip(arrs, flat_sh)]
    restored = jax.tree_util.tree_unflatten(treedef, arrs)
    # cast to the dtypes of `like` (bf16 leaves round-trip via numpy as-is)
    return jax.tree_util.tree_map(
        lambda r, l: jax.numpy.asarray(r, getattr(l, "dtype", None)), restored, like)


class CheckpointManager:
    """keep-last-k + optional async writer + resume discovery."""

    def __init__(self, directory: str | Path, keep: int = 3, async_save: bool = False):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any):
        self.wait()
        # device_get on the caller's thread (arrays may be donated next step)
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree)

    def _save_and_gc(self, step: int, tree: Any):
        save(self.directory, step, tree)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(m.stem.split("_")[1]) for m in self.directory.glob("step_*.COMMIT"))
        for s in steps[: -self.keep]:
            (self.directory / f"step_{s:09d}.COMMIT").unlink(missing_ok=True)
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like: Any, step: Optional[int] = None, shardings: Any = None):
        self.wait()
        step = self.latest() if step is None else step
        if step is None:
            return None, None
        return restore(self.directory, step, like, shardings), step
