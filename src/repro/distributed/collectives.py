"""Distributed-optimization tricks: gradient compression + DP helpers.

`compressed_psum_grads`: bf16 all-reduce with fp32 error feedback — the
residual between the fp32 gradient and its bf16 cast is carried to the
next step, so compression noise doesn't accumulate (1-bit-Adam-style
error feedback, at bf16).  Halves DP gradient bytes on the wire; the
effect is visible in the roofline collective term and convergence
parity is tested in tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_with_feedback", "decompress_accumulate", "compressed_psum_grads"]

PyTree = Any


def compress_with_feedback(grads: PyTree, residual: PyTree) -> Tuple[PyTree, PyTree]:
    """fp32 grads + carried residual -> (bf16 payload, new residual)."""
    def comp(g, r):
        g32 = g.astype(jnp.float32) + r
        payload = g32.astype(jnp.bfloat16)
        new_r = g32 - payload.astype(jnp.float32)
        return payload, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    payload = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return payload, new_res


def decompress_accumulate(payload: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), payload)


def compressed_psum_grads(grads: PyTree, residual: PyTree, axis_name: str):
    """For shard_map DP loops: compress -> psum(bf16) -> decompress.
    Returns (mean grads fp32, new residual)."""
    payload, new_res = compress_with_feedback(grads, residual)
    summed = jax.tree_util.tree_map(
        lambda p: jax.lax.pmean(p, axis_name), payload)
    return decompress_accumulate(summed), new_res


def zeros_like_residual(grads: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
