"""Fault-tolerant training driver: checkpoint/restart, failure
injection, straggler posture.

SPMD posture (DESIGN.md §7): node failures surface as a dead step — the
recovery unit is (re-mesh if needed) + restore-from-last-commit +
replay.  The data pipeline is stateless-seeded by step number, so
replaying never double-feeds or skips a batch.  Straggler mitigation in
synchronous SPMD is cadence + prefetch: checkpoint cadence bounds lost
work, host prefetch hides input jitter, and per-pod async evaluation
keeps slow evals off the training path (see README §Operations).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from .checkpoint import CheckpointManager

__all__ = ["FaultToleranceConfig", "FailureInjector", "run_resilient_loop"]


@dataclasses.dataclass
class FaultToleranceConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = True
    max_restarts: int = 10


class FailureInjector:
    """Deterministic failure schedule for tests: raises at given steps
    (once each) to simulate preemption / node loss."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def run_resilient_loop(
    state: Any,
    step_fn: Callable[[Any, int], Any],       # (state, step) -> state
    n_steps: int,
    ft: FaultToleranceConfig,
    injector: Optional[FailureInjector] = None,
    on_metrics: Optional[Callable[[int, Any], None]] = None,
) -> Dict[str, Any]:
    """Run `n_steps` of `step_fn` surviving injected/real failures.

    Returns {state, restarts, steps_replayed, wall_s}.  `step_fn` must be
    a pure function of (state, step) — the seeded-by-step contract that
    makes replay exact.
    """
    mgr = CheckpointManager(ft.ckpt_dir, keep=ft.keep, async_save=ft.async_save)
    t0 = time.time()
    restarts = 0
    replayed = 0

    restored, start = mgr.restore(state)
    step = 0
    if restored is not None:
        state, step = restored, start + 1

    while step < n_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            state = step_fn(state, step)
            if on_metrics is not None:
                on_metrics(step, state)
            if step % ft.ckpt_every == 0:
                mgr.save(step, state)
            step += 1
        except RuntimeError as e:
            if "injected" not in str(e) or restarts >= ft.max_restarts:
                raise
            restarts += 1
            mgr.wait()
            restored, last = mgr.restore(state)
            if restored is None:
                state_step = 0
            else:
                state, state_step = restored, last + 1
            replayed += max(0, step - state_step)
            step = state_step if restored is not None else 0

    mgr.save(n_steps - 1, state)
    mgr.wait()
    return {"state": state, "restarts": restarts,
            "steps_replayed": replayed, "wall_s": time.time() - t0}
