"""Parameter / input PartitionSpec rules per architecture family.

Megatron-style TP over `model` (attention heads, FFN hidden, vocab,
experts, embedding rows), DP over `pod`×`data`, ZeRO-1-style optimizer
state sharding over `data` (cross-pod ZeRO would ride the slow DCN —
states replicate across pods; DESIGN.md §7), KV-cache sequence sharding
over `model` for decode.

Rules pattern-match on parameter-tree paths, so they work for any
config of a family without per-arch tables.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["data_axes", "lm_param_specs", "zero1_state_specs", "kv_cache_specs",
           "gnn_param_specs", "recsys_param_specs", "spec_tree"]


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _lm_rule(path: str, shape=None, model_size=None, fsdp=False, zero3=False) -> P:
    """Path-pattern → spec for stacked transformer params (leading L dim)."""
    # MoE experts: (L, E, d, f). EP over model when E divides the axis;
    # otherwise TP over d_ff (grok-1: 8 experts on a 16-way axis).
    # With fsdp=True the d_model axis additionally shards over `data`
    # (gathered per layer inside the scan — ZeRO-3 for the expert bulk).
    if "experts" in path:
        e = shape[1] if shape is not None else None
        d_axis = "data" if fsdp else None
        if model_size and e is not None and e % model_size != 0:
            if path.endswith("w_down"):
                return P(None, None, "model", d_axis)
            return P(None, None, d_axis, "model")
        if path.endswith("w_down"):
            return P(None, "model", None, d_axis)
        return P(None, "model", d_axis, None)
    if "router" in path:
        return P()
    if zero3 and path.endswith(("wq", "wk", "wv", "wo", "w_gate", "w_up",
                                 "w_down")):
        # ZeRO-3 dense: (L, a, b) fully sharded; gathered per layer
        return P(None, "data", "model")
    if zero3 and path.endswith(("b_up", "b_down")):
        return P(None, "model")
    if path.endswith(("wq", "wk", "wv", "w_uk", "w_uv")):
        return P(None, None, "model")          # (L, d, heads*dh) — heads sharded
    if path.endswith("w_dkv"):
        return P(None, None, None)             # (L, d, r+dr) — small, replicated
    if path.endswith("wo"):
        return P(None, "model", None)          # (L, heads*dh, d)
    if path.endswith(("w_gate", "w_up")):
        return P(None, None, "model")          # (L, d, dff)
    if path.endswith("w_down"):
        return P(None, "model", None)          # (L, dff, d)
    if path.endswith("b_up"):
        return P(None, "model")
    if path.endswith("embed"):
        if zero3:
            return P()                         # replicated: batch owns `model`
        return P("model", None)                # (V, d) vocab-sharded
    if path.endswith("lm_head"):
        if zero3:
            return P()
        return P(None, "model")                # (d, V)
    return P()                                 # norms, biases


def _paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )
        out[path] = leaf
    return out


def spec_tree(params, rule) -> Any:
    """Apply a (path, leaf)→spec rule over a pytree, preserving structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        try:
            specs.append(rule(path, leaf))
        except TypeError:
            specs.append(rule(path))
    return jax.tree_util.tree_unflatten(treedef, specs)


def lm_param_specs(params, model_size: int | None = None, fsdp: bool = False,
                   zero3: bool = False) -> Any:
    return spec_tree(
        params,
        lambda path, leaf: _lm_rule(path, getattr(leaf, "shape", None), model_size,
                                    fsdp, zero3),
    )


def zero1_state_specs(params, param_specs, mesh, axis: str = "data") -> Any:
    """Optimizer-moment specs: param spec + `axis` added on the largest
    still-unsharded dim that divides evenly (ZeRO-1)."""
    n = mesh.shape[axis]

    def add_axis(spec: P, shape) -> P:
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                used.add(a)
        if axis in used:
            return spec                  # FSDP leaves already consume `data`
        best, best_size = None, 0
        for i, (s, e) in enumerate(zip(shape, entries)):
            if e is None and s % n == 0 and s // n > 0 and s > best_size:
                best, best_size = i, s
        if best is None:
            return spec
        entries[best] = axis
        return P(*entries)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_s = treedef.flatten_up_to(param_specs)
    return jax.tree_util.tree_unflatten(
        treedef, [add_axis(s, p.shape) for p, s in zip(flat_p, flat_s)]
    )


def kv_cache_specs(cache, mesh) -> Any:
    """Decode KV cache: batch over data axes when divisible, sequence over
    `model` (LSE-merged attention; works for 32k×128 and 500k×1 alike)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def rule(leaf):
        # layouts: (L, B, S, kv, dh) or (L, B, S, r)
        b = leaf.shape[1]
        batch_axes = dp if b % dp_size == 0 and b >= dp_size else ()
        rest = [None] * (leaf.ndim - 3)
        return P(None, batch_axes if batch_axes else None, "model", *rest)

    return jax.tree_util.tree_map(rule, cache)


def gnn_param_specs(params, model_size: int | None = None) -> Any:
    def rule(path: str, leaf=None) -> P:
        shape = getattr(leaf, "shape", None)
        if path.endswith(("w_self", "w_neigh")):
            # hidden sharded — but the classifier layer's tiny class dim
            # (e.g. 7/41/47) stays replicated
            if shape is not None and model_size and shape[1] % model_size == 0:
                return P(None, "model")
            return P()
        return P()
    return spec_tree(params, rule)


def recsys_param_specs(params, model_size: int | None = None) -> Any:
    def rule(path: str, leaf=None) -> P:
        shape = getattr(leaf, "shape", None)
        if path.endswith(("embed", "item_embed", "wide", "first_order")):
            # big tables row-shard; tiny ones (pos_embed) replicate
            if (shape is not None and len(shape) == 2
                    and (model_size is None or shape[0] % model_size == 0)
                    and shape[0] >= 4096):
                return P("model", None)
            return P()
        return P()                              # dense towers replicated (small)
    return spec_tree(params, rule)
