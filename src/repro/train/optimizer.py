"""Optimizers — hand-rolled (no optax in the environment).

AdamW with optional bf16 moment storage (halves optimizer-state HBM —
how grok-1-314b fits one pod; DESIGN.md §7), SGD+momentum, Adafactor
(sub-linear memory for the largest configs), global-norm clipping, and
cosine/linear schedules.  All state is a pytree that shards like the
parameters (ZeRO-style over `data` when the sharding rules say so).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "sgdm_init", "sgdm_update",
           "adafactor_init", "adafactor_update", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Any = jnp.float32   # jnp.bfloat16 halves optimizer HBM


def adamw_init(params: PyTree, cfg: AdamWConfig = AdamWConfig()):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig = AdamWConfig(),
                 lr_scale: jnp.ndarray | float = 1.0):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return newp.astype(p.dtype), mu32.astype(cfg.state_dtype), nu32.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    res = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [r[i] for r in res])
    return unf(0), {"mu": unf(1), "nu": unf(2), "count": count}


def sgdm_init(params: PyTree):
    return {"mom": jax.tree_util.tree_map(jnp.zeros_like, params)}


def sgdm_update(params, grads, state, lr: float = 0.01, beta: float = 0.9):
    mom = jax.tree_util.tree_map(lambda m, g: beta * m + g, state["mom"], grads)
    params = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mom)
    return params, {"mom": mom}


# --------------------------------------------------------------- adafactor
def adafactor_init(params: PyTree):
    def init(p):
        if p.ndim >= 2:
            return (jnp.zeros(p.shape[:-1], jnp.float32), jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return (jnp.zeros(p.shape, jnp.float32), None)

    return {
        "fac": jax.tree_util.tree_map(init, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30):
    count = state["count"] + 1
    beta = 1.0 - count.astype(jnp.float32) ** (-decay)

    def upd(p, g, fac):
        g32 = g.astype(jnp.float32)
        sq = g32 * g32 + eps
        if p.ndim >= 2:
            r, c = fac
            r = beta * r + (1 - beta) * sq.mean(-1)
            c = beta * c + (1 - beta) * sq.mean(-2)
            denom = jnp.sqrt(r[..., None] * c[..., None, :] / jnp.maximum(r.mean(-1, keepdims=True)[..., None], eps))
            step = g32 / jnp.maximum(denom, eps)
            newfac = (r, c)
        else:
            v, _ = fac
            v = beta * v + (1 - beta) * sq
            step = g32 / jnp.sqrt(v + eps)
            newfac = (v, None)
        # relative step size (Adafactor's update clipping, simplified)
        rms = jnp.sqrt(jnp.mean(step * step) + eps)
        step = step / jnp.maximum(1.0, rms)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), newfac

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_f = treedef.flatten_up_to(state["fac"])
    res = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [r[i] for r in res])
    return unf(0), {"fac": unf(1), "count": count}


# ------------------------------------------------------------------ utils
def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(step: jnp.ndarray, total: int, warmup: int = 0, floor: float = 0.1):
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    return warm * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))


def linear_warmup(step: jnp.ndarray, warmup: int):
    return jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
