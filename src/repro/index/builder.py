"""Inverted index builder + query-time occupancy tensor construction.

Build side (host, numpy): one CSR-style posting structure per field,
postings implicitly sorted by static rank because doc ids are assigned
in static-rank order.

Query side: for a (padded) set of query terms, gather the posting lists
and scatter them into the bitpacked occupancy tensor
``occ[block, term, field, word]`` consumed by the JAX match-plan
executor and the ``block_scan`` Pallas kernel.  This mirrors what the
production system does when it streams posting blocks from disk; the
occupancy tensor *is* the byte stream whose consumption the RL agent
learns to minimize.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .blocks import WORD_BITS, pack_bits, words_per_block
from .corpus import Corpus, N_FIELDS

__all__ = ["InvertedIndex", "build_index", "build_index_from_pairs",
           "forward_csr", "query_occupancy", "batch_query_occupancy",
           "MAX_QUERY_TERMS"]

MAX_QUERY_TERMS = 4  # queries are padded to this many terms


@dataclasses.dataclass
class InvertedIndex:
    """CSR postings per field + doc metadata."""

    n_docs: int
    vocab_size: int
    block_docs: int
    # per field: indptr (vocab+1,) int64 and doc ids (nnz,) int32
    indptr: List[np.ndarray]
    doc_ids: List[np.ndarray]
    static_rank: np.ndarray           # (n_docs,) float32
    doc_len: np.ndarray               # (n_docs, n_fields) int32 unique-term counts
    df: np.ndarray                    # (vocab, n_fields) int32 document frequencies

    @property
    def n_blocks(self) -> int:
        return self.padded_docs // self.block_docs

    @property
    def padded_docs(self) -> int:
        bd = self.block_docs
        return ((self.n_docs + bd - 1) // bd) * bd

    def postings(self, term: int, field: int) -> np.ndarray:
        lo, hi = self.indptr[field][term], self.indptr[field][term + 1]
        return self.doc_ids[field][lo:hi]


def _field_csr(docs: np.ndarray, terms: np.ndarray, n_docs: int,
               vocab: int, dedup: bool):
    """CSR postings for one field from flat (doc, term) pairs.

    Returns ``(indptr, doc_ids, df_col, doc_len_col)`` in the canonical
    order: postings per term sorted by ascending doc id (= static-rank
    order, the layout the paper's best-first block scan assumes).  With
    ``dedup`` the pairs are first canonicalized (sorted, duplicates
    collapsed); without it the caller promises doc-major pairs with
    unique terms per doc — the fast path for corpus lists, which store
    sorted-unique term arrays already.
    """
    docs = np.asarray(docs, dtype=np.int64).ravel()
    terms = np.asarray(terms, dtype=np.int64).ravel()
    if dedup and len(docs):
        key = np.unique(docs * vocab + terms)          # doc-major sorted
        docs, terms = key // vocab, key % vocab
    counts = np.bincount(terms, minlength=vocab) if len(terms) else \
        np.zeros(vocab, dtype=np.int64)
    indptr = np.zeros(vocab + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    # Stable sort by term: within a term, pairs keep their doc-major
    # (ascending doc id) order — identical to the old cursor fill.
    order = np.argsort(terms, kind="stable")
    ids = docs[order].astype(np.int32)
    df_col = counts.astype(np.int32)
    dl_col = (np.bincount(docs, minlength=n_docs) if len(docs) else
              np.zeros(n_docs, dtype=np.int64)).astype(np.int32)
    return indptr, ids, df_col, dl_col


def build_index_from_pairs(pair_docs: Sequence[np.ndarray],
                           pair_terms: Sequence[np.ndarray], *,
                           n_docs: int, vocab_size: int,
                           static_rank: np.ndarray,
                           block_docs: int = 512,
                           dedup: bool = True) -> InvertedIndex:
    """Build an index directly from flat per-field (doc, term) pair
    arrays — the vectorized core shared by :func:`build_index`, the
    live index's merge compaction, and the ≥1M-doc benchmark generator
    (which synthesizes pairs without ever materializing per-doc lists).

    ``pair_docs[f]``/``pair_terms[f]`` are parallel 1-D arrays for
    field ``f``.  With ``dedup`` (default) duplicate (doc, term) pairs
    are collapsed, so any pair soup produces canonical postings.
    """
    indptrs, doc_id_arrays = [], []
    df = np.zeros((vocab_size, N_FIELDS), dtype=np.int32)
    doc_len = np.zeros((n_docs, N_FIELDS), dtype=np.int32)
    for f in range(N_FIELDS):
        indptr, ids, df[:, f], doc_len[:, f] = _field_csr(
            pair_docs[f], pair_terms[f], n_docs, vocab_size, dedup)
        indptrs.append(indptr)
        doc_id_arrays.append(ids)
    return InvertedIndex(
        n_docs=n_docs,
        vocab_size=vocab_size,
        block_docs=block_docs,
        indptr=indptrs,
        doc_ids=doc_id_arrays,
        static_rank=np.asarray(static_rank, dtype=np.float32),
        doc_len=doc_len,
        df=df,
    )


def build_index(corpus: Corpus, block_docs: int = 512) -> InvertedIndex:
    n_docs = corpus.n_docs
    pair_docs, pair_terms = [], []
    for f in range(N_FIELDS):
        lists = corpus.field_terms[f]
        lens = np.fromiter((len(t) for t in lists), dtype=np.int64,
                           count=n_docs)
        pair_docs.append(np.repeat(np.arange(n_docs, dtype=np.int64), lens))
        pair_terms.append(np.concatenate(lists) if lens.sum() else
                          np.empty(0, dtype=np.int64))
    # Corpus lists are sorted-unique per doc, so the pairs are already
    # canonical — skip the dedup sort.
    return build_index_from_pairs(
        pair_docs, pair_terms, n_docs=n_docs,
        vocab_size=corpus.config.vocab_size,
        static_rank=corpus.static_rank, block_docs=block_docs, dedup=False)


def forward_csr(index: InvertedIndex):
    """Per-field forward CSR (doc → sorted term ids), the transpose of
    the postings.  Returns ``(fwd_indptr, fwd_terms)`` lists: for field
    ``f``, ``fwd_terms[f][fwd_indptr[f][d]:fwd_indptr[f][d+1]]`` are
    doc ``d``'s terms in ascending order.  The live index's base
    segment stores this sidecar so document *updates* can subtract the
    old terms (df maintenance, tombstones) without scanning postings.
    """
    fwd_indptrs, fwd_terms = [], []
    for f in range(N_FIELDS):
        indptr, docs = index.indptr[f], index.doc_ids[f]
        terms = np.repeat(np.arange(index.vocab_size, dtype=np.int64),
                          np.diff(indptr))
        # Stable sort by doc: within a doc, term-major input order is
        # preserved, i.e. terms come out ascending.
        order = np.argsort(docs, kind="stable")
        fi = np.zeros(index.n_docs + 1, dtype=np.int64)
        np.cumsum(np.bincount(docs, minlength=index.n_docs), out=fi[1:])
        fwd_indptrs.append(fi)
        fwd_terms.append(terms[order].astype(np.int32))
    return fwd_indptrs, fwd_terms


def query_occupancy(index: InvertedIndex, terms: Sequence[int]) -> np.ndarray:
    """Build ``occ[block, term, field, word]`` uint32 for one query.

    ``terms`` may be shorter than MAX_QUERY_TERMS; missing slots are
    all-zero planes (the match engine masks them out via the query's
    term-count).
    """
    n_pad = index.padded_docs
    occ_bits = np.zeros((MAX_QUERY_TERMS, N_FIELDS, n_pad), dtype=bool)
    for t, term in enumerate(terms[:MAX_QUERY_TERMS]):
        for f in range(N_FIELDS):
            ids = index.postings(int(term), f)
            occ_bits[t, f, ids] = True
    packed = pack_bits(occ_bits)                      # (T, F, n_pad/32)
    W = words_per_block(index.block_docs)
    n_blocks = index.n_blocks
    packed = packed.reshape(MAX_QUERY_TERMS, N_FIELDS, n_blocks, W)
    return np.ascontiguousarray(packed.transpose(2, 0, 1, 3))  # (block, T, F, W)


def batch_query_occupancy(index: InvertedIndex, term_lists: Sequence[Sequence[int]]) -> np.ndarray:
    """Stack per-query occupancy tensors: (Q, block, T, F, W) uint32."""
    return np.stack([query_occupancy(index, ts) for ts in term_lists])
