"""Inverted index builder + query-time occupancy tensor construction.

Build side (host, numpy): one CSR-style posting structure per field,
postings implicitly sorted by static rank because doc ids are assigned
in static-rank order.

Query side: for a (padded) set of query terms, gather the posting lists
and scatter them into the bitpacked occupancy tensor
``occ[block, term, field, word]`` consumed by the JAX match-plan
executor and the ``block_scan`` Pallas kernel.  This mirrors what the
production system does when it streams posting blocks from disk; the
occupancy tensor *is* the byte stream whose consumption the RL agent
learns to minimize.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .blocks import WORD_BITS, pack_bits, words_per_block
from .corpus import Corpus, N_FIELDS

__all__ = ["InvertedIndex", "build_index", "query_occupancy", "batch_query_occupancy", "MAX_QUERY_TERMS"]

MAX_QUERY_TERMS = 4  # queries are padded to this many terms


@dataclasses.dataclass
class InvertedIndex:
    """CSR postings per field + doc metadata."""

    n_docs: int
    vocab_size: int
    block_docs: int
    # per field: indptr (vocab+1,) int64 and doc ids (nnz,) int32
    indptr: List[np.ndarray]
    doc_ids: List[np.ndarray]
    static_rank: np.ndarray           # (n_docs,) float32
    doc_len: np.ndarray               # (n_docs, n_fields) int32 unique-term counts
    df: np.ndarray                    # (vocab, n_fields) int32 document frequencies

    @property
    def n_blocks(self) -> int:
        return self.padded_docs // self.block_docs

    @property
    def padded_docs(self) -> int:
        bd = self.block_docs
        return ((self.n_docs + bd - 1) // bd) * bd

    def postings(self, term: int, field: int) -> np.ndarray:
        lo, hi = self.indptr[field][term], self.indptr[field][term + 1]
        return self.doc_ids[field][lo:hi]


def build_index(corpus: Corpus, block_docs: int = 512) -> InvertedIndex:
    vocab = corpus.config.vocab_size
    n_docs = corpus.n_docs

    indptrs, doc_id_arrays = [], []
    df = np.zeros((vocab, N_FIELDS), dtype=np.int32)
    doc_len = np.zeros((n_docs, N_FIELDS), dtype=np.int32)

    for f in range(N_FIELDS):
        counts = np.zeros(vocab, dtype=np.int64)
        for d in range(n_docs):
            terms = corpus.field_terms[f][d]
            counts[terms] += 1
            doc_len[d, f] = len(terms)
        df[:, f] = counts
        indptr = np.zeros(vocab + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        ids = np.zeros(indptr[-1], dtype=np.int32)
        cursor = indptr[:-1].copy()
        for d in range(n_docs):
            terms = corpus.field_terms[f][d]
            ids[cursor[terms]] = d
            cursor[terms] += 1
        indptrs.append(indptr)
        doc_id_arrays.append(ids)

    return InvertedIndex(
        n_docs=n_docs,
        vocab_size=vocab,
        block_docs=block_docs,
        indptr=indptrs,
        doc_ids=doc_id_arrays,
        static_rank=corpus.static_rank,
        doc_len=doc_len,
        df=df,
    )


def query_occupancy(index: InvertedIndex, terms: Sequence[int]) -> np.ndarray:
    """Build ``occ[block, term, field, word]`` uint32 for one query.

    ``terms`` may be shorter than MAX_QUERY_TERMS; missing slots are
    all-zero planes (the match engine masks them out via the query's
    term-count).
    """
    n_pad = index.padded_docs
    occ_bits = np.zeros((MAX_QUERY_TERMS, N_FIELDS, n_pad), dtype=bool)
    for t, term in enumerate(terms[:MAX_QUERY_TERMS]):
        for f in range(N_FIELDS):
            ids = index.postings(int(term), f)
            occ_bits[t, f, ids] = True
    packed = pack_bits(occ_bits)                      # (T, F, n_pad/32)
    W = words_per_block(index.block_docs)
    n_blocks = index.n_blocks
    packed = packed.reshape(MAX_QUERY_TERMS, N_FIELDS, n_blocks, W)
    return np.ascontiguousarray(packed.transpose(2, 0, 1, 3))  # (block, T, F, W)


def batch_query_occupancy(index: InvertedIndex, term_lists: Sequence[Sequence[int]]) -> np.ndarray:
    """Stack per-query occupancy tensors: (Q, block, T, F, W) uint32."""
    return np.stack([query_occupancy(index, ts) for ts in term_lists])
