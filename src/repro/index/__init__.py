from .blocks import WORD_BITS, pack_bits, unpack_bits, popcount, words_per_block
from .corpus import FIELDS, N_FIELDS, CorpusConfig, Corpus, generate_corpus
from .builder import (
    MAX_QUERY_TERMS,
    InvertedIndex,
    build_index,
    query_occupancy,
    batch_query_occupancy,
)
