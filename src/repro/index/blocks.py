"""Bitpacked block store for query-time index scanning.

The inverted index is consumed at query time as a *bitpacked occupancy
tensor*::

    occ[block, term, field, word]  (uint32)

bit ``j`` of ``occ[b, t, f, w]`` says whether document ``b*BLOCK_DOCS +
w*32 + j`` contains query term ``t`` in field ``f``.  Documents are laid
out in static-rank order, so scanning blocks in order means scanning the
index best-first — exactly the layout the paper assumes ("the index is
sorted by static rank").

A *block* is the unit of the paper's ``u`` accumulator (index blocks
read from disk).  On TPU the analogue is one HBM→VMEM tile of the
occupancy tensor; the cost model charges one unit of ``u`` per
``(term, field)`` plane a match rule actually inspects in a block (a
rule that looks at fewer fields reads fewer posting blocks).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

WORD_BITS = 32

__all__ = [
    "WORD_BITS",
    "pack_bits",
    "unpack_bits",
    "popcount",
    "doc_bit",
    "words_per_block",
]


def words_per_block(block_docs: int) -> int:
    if block_docs % WORD_BITS != 0:
        raise ValueError(f"block_docs must be a multiple of {WORD_BITS}")
    return block_docs // WORD_BITS


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a boolean array (..., n_docs) into uint32 words (..., n_docs/32).

    Bit ``j`` of word ``w`` corresponds to doc ``w*32 + j`` (LSB-first).
    Host-side (numpy) — used by the index builder.
    """
    bits = np.asarray(bits, dtype=bool)
    n = bits.shape[-1]
    if n % WORD_BITS != 0:
        raise ValueError(f"trailing dim must be a multiple of {WORD_BITS}")
    shaped = bits.reshape(*bits.shape[:-1], n // WORD_BITS, WORD_BITS)
    weights = (1 << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
    packed = (shaped.astype(np.uint64) * weights).sum(-1)
    return packed.astype(np.uint32)


def unpack_bits(words: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_bits` (host-side)."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & np.uint32(1)
    return bits.astype(bool).reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Per-word population count; device-side."""
    return lax.population_count(x)


def doc_bit(words: jnp.ndarray, doc_in_block: jnp.ndarray) -> jnp.ndarray:
    """Extract the bit for a document offset inside a block of words.

    ``words``: (..., W) uint32; ``doc_in_block``: scalar/vector int index.
    """
    w = doc_in_block // WORD_BITS
    b = doc_in_block % WORD_BITS
    return (jnp.take(words, w, axis=-1) >> b.astype(jnp.uint32)) & jnp.uint32(1)
