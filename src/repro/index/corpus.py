"""Synthetic web corpus with multi-field documents and static rank.

Documents carry four fields — Anchor (A), Url (U), Body (B), Title (T) —
mirroring the paper's example match rules.  Terms follow a Zipf
distribution; titles/urls/anchors are correlated subsets of the body so
that field-restricted match rules (e.g. ``term ∈ U|T``) behave the way
they do in a real web index: much sparser, but biased toward documents
for which the term is *topical*.

Documents are generated directly in static-rank order (doc id 0 = best
static rank).  High-rank documents receive more anchor text (popular
pages attract links), which is what makes shallow U|T|A scans effective
for navigational queries — the structural fact the paper's match plans
exploit.

Everything here is host-side numpy: this is the data-preparation layer
that feeds the JAX query-evaluation runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

FIELDS = ("anchor", "url", "body", "title")
N_FIELDS = len(FIELDS)
A, U, B, T = range(N_FIELDS)

__all__ = ["FIELDS", "N_FIELDS", "A", "U", "B", "T", "CorpusConfig", "Corpus", "generate_corpus"]


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 8192
    vocab_size: int = 2048
    zipf_a: float = 1.15          # Zipf exponent for term frequencies
    body_terms: int = 48          # unique body terms per doc (mean)
    title_terms: int = 6
    url_terms: int = 3
    anchor_terms_base: int = 2    # anchors grow with static rank
    anchor_terms_top: int = 12
    n_topics: int = 64            # latent topics tying docs and queries together
    seed: int = 0


@dataclasses.dataclass
class Corpus:
    config: CorpusConfig
    # field_terms[f] : list of np.int32 arrays, one per doc (sorted unique term ids)
    field_terms: List[List[np.ndarray]]
    static_rank: np.ndarray       # (n_docs,) float32, descending in doc-id order
    doc_topic: np.ndarray         # (n_docs,) int32 latent topic per doc
    topic_terms: np.ndarray       # (n_topics, topic_vocab) int32 term ids per topic

    @property
    def n_docs(self) -> int:
        return self.config.n_docs


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


def generate_corpus(config: CorpusConfig = CorpusConfig()) -> Corpus:
    rng = np.random.default_rng(config.seed)
    vocab = config.vocab_size

    probs = _zipf_probs(vocab, config.zipf_a)

    # Latent topics: each topic owns a pocket of moderately rare terms.
    topic_vocab = max(8, vocab // config.n_topics)
    topic_terms = np.zeros((config.n_topics, topic_vocab), dtype=np.int32)
    # Topic terms drawn from the rarer half of the vocabulary so topical
    # queries are CAT1-like (rare multi-term).
    rare_pool = np.arange(vocab // 4, vocab, dtype=np.int32)
    for k in range(config.n_topics):
        topic_terms[k] = rng.choice(rare_pool, size=topic_vocab, replace=False)

    # Static rank: exponential-ish decay, already sorted descending.
    static_rank = np.sort(rng.exponential(scale=1.0, size=config.n_docs))[::-1]
    static_rank = (static_rank / static_rank.max()).astype(np.float32)

    doc_topic = rng.integers(0, config.n_topics, size=config.n_docs).astype(np.int32)

    field_terms: List[List[np.ndarray]] = [[] for _ in range(N_FIELDS)]
    for d in range(config.n_docs):
        topic = doc_topic[d]
        n_body = max(4, rng.poisson(config.body_terms))
        # Body = Zipf background + topical pocket.
        n_topical = max(2, n_body // 4)
        body = np.union1d(
            rng.choice(vocab, size=n_body - n_topical, p=probs),
            rng.choice(topic_terms[topic], size=n_topical),
        ).astype(np.int32)

        # Title: topical subset of the body plus a couple of head terms.
        n_title = min(len(body), max(2, rng.poisson(config.title_terms)))
        topical_in_body = np.intersect1d(body, topic_terms[topic])
        title_pick = topical_in_body[: max(1, n_title // 2)]
        title = np.union1d(
            title_pick, rng.choice(body, size=max(1, n_title - len(title_pick)))
        ).astype(np.int32)

        # URL: small subset of title.
        n_url = min(len(title), max(1, rng.poisson(config.url_terms)))
        url = rng.choice(title, size=n_url, replace=False).astype(np.int32)
        url = np.unique(url)

        # Anchor: grows with static rank (popular pages get more links);
        # drawn from title+topic so navigational scans work.
        frac = static_rank[d]
        n_anchor = int(round(config.anchor_terms_base + frac * (config.anchor_terms_top - config.anchor_terms_base)))
        anchor_pool = np.union1d(title, topic_terms[topic][: topic_vocab // 2])
        n_anchor = min(len(anchor_pool), max(1, n_anchor))
        anchor = np.unique(rng.choice(anchor_pool, size=n_anchor, replace=False)).astype(np.int32)

        field_terms[A].append(anchor)
        field_terms[U].append(url)
        field_terms[B].append(np.unique(body))
        field_terms[T].append(np.unique(title))

    return Corpus(
        config=config,
        field_terms=field_terms,
        static_rank=static_rank,
        doc_topic=doc_topic,
        topic_terms=topic_terms,
    )
