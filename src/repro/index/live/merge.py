"""Background merge: compact delta segments while serving continues.

`MergeDaemon` watches a :class:`~repro.index.live.live_index.LiveIndex`
and runs ``merge()`` on its own thread whenever the committed delta
grows past ``min_delta_docs`` (or on an explicit :meth:`trigger`).  The
heavy compaction happens outside the writer lock, so queries keep
flowing against the pinned epochs the whole time; the new generation
appears to readers as just another epoch publish.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

__all__ = ["MergeConfig", "MergeDaemon"]


@dataclass(frozen=True)
class MergeConfig:
    min_delta_docs: int = 256     # compact once the delta owns this many
    poll_interval_s: float = 0.05
    max_merges: int = 0           # 0 = unbounded; >0 = stop after N (tests)


class MergeDaemon:
    """One background thread compacting a LiveIndex.

    ``start``/``stop`` bracket the thread; ``trigger`` forces a merge
    check immediately (used by load generators between ticks).  Every
    merge is counted on the LiveIndex's own registry
    (``index.merges`` / ``index.merge_ms``), so the daemon carries no
    metric state of its own.
    """

    def __init__(self, live, config: MergeConfig = MergeConfig()):
        self.live = live
        self.config = config
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.merges_run = 0
        self.last_error: BaseException | None = None

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "MergeDaemon":
        if self._thread is not None:
            raise RuntimeError("MergeDaemon already started")
        self._thread = threading.Thread(target=self._run,
                                        name="index-merge", daemon=True)
        self._thread.start()
        return self

    def stop(self, final_merge: bool = False) -> None:
        """Stop the thread; with ``final_merge`` run one last compaction
        inline so shutdown leaves an empty delta."""
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None
        if final_merge and self.live.delta_docs:
            self.live.merge()
            self.merges_run += 1

    def __enter__(self) -> "MergeDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def trigger(self) -> None:
        """Ask the daemon to check (and merge) now, ignoring the poll
        interval — still subject to ``min_delta_docs``."""
        self._wake.set()

    # --------------------------------------------------------------- loop
    def _due(self) -> bool:
        return self.live.delta_docs >= self.config.min_delta_docs

    def _run(self) -> None:
        cfg = self.config
        while not self._stop.is_set():
            self._wake.wait(timeout=cfg.poll_interval_s)
            self._wake.clear()
            if self._stop.is_set():
                return
            if not self._due():
                continue
            try:
                self.live.merge()
                self.merges_run += 1
            except BaseException as e:      # keep serving; surface in stats
                self.last_error = e
                return
            if cfg.max_merges and self.merges_run >= cfg.max_merges:
                return
