"""Tiered live-index segments: mmap base + in-memory delta.

The live index (docs/index.md) serves from two tiers:

- :class:`BaseSegment` — a read-only generation of the index in a flat
  on-disk layout (one raw binary file per array + ``manifest.json``),
  ``np.memmap``-backed so future multi-process replicas map one copy.
  Alongside the CSR postings it stores the *forward* CSR (doc → terms)
  sidecar, which is what lets document updates subtract their old
  terms (tombstones, df maintenance) without scanning postings.
- :class:`DeltaSegment` — an immutable in-memory snapshot of every
  mutation since the base generation, rebuilt from the writer's
  operation log at each commit.  Appended docs take the next doc ids
  after the base (so a from-scratch rebuild of the logical corpus
  assigns identical ids — the bit-parity invariant); updated base docs
  are *tombstoned* in the base (all their base postings masked) and
  carried in the delta's postings under their original id.

Both tiers are immutable once constructed: readers hold a view
(`repro.index.live.live_index.IndexView`) pinned by an epoch and never
see torn state.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.index.builder import InvertedIndex, forward_csr
from repro.index.corpus import N_FIELDS

__all__ = ["BaseSegment", "DeltaOp", "DeltaSegment", "MANIFEST_NAME"]

MANIFEST_NAME = "manifest.json"


def _canon_fields(fields: Sequence[np.ndarray]) -> Tuple[np.ndarray, ...]:
    if len(fields) != N_FIELDS:
        raise ValueError(f"expected {N_FIELDS} field term arrays, "
                         f"got {len(fields)}")
    return tuple(np.unique(np.asarray(f, dtype=np.int32).ravel())
                 for f in fields)


@dataclasses.dataclass(frozen=True)
class DeltaOp:
    """One document mutation in the writer's op log."""
    kind: str                       # "add" | "update"
    doc_id: int
    fields: Tuple[np.ndarray, ...]  # per-field sorted unique term ids
    static_rank: float = 0.0        # adds only; updates keep their rank


class BaseSegment:
    """One read-only index generation + forward CSR sidecar."""

    # (name template, dtype) for every per-field array in the layout
    _FIELD_ARRAYS = (("indptr{f}.i64", np.int64),
                     ("docids{f}.i32", np.int32),
                     ("fwd_indptr{f}.i64", np.int64),
                     ("fwd_terms{f}.i32", np.int32))

    def __init__(self, index: InvertedIndex,
                 fwd_indptr: List[np.ndarray], fwd_terms: List[np.ndarray],
                 generation: int = 0, path: Optional[Path] = None):
        self.index = index
        self.fwd_indptr = fwd_indptr
        self.fwd_terms = fwd_terms
        self.generation = generation
        self.path = path

    # ----------------------------------------------------------- factory
    @classmethod
    def from_index(cls, index: InvertedIndex,
                   generation: int = 0) -> "BaseSegment":
        fi, ft = forward_csr(index)
        return cls(index, fi, ft, generation=generation)

    # ------------------------------------------------------------ access
    @property
    def n_docs(self) -> int:
        return self.index.n_docs

    @property
    def nbytes(self) -> int:
        arrays = (self.index.indptr + self.index.doc_ids
                  + self.fwd_indptr + self.fwd_terms
                  + [self.index.static_rank, self.index.doc_len,
                     self.index.df])
        return int(sum(a.nbytes for a in arrays))

    @property
    def mmapped(self) -> bool:
        return isinstance(self.index.doc_ids[0], np.memmap)

    def doc_terms(self, doc_id: int, field: int) -> np.ndarray:
        """Doc's sorted term ids in one field (forward CSR row)."""
        lo = self.fwd_indptr[field][doc_id]
        hi = self.fwd_indptr[field][doc_id + 1]
        return self.fwd_terms[field][lo:hi]

    def doc_fields(self, doc_id: int) -> Tuple[np.ndarray, ...]:
        return tuple(self.doc_terms(doc_id, f) for f in range(N_FIELDS))

    # --------------------------------------------------------- flat disk
    def _arrays(self) -> Dict[str, np.ndarray]:
        out = {"static_rank.f32": self.index.static_rank,
               "doc_len.i32": self.index.doc_len,
               "df.i32": self.index.df}
        for f in range(N_FIELDS):
            per = (self.index.indptr[f], self.index.doc_ids[f],
                   self.fwd_indptr[f], self.fwd_terms[f])
            for (tmpl, _), arr in zip(self._FIELD_ARRAYS, per):
                out[tmpl.format(f=f)] = arr
        return out

    def save(self, dir_path) -> "BaseSegment":
        """Write the flat layout (raw binaries + manifest) and return a
        fresh segment memmapping the files read-only."""
        dir_path = Path(dir_path)
        dir_path.mkdir(parents=True, exist_ok=True)
        arrays = self._arrays()
        manifest = {
            "generation": self.generation,
            "n_docs": self.index.n_docs,
            "vocab_size": self.index.vocab_size,
            "block_docs": self.index.block_docs,
            "n_fields": N_FIELDS,
            "arrays": {name: {"dtype": str(arr.dtype),
                              "shape": list(arr.shape)}
                       for name, arr in arrays.items()},
        }
        for name, arr in arrays.items():
            np.ascontiguousarray(arr).tofile(dir_path / name)
        (dir_path / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1))
        return self.load(dir_path)

    @classmethod
    def load(cls, dir_path, mmap: bool = True) -> "BaseSegment":
        """Open a saved generation; with ``mmap`` (default) every array
        is a read-only ``np.memmap`` — N processes map one copy."""
        dir_path = Path(dir_path)
        manifest = json.loads((dir_path / MANIFEST_NAME).read_text())

        def arr(name: str) -> np.ndarray:
            spec = manifest["arrays"][name]
            shape = tuple(spec["shape"])
            if mmap:
                return np.memmap(dir_path / name, dtype=spec["dtype"],
                                 mode="r", shape=shape)
            return np.fromfile(dir_path / name,
                               dtype=spec["dtype"]).reshape(shape)

        indptr, doc_ids, fwd_indptr, fwd_terms = [], [], [], []
        for f in range(manifest["n_fields"]):
            indptr.append(arr(f"indptr{f}.i64"))
            doc_ids.append(arr(f"docids{f}.i32"))
            fwd_indptr.append(arr(f"fwd_indptr{f}.i64"))
            fwd_terms.append(arr(f"fwd_terms{f}.i32"))
        index = InvertedIndex(
            n_docs=manifest["n_docs"], vocab_size=manifest["vocab_size"],
            block_docs=manifest["block_docs"], indptr=indptr,
            doc_ids=doc_ids, static_rank=arr("static_rank.f32"),
            doc_len=arr("doc_len.i32"), df=arr("df.i32"))
        return cls(index, fwd_indptr, fwd_terms,
                   generation=manifest["generation"], path=dir_path)


class DeltaSegment:
    """Immutable view of the op log on top of one base generation.

    Last-writer-wins per doc id: re-updating a doc (or updating a doc
    added earlier in the same delta) replaces its field terms.  The
    segment precomputes per-field postings for its docs, the tombstone
    mask over base doc ids, and the *live* df (base df with tombstoned
    contributions subtracted and delta contributions added) — so a view
    answers df/doc_len/occupancy questions without touching the op log.
    """

    def __init__(self, base: BaseSegment, ops: Sequence[DeltaOp] = ()):
        self.base = base
        n_base = base.n_docs
        current: Dict[int, Tuple[np.ndarray, ...]] = {}
        ranks: Dict[int, float] = {}
        next_id = n_base
        for op in ops:
            if op.kind == "add":
                if op.doc_id != next_id:
                    raise ValueError(
                        f"append-only ids: expected doc {next_id}, "
                        f"got {op.doc_id}")
                next_id += 1
                ranks[op.doc_id] = float(op.static_rank)
            elif op.kind != "update":
                raise ValueError(f"unknown op kind {op.kind!r}")
            elif not (0 <= op.doc_id < next_id):
                raise IndexError(f"update of unknown doc {op.doc_id}")
            current[op.doc_id] = op.fields

        self.n_new_docs = next_id - n_base
        self.first_new_doc = n_base
        # doc id -> current per-field terms, for every doc the delta
        # owns — the forward view merge/parity rebuilds read.
        self.doc_fields: Dict[int, Tuple[np.ndarray, ...]] = current
        new_ids = np.arange(n_base, next_id, dtype=np.int64)
        self.static_rank_new = np.asarray(
            [ranks[d] for d in new_ids], dtype=np.float32)
        self.tombstones = np.asarray(
            sorted(d for d in current if d < n_base), dtype=np.int64)
        # O(n_base) bool lookup: vectorized postings filtering.
        self.tomb_mask = np.zeros(n_base, dtype=bool)
        self.tomb_mask[self.tombstones] = True

        # Per-field postings over every doc the delta owns (adds AND
        # updated base docs), plus live df / doc_len deltas.
        self.doc_len_new = np.zeros((self.n_new_docs, N_FIELDS), np.int32)
        self.updated_doc_len: Dict[int, np.ndarray] = {
            int(d): np.zeros(N_FIELDS, np.int32) for d in self.tombstones}
        df = np.asarray(base.index.df, dtype=np.int64).copy()
        self._postings: List[Dict[int, np.ndarray]] = []
        self.nbytes = 0
        own = sorted(current)
        for f in range(N_FIELDS):
            per_term: Dict[int, np.ndarray] = {}
            if own:
                docs_l, terms_l = [], []
                for d in own:
                    t = current[d][f]
                    docs_l.append(np.full(len(t), d, dtype=np.int64))
                    terms_l.append(np.asarray(t, dtype=np.int64))
                    if d >= n_base:
                        self.doc_len_new[d - n_base, f] = len(t)
                    else:
                        self.updated_doc_len[d][f] = len(t)
                        # the doc's base contribution leaves the index
                        df[base.doc_terms(d, f).astype(np.int64), f] -= 1
                docs = np.concatenate(docs_l)
                terms = np.concatenate(terms_l)
                if len(terms):
                    df[:, f] += np.bincount(terms, minlength=df.shape[0])
                order = np.argsort(terms, kind="stable")  # docs asc per term
                t_sorted, d_sorted = terms[order], docs[order].astype(np.int32)
                uniq, starts = np.unique(t_sorted, return_index=True)
                bounds = np.append(starts, len(t_sorted))
                for i, term in enumerate(uniq):
                    ids = d_sorted[bounds[i]:bounds[i + 1]]
                    per_term[int(term)] = ids
                    self.nbytes += ids.nbytes
            self._postings.append(per_term)
        self.df = df.astype(np.int32)

    # ------------------------------------------------------------ access
    _EMPTY = np.empty(0, dtype=np.int32)

    def postings(self, term: int, field: int) -> np.ndarray:
        """Delta doc ids for (term, field), ascending; adds and updated
        base docs alike (an updated doc's base postings are masked via
        :attr:`tomb_mask`, its current terms live here)."""
        return self._postings[field].get(int(term), self._EMPTY)

    def terms_present(self) -> frozenset:
        """Terms with at least one delta posting in any field — the
        admission plane's "does this query touch the delta" probe."""
        out = set()
        for per_term in self._postings:
            out.update(per_term)
        return frozenset(out)

    @property
    def n_docs_owned(self) -> int:
        """Docs whose current truth lives in the delta."""
        return self.n_new_docs + len(self.tombstones)

    def describe(self) -> dict:
        return {"base_generation": self.base.generation,
                "n_new_docs": self.n_new_docs,
                "n_tombstones": int(len(self.tombstones)),
                "nbytes": int(self.nbytes)}
