"""Live-vs-rebuild parity harness.

The live index's one correctness contract: at EVERY epoch, serving
from (base generation + delta) is bit-identical to serving from a
from-scratch ``build_index`` of the logical corpus at that epoch —
same df / doc_len / static_rank, same occupancy planes, and the same
rollout outcome on every scan backend.  ``check_epoch_parity`` pins
all of it; the index-smoke CI target and tests/test_live_index.py run
it at each recorded epoch.

Parity holds *at equal capacity*: the live view always spans
``capacity_blocks`` blocks (fixed AOT shapes), so the rebuilt index's
occupancy is zero-padded up to the same block count before comparison
— all-zero planes are no-ops for both backends.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.match_plan import plan_rollout
from repro.index.builder import (InvertedIndex, batch_query_occupancy,
                                 build_index_from_pairs)
from repro.index.corpus import N_FIELDS

__all__ = ["ParityError", "rebuild_index", "check_epoch_parity"]

DEFAULT_BACKENDS = ("xla", "pallas_block_scan")


class ParityError(AssertionError):
    """The live view diverged from the from-scratch rebuild."""


def rebuild_index(view) -> InvertedIndex:
    """From-scratch index over the view's logical corpus — the oracle
    the live tiers must match bit-for-bit."""
    field_terms = view.logical_field_terms()
    pair_docs, pair_terms = [], []
    for f in range(N_FIELDS):
        lists = field_terms[f]
        lens = np.fromiter((len(t) for t in lists), dtype=np.int64,
                           count=view.n_docs)
        pair_docs.append(np.repeat(np.arange(view.n_docs, dtype=np.int64),
                                   lens))
        pair_terms.append(np.concatenate(lists).astype(np.int64)
                          if lens.sum() else np.empty(0, np.int64))
    # Logical term arrays are sorted-unique per doc (canonicalized at
    # the op log boundary), so the pairs are already canonical.
    return build_index_from_pairs(
        pair_docs, pair_terms, n_docs=view.n_docs,
        vocab_size=view.vocab_size, static_rank=view.static_rank(),
        block_docs=view.block_docs, dedup=False)


def _pad_occ(occ: np.ndarray, capacity_blocks: int) -> np.ndarray:
    """Zero-pad a (Q, blocks, T, F, W) occupancy up to the live view's
    fixed capacity."""
    pad = capacity_blocks - occ.shape[1]
    if pad < 0:
        raise ParityError(f"rebuild spans {occ.shape[1]} blocks, more "
                          f"than capacity {capacity_blocks}")
    if pad == 0:
        return occ
    return np.pad(occ, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))


def _final_fields(final) -> Dict[str, np.ndarray]:
    return {k: np.asarray(getattr(final, k))
            for k in ("u", "v", "cand", "cand_cnt", "topn")}


def check_epoch_parity(system, epoch, query_ids: Sequence[int],
                       backends: Sequence[str] = DEFAULT_BACKENDS) -> dict:
    """Assert live == rebuild at one epoch; returns a report dict.

    Three layers, each raising :class:`ParityError` on divergence:

    1. **structure** — df, doc_len, static_rank of the live view equal
       the from-scratch rebuild's.
    2. **occupancy** — for the sampled queries, the live view's packed
       planes (base ∪ delta, tombstones masked) are bit-identical to
       the rebuilt index's, zero-padded to capacity.
    3. **rollout** — the production plan's final state (u, v, cand,
       cand_cnt, topn) matches across every requested scan backend on
       the live occupancy.  Combined with (2), any backend's rollout
       against a rebuilt index is covered transitively.
    """
    view = epoch.view
    rebuilt = rebuild_index(view)

    # 1. structural parity ------------------------------------------------
    pairs = (("static_rank", view.static_rank(),
              rebuilt.static_rank),
             ("doc_len", view.doc_len(), rebuilt.doc_len),
             ("df", np.asarray(view.df), rebuilt.df))
    for name, live_a, reb_a in pairs:
        if not np.array_equal(np.asarray(live_a), np.asarray(reb_a)):
            raise ParityError(
                f"epoch v{epoch.version} (gen {epoch.generation}): "
                f"{name} diverged from from-scratch rebuild")

    # 2. occupancy parity -------------------------------------------------
    qids = np.asarray(query_ids)
    log = system.log
    term_lists = [log.terms[q, : log.n_terms[q]] for q in qids]
    occ_live = view.batch_query_occupancy(term_lists)
    occ_reb = _pad_occ(batch_query_occupancy(rebuilt, term_lists),
                       view.capacity_blocks)
    if not np.array_equal(occ_live, occ_reb):
        bad = [int(q) for i, q in enumerate(qids)
               if not np.array_equal(occ_live[i], occ_reb[i])]
        raise ParityError(
            f"epoch v{epoch.version} (gen {epoch.generation}): occupancy "
            f"diverged from rebuild for queries {bad[:8]}"
            f"{'…' if len(bad) > 8 else ''}")

    # 3. backend rollout parity ------------------------------------------
    occ, scores, term_present = system.batch_inputs(qids,
                                                    epoch=epoch)
    if not np.array_equal(np.asarray(occ), occ_live):
        raise ParityError(
            f"epoch v{epoch.version}: system.batch_inputs occupancy "
            "disagrees with the pinned view (epoch threading bug)")
    finals: Dict[str, Dict[int, Dict[str, np.ndarray]]] = {}
    cats = np.asarray(log.category)[qids]
    for backend in backends:
        finals[backend] = {}
        for cat in np.unique(cats):
            rows = np.where(cats == cat)[0]
            plan = system.plan_for_category(int(cat))
            final, _ = plan_rollout(
                system.env_cfg, system.ruleset, plan,
                occ[rows], scores[rows], term_present[rows],
                backend=backend)
            finals[backend][int(cat)] = _final_fields(final)
    ref_backend = backends[0]
    for backend in backends[1:]:
        for cat, ref in finals[ref_backend].items():
            got = finals[backend][cat]
            for k, ref_a in ref.items():
                if not np.array_equal(ref_a, got[k]):
                    raise ParityError(
                        f"epoch v{epoch.version}: backend {backend!r} "
                        f"final.{k} diverged from {ref_backend!r} "
                        f"(cat {cat})")

    return {"epoch": epoch.version, "generation": epoch.generation,
            "n_docs": view.n_docs, "n_queries": int(len(qids)),
            "backends": list(backends), "ok": True}
