"""`LiveRetrievalSystem`: the full retrieval system over a live index.

Extends `repro.system.RetrievalSystem` with the tiered live index:
the corpus-built inverted index becomes generation 0 of a
:class:`~repro.index.live.live_index.LiveIndex`, and every batch of
query inputs is served from a pinned :class:`IndexEpoch` — callers
(the serve engine) thread the epoch they pinned through
``batch_inputs(qids, epoch=...)`` so a hot swap mid-batch can never
mix two indexes in one execution.

Shapes are FIXED at the live index's capacity: ``env_cfg.n_blocks`` is
``capacity_blocks`` from construction, so every AOT rollout executable
survives any number of epoch swaps with zero retraces.  Per-epoch
device planes (static rank, doc lengths, zero-padded to capacity) are
memoized in a small LRU keyed by epoch version.

The query log grows too (``append_queries``): freshness workloads
append queries targeting just-added docs, and the trainer/tap see them
like any logged query.  Appends are lock-serialized and strictly
append-only, so concurrent readers indexing by qid stay safe.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.builder import MAX_QUERY_TERMS
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.ranking.l1_ranker import idf_for_terms, score_all_docs
from repro.system import RetrievalSystem, SystemConfig

from .live_index import IndexEpoch, LiveIndex

__all__ = ["EpochReadMixin", "LiveRetrievalSystem"]

_PLANES_LRU = 4   # epochs worth of device planes kept warm


class EpochReadMixin:
    """Read side of an epoch-versioned system: epoch-pinned batch
    inputs plus capacity-padded per-epoch device planes.

    Shared by the writer-side :class:`LiveRetrievalSystem` (whose
    epochs come from its own `LiveIndex`) and the process cell's
    worker-side follower (`repro.cluster.proc.follower`), whose epochs
    arrive over the control channel and are republished into a local
    store.  Hosts must provide ``index_epoch_store`` (an
    `IndexEpochStore`) and the `RetrievalSystem` attributes the batch
    path reads (``log``, ``idf_all``, ``l1_params``), and call
    :meth:`_init_epoch_reader` before the first batch."""

    def _init_epoch_reader(self) -> None:
        self._planes: "OrderedDict[int, Tuple[jnp.ndarray, jnp.ndarray]]" = \
            OrderedDict()
        self._planes_mu = threading.Lock()

    # ------------------------------------------------------------- planes
    def _epoch_planes(self, epoch: IndexEpoch):
        """(static_rank, doc_len) device arrays padded to capacity for
        one epoch, LRU-memoized (a swap only rebuilds two small
        planes, never the occupancy path)."""
        with self._planes_mu:
            hit = self._planes.get(epoch.version)
            if hit is not None:
                self._planes.move_to_end(epoch.version)
                return hit
        view = epoch.view
        cap = view.capacity_docs
        sr = np.zeros(cap, np.float32)
        sr[: view.n_docs] = view.static_rank()
        dl_raw = view.doc_len()
        dl = np.zeros((cap, dl_raw.shape[1]), np.float32)
        dl[: view.n_docs] = np.log1p(dl_raw) / np.log(256.0)
        planes = (jnp.asarray(sr), jnp.asarray(dl))
        with self._planes_mu:
            self._planes[epoch.version] = planes
            while len(self._planes) > _PLANES_LRU:
                self._planes.popitem(last=False)
        return planes

    # ------------------------------------------------------------ batches
    def batch_inputs(self, query_ids: Sequence[int],
                     epoch: Optional[IndexEpoch] = None):
        """Occupancy + L1 scores + masks at one pinned index epoch
        (head epoch when omitted — single-threaded callers)."""
        if epoch is None:
            epoch = self.index_epoch_store.snapshot()
        view = epoch.view
        qids = np.asarray(query_ids)
        log = self.log                      # capture refs: appends swap
        idf_all = self.idf_all              # whole arrays, never resize
        term_lists = [log.terms[q, : log.n_terms[q]] for q in qids]
        occ = jnp.asarray(view.batch_query_occupancy(term_lists))
        term_present = jnp.asarray(log.terms[qids] >= 0)
        idf = jnp.asarray(idf_all[qids])
        static_rank, doc_len = self._epoch_planes(epoch)
        scores = jax.vmap(
            lambda o, i, t: score_all_docs(
                self.l1_params, o, i, t, static_rank, doc_len)
        )(occ, idf, term_present)
        return occ, scores, term_present


class LiveRetrievalSystem(EpochReadMixin, RetrievalSystem):
    def __init__(self, cfg: SystemConfig, *,
                 capacity_docs: Optional[int] = None,
                 storage_dir=None,
                 staleness_bound: int = 64,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Tracer = NULL_TRACER):
        super().__init__(cfg)
        self.live = LiveIndex(self.index, capacity_docs=capacity_docs,
                              staleness_bound=staleness_bound,
                              storage_dir=storage_dir,
                              registry=registry, tracer=tracer)
        # Fixed shapes across epochs: rollouts always span capacity.
        self.env_cfg = dataclasses.replace(
            self.env_cfg, n_blocks=self.live.capacity_blocks)
        self._init_epoch_reader()
        self._log_mu = threading.Lock()
        # Base-class paths (fit_l1, feature extraction) read
        # self.static_rank / self.doc_len directly: re-point them at
        # the capacity-padded epoch-1 planes so their shapes match the
        # capacity-spanning occupancy every live batch produces.
        self.static_rank, self.doc_len = self._epoch_planes(
            self.live.store.snapshot())

    # ----------------------------------------------------------- epoching
    @property
    def index_epoch_store(self):
        return self.live.store

    @property
    def index_epoch(self) -> int:
        return self.live.epoch

    # ------------------------------------------------------------- writes
    def add_documents(self, docs, static_rank=None) -> List[int]:
        return self.live.add_documents(docs, static_rank)

    def add_document(self, fields, static_rank: float = 0.0) -> int:
        return self.live.add_document(fields, static_rank)

    def update_document(self, doc_id: int, fields) -> None:
        self.live.update_document(doc_id, fields)

    def commit_index(self) -> int:
        return self.live.commit()

    def merge_index(self) -> int:
        return self.live.merge()

    # ---------------------------------------------------------- query log
    def append_queries(self, term_lists: Sequence[Sequence[int]],
                       categories: Sequence[int],
                       judged_ids: Optional[Sequence[Sequence[int]]] = None,
                       judged_gains: Optional[Sequence[Sequence[int]]] = None,
                       popularity: Optional[Sequence[float]] = None
                       ) -> np.ndarray:
        """Append fresh queries to the log; returns their new qids.

        IDF for the new rows is computed against the live df at append
        time (body field), matching how the base log's idf was built.
        Appends replace whole arrays under a lock — existing rows keep
        their positions, so concurrent readers holding old references
        stay consistent.
        """
        n = len(term_lists)
        if n == 0:
            return np.empty(0, dtype=np.int64)
        with self._log_mu:
            log = self.log
            q0 = log.n_queries
            j_width = log.judged_ids.shape[1]

            terms = np.full((n, MAX_QUERY_TERMS), -1, np.int32)
            n_terms = np.zeros(n, np.int32)
            for i, ts in enumerate(term_lists):
                ts = np.asarray(ts, dtype=np.int32)[:MAX_QUERY_TERMS]
                terms[i, : len(ts)] = ts
                n_terms[i] = len(ts)
            cat = np.asarray(categories, dtype=np.int8)

            j_ids = np.full((n, j_width), -1, np.int32)
            j_gains = np.zeros((n, j_width), np.int8)
            if judged_ids is not None:
                for i, (ids, gains) in enumerate(zip(judged_ids,
                                                     judged_gains)):
                    ids = np.asarray(ids, np.int32)[:j_width]
                    j_ids[i, : len(ids)] = ids
                    j_gains[i, : len(ids)] = np.asarray(gains,
                                                        np.int8)[:len(ids)]
            seed_doc = np.where(j_ids[:, 0] >= 0, j_ids[:, 0],
                                0).astype(np.int32)

            pop_new = (np.asarray(popularity, np.float64)
                       if popularity is not None
                       else np.full(n, log.popularity.mean()))
            pop = np.concatenate([log.popularity, pop_new])
            pop = pop / pop.sum()

            head = self.live.store.snapshot().view
            idf_new = idf_for_terms(
                np.asarray(head.df[:, 2], dtype=np.float64),
                head.n_docs, terms)

            log.terms = np.concatenate([log.terms, terms])
            log.n_terms = np.concatenate([log.n_terms, n_terms])
            log.category = np.concatenate([log.category, cat])
            log.judged_ids = np.concatenate([log.judged_ids, j_ids])
            log.judged_gains = np.concatenate([log.judged_gains, j_gains])
            log.seed_doc = np.concatenate([log.seed_doc, seed_doc])
            log.popularity = pop
            self.idf_all = np.concatenate([self.idf_all, idf_new])
            return np.arange(q0, q0 + n, dtype=np.int64)
