"""Tiered live index: mmap base + delta segments, epoch-versioned
hot-swap, background merge.  See docs/index.md.

`LiveRetrievalSystem` is exported lazily (PEP 562): it pulls in
`repro.system` — which itself imports `repro.index` — so an eager
import here would be circular.
"""
from .live_index import (IndexEpoch, IndexEpochStore, IndexView, LiveIndex,
                         StaleIndexEpochError)
from .merge import MergeConfig, MergeDaemon
from .parity import ParityError, check_epoch_parity, rebuild_index
from .segments import BaseSegment, DeltaOp, DeltaSegment

__all__ = ["BaseSegment", "DeltaOp", "DeltaSegment", "IndexEpoch",
           "IndexEpochStore", "IndexView", "LiveIndex",
           "LiveRetrievalSystem", "MergeConfig", "MergeDaemon",
           "ParityError", "StaleIndexEpochError", "check_epoch_parity",
           "rebuild_index"]


def __getattr__(name):
    if name == "LiveRetrievalSystem":
        from .system import LiveRetrievalSystem
        return LiveRetrievalSystem
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
