"""`LiveIndex`: epoch-versioned tiered index that serves while it mutates.

One writer mutates (buffered adds/updates → ``commit`` → merge), many
readers serve: every visible mutation is published as an immutable
:class:`IndexEpoch` — (version, base generation, :class:`IndexView`) —
through :class:`IndexEpochStore`, the same version/staleness/subscribe
machinery policy snapshots use (`repro.core.versioned.VersionedStore`).
Readers pin an epoch and periodically refresh; they never see torn
state, and a pinned view keeps working after any number of later
commits or merges (old base generations stay mapped).

Capacity is FIXED at construction: the occupancy tensor always spans
``capacity_blocks`` blocks, so every AOT-compiled rollout executable
keeps its shapes across epochs — an epoch swap costs zero retraces.
Blocks past the current doc count are all-zero planes; both scan
backends treat them identically, which is what makes live-vs-rebuild
parity exact *at equal capacity* (docs/index.md).
"""
from __future__ import annotations

import pickle
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.versioned import StaleVersionError, VersionedStore
from repro.index.blocks import pack_bits, words_per_block
from repro.index.builder import (InvertedIndex, MAX_QUERY_TERMS,
                                 build_index_from_pairs)
from repro.index.corpus import N_FIELDS
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer

from .segments import BaseSegment, DeltaOp, DeltaSegment, _canon_fields

__all__ = ["IndexEpoch", "IndexEpochStore", "IndexView", "LiveIndex",
           "StaleIndexEpochError", "MERGE_MS_EDGES", "OPLOG_NAME"]

#: Op-log checkpoint file, written next to the generation dirs.
OPLOG_NAME = "oplog.ckpt"

# Merge wall-time histogram buckets (ms): spans tiny test merges to
# multi-second 1M-doc compactions.
MERGE_MS_EDGES = (5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                  1000.0, 2500.0, 5000.0, 15000.0, 60000.0)


class StaleIndexEpochError(StaleVersionError):
    """A consumer's pinned index epoch is older than the staleness bound."""


class IndexView:
    """Immutable read view over (base generation + delta) at one epoch.

    Doc ids are positions in the *logical corpus* (base order, then
    appends), identical to what a from-scratch ``build_index`` of the
    same docs would assign — the invariant the parity harness pins.
    """

    def __init__(self, base: BaseSegment, delta: DeltaSegment,
                 capacity_docs: int,
                 account: Optional[Callable[[int, int], None]] = None):
        bd = base.index.block_docs
        if capacity_docs % bd != 0:
            raise ValueError(
                f"capacity_docs {capacity_docs} not a multiple of "
                f"block_docs {bd}")
        self.base = base
        self.delta = delta
        self.block_docs = bd
        self.capacity_docs = capacity_docs
        self.capacity_blocks = capacity_docs // bd
        self.words = words_per_block(bd)
        self.n_docs = base.n_docs + delta.n_new_docs
        if self.n_docs > capacity_docs:
            raise ValueError(f"{self.n_docs} docs exceed capacity "
                             f"{capacity_docs}")
        self.vocab_size = base.index.vocab_size
        self._account = account

    # ---------------------------------------------------------- postings
    def postings(self, term: int, field: int) -> np.ndarray:
        """Merged (base minus tombstones, plus delta) doc ids,
        ascending — bit-identical to a rebuilt index's posting list."""
        ids = self.base.index.postings(int(term), field)
        if self.delta.tombstones.size:
            ids = ids[~self.delta.tomb_mask[ids]]
        d_ids = self.delta.postings(int(term), field)
        if not d_ids.size:
            return np.asarray(ids, dtype=np.int32)
        return np.sort(np.concatenate(
            [np.asarray(ids, dtype=np.int32), d_ids]))

    @property
    def df(self) -> np.ndarray:
        """Live document frequencies (vocab, n_fields)."""
        return self.delta.df

    def static_rank(self) -> np.ndarray:
        return np.concatenate([np.asarray(self.base.index.static_rank),
                               self.delta.static_rank_new])

    def doc_len(self) -> np.ndarray:
        dl = np.array(self.base.index.doc_len, dtype=np.int32, copy=True)
        for d, row in self.delta.updated_doc_len.items():
            dl[d] = row
        if self.delta.n_new_docs:
            dl = np.concatenate([dl, self.delta.doc_len_new])
        return dl

    def doc_terms(self, doc_id: int, field: int) -> np.ndarray:
        cur = self.delta.doc_fields.get(int(doc_id))
        if cur is not None:
            return cur[field]
        return np.asarray(self.base.doc_terms(doc_id, field))

    def logical_field_terms(self) -> List[List[np.ndarray]]:
        """Per-field per-doc term arrays of the logical corpus — the
        input a from-scratch parity rebuild feeds ``build_index``."""
        return [[self.doc_terms(d, f) for d in range(self.n_docs)]
                for f in range(N_FIELDS)]

    # --------------------------------------------------------- occupancy
    def query_occupancy(self, terms: Sequence[int]) -> np.ndarray:
        """``occ[block, term, field, word]`` uint32 over the FIXED
        capacity: base planes (tombstones masked) unioned with delta
        planes.  Both scan backends consume the union unchanged, so
        candidates from either segment merge inside the ordinary
        block scan."""
        occ_bits = np.zeros((MAX_QUERY_TERMS, N_FIELDS, self.capacity_docs),
                            dtype=bool)
        base_bytes = delta_bytes = 0
        tomb = self.delta.tombstones.size > 0
        for t, term in enumerate(terms[:MAX_QUERY_TERMS]):
            for f in range(N_FIELDS):
                ids = self.base.index.postings(int(term), f)
                base_bytes += ids.nbytes
                if tomb:
                    ids = ids[~self.delta.tomb_mask[ids]]
                occ_bits[t, f, ids] = True
                d_ids = self.delta.postings(int(term), f)
                if d_ids.size:
                    delta_bytes += d_ids.nbytes
                    occ_bits[t, f, d_ids] = True
        if self._account is not None:
            self._account(base_bytes, delta_bytes)
        packed = pack_bits(occ_bits)          # (T, F, capacity/32)
        packed = packed.reshape(MAX_QUERY_TERMS, N_FIELDS,
                                self.capacity_blocks, self.words)
        return np.ascontiguousarray(packed.transpose(2, 0, 1, 3))

    def batch_query_occupancy(self,
                              term_lists: Sequence[Sequence[int]]) -> np.ndarray:
        return np.stack([self.query_occupancy(ts) for ts in term_lists])

    def describe(self) -> dict:
        return {"n_docs": self.n_docs, "capacity_docs": self.capacity_docs,
                "capacity_blocks": self.capacity_blocks,
                "base_generation": self.base.generation,
                "base_n_docs": self.base.n_docs,
                "delta": self.delta.describe()}


class IndexEpoch:
    """One published index version: readers pin it like a policy
    snapshot (immutable; ``version`` is the epoch id the result cache
    keys on, ``generation`` counts merges).

    ``ops`` is the committed delta op log the epoch's view was built
    from — the compact payload the process cell relays to worker
    processes, which mmap the base generation themselves and rebuild
    the (cheap, in-memory) delta from these ops."""

    __slots__ = ("version", "generation", "view", "ops")

    def __init__(self, version: int, generation: int, view: IndexView,
                 ops: Tuple[DeltaOp, ...] = ()):
        self.version = version
        self.generation = generation
        self.view = view
        self.ops = tuple(ops)

    def describe(self) -> dict:
        return {"version": self.version, "generation": self.generation,
                **self.view.describe()}


class IndexEpochStore(VersionedStore):
    """`VersionedStore` over :class:`IndexEpoch` — EVERY visible index
    mutation (delta commit or merge) bumps the epoch.

    ``version`` pins an explicit epoch id: the process cell's workers
    republish relayed epochs into their local store under the
    producer's numbering (gaps are legal — a respawned worker jumps
    straight to the head epoch it is sent)."""

    stale_error = StaleIndexEpochError
    artifact = "index epoch"

    def publish(self, view: IndexView, generation: int,
                ops: Sequence[DeltaOp] = (),
                version: Optional[int] = None) -> int:
        return self._publish_snapshot(
            lambda prev, ver: IndexEpoch(ver, generation, view, ops),
            version=version)


class LiveIndex:
    """Single-writer live index: buffered mutations, epoch publishes,
    background-mergeable compaction.

    ``add_document``/``update_document`` buffer ops (invisible to
    readers); ``commit`` publishes them as a new epoch; ``merge``
    compacts every committed delta op into a new base generation
    (written to ``storage_dir`` and memmapped back when given) and
    publishes that as the next epoch with an empty-or-residual delta.
    ``merge`` computes outside the writer lock, so adds keep landing —
    and serving never pauses — while a compaction runs.
    """

    def __init__(self, base, *, capacity_docs: Optional[int] = None,
                 staleness_bound: int = 64,
                 storage_dir=None, keep_generations: int = 2,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Tracer = NULL_TRACER):
        if isinstance(base, InvertedIndex):
            base = BaseSegment.from_index(base)
        bd = base.index.block_docs
        if capacity_docs is None:
            capacity_docs = 2 * max(base.index.padded_docs, bd)
        capacity_docs = ((capacity_docs + bd - 1) // bd) * bd
        if capacity_docs < base.index.padded_docs:
            raise ValueError("capacity_docs below the base segment")
        self.capacity_docs = capacity_docs
        self.capacity_blocks = capacity_docs // bd
        self.block_docs = bd
        self.storage_dir = Path(storage_dir) if storage_dir else None
        self.keep_generations = keep_generations
        self.tracer = tracer
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._c_added = r.counter("index.docs_added")
        self._c_updated = r.counter("index.docs_updated")
        self._c_commits = r.counter("index.commits")
        self._c_merges = r.counter("index.merges")
        self._c_bytes_base = r.counter("index.bytes", segment="base")
        self._c_bytes_delta = r.counter("index.bytes", segment="delta")
        self._c_queries = r.counter("index.plane_queries")
        self._g_delta = r.gauge("index.delta_docs")
        self._g_epoch = r.gauge("index.epoch")
        self._g_generation = r.gauge("index.generation")
        self._h_merge = r.histogram("index.merge_ms", MERGE_MS_EDGES)

        self._mu = threading.Lock()          # writer lock (ops + cutover)
        self._base = base
        self._ops: List[DeltaOp] = []        # committed-but-unmerged + pending
        self._n_committed = 0                # prefix of _ops already published
        self._next_doc = base.n_docs
        self.store = IndexEpochStore(staleness_bound=staleness_bound)
        if self.storage_dir and not base.path:
            self._base = base.save(self.storage_dir / "gen-00000")
        self._publish_locked(self._base, list(self._ops))

    # ------------------------------------------------------------ gauges
    @property
    def epoch(self) -> int:
        return self.store.version

    @property
    def generation(self) -> int:
        return self._base.generation

    @property
    def n_docs(self) -> int:
        """Docs visible at the head epoch (committed)."""
        return self.store.snapshot().view.n_docs

    @property
    def delta_docs(self) -> int:
        """Committed-but-unmerged docs owned by the delta tier."""
        return self.store.snapshot().view.delta.n_docs_owned

    @property
    def pending_ops(self) -> int:
        with self._mu:
            return len(self._ops) - self._n_committed

    def _account(self, base_bytes: int, delta_bytes: int) -> None:
        self._c_bytes_base.inc(base_bytes)
        self._c_bytes_delta.inc(delta_bytes)
        self._c_queries.inc()

    # ------------------------------------------------------------ writes
    def add_document(self, fields: Sequence[np.ndarray],
                     static_rank: float = 0.0) -> int:
        """Buffer one appended doc (next logical id — append-only ids
        keep rebuild parity); visible after ``commit``.  Fresh docs
        default to the bottom of the static-rank order, which is where
        news-like docs start out."""
        canon = _canon_fields(fields)
        with self._mu:
            if self._next_doc >= self.capacity_docs:
                raise ValueError(
                    f"capacity_docs={self.capacity_docs} exhausted; "
                    "merge into a larger generation or raise capacity")
            doc_id = self._next_doc
            self._next_doc += 1
            self._ops.append(DeltaOp("add", doc_id, canon,
                                     float(static_rank)))
        self._c_added.inc()
        return doc_id

    def add_documents(self, docs: Sequence[Sequence[np.ndarray]],
                      static_rank: Optional[Sequence[float]] = None) -> List[int]:
        ranks = (list(static_rank) if static_rank is not None
                 else [0.0] * len(docs))
        return [self.add_document(d, r) for d, r in zip(docs, ranks)]

    def update_document(self, doc_id: int,
                        fields: Sequence[np.ndarray]) -> None:
        """Buffer a full-document replacement (same id, new terms): the
        doc's old postings are tombstoned, the new ones served from the
        delta until the next merge folds them into the base."""
        canon = _canon_fields(fields)
        with self._mu:
            if not (0 <= doc_id < self._next_doc):
                raise IndexError(f"unknown doc {doc_id}")
            self._ops.append(DeltaOp("update", int(doc_id), canon))
        self._c_updated.inc()

    # ----------------------------------------------------------- publish
    def _publish_locked(self, base: BaseSegment,
                        ops: List[DeltaOp]) -> int:
        delta = DeltaSegment(base, ops)
        view = IndexView(base, delta, self.capacity_docs,
                         account=self._account)
        version = self.store.publish(view, base.generation, ops=ops)
        self._g_delta.set(delta.n_docs_owned)
        self._g_epoch.set(version)
        self._g_generation.set(base.generation)
        return version

    def commit(self) -> int:
        """Publish every buffered op as a new epoch (new delta segment,
        same base generation); returns the epoch version."""
        with self.tracer.span("index_commit") as span:
            with self._mu:
                ops = list(self._ops)
                self._n_committed = len(ops)
                version = self._publish_locked(self._base, ops)
            self._c_commits.inc()
            span.end(epoch=version, delta_ops=len(ops))
        return version

    # ------------------------------------------------------------- merge
    def merge(self) -> int:
        """Compact committed delta ops into a new base generation and
        publish it as the next epoch.  The heavy rebuild runs OUTSIDE
        the writer lock against immutable inputs; only the cutover
        (swap base, trim the op log, publish) takes the lock, so
        concurrent adds/updates are never blocked for long and land in
        the next generation's residual delta."""
        t0 = time.perf_counter()
        with self.tracer.span("index_merge") as span:
            with self._mu:
                base = self._base
                ops_at = list(self._ops[:self._n_committed])
                n_merged = len(ops_at)
            merged = self._compact(base, ops_at)          # heavy, unlocked
            if self.storage_dir:
                gen_dir = self.storage_dir / f"gen-{merged.generation:05d}"
                merged = merged.save(gen_dir)
            with self._mu:
                residual = self._ops[n_merged:]
                self._base = merged
                self._ops = residual
                self._n_committed = max(0, self._n_committed - n_merged)
                committed_residual = residual[:self._n_committed]
                version = self._publish_locked(merged, committed_residual)
            self._c_merges.inc()
            dt_ms = (time.perf_counter() - t0) * 1e3
            self._h_merge.record(dt_ms)
            self._gc_generations()
            # A merge changes which generation the op log is relative
            # to: an existing checkpoint must follow, or a crash after
            # the merge would leave a stale checkpoint whose residual
            # ops restore() has to discard.
            if (self.storage_dir
                    and (self.storage_dir / OPLOG_NAME).exists()):
                self.checkpoint()
            span.end(epoch=version, generation=merged.generation,
                     merged_ops=n_merged, ms=round(dt_ms, 2))
        return version

    # ----------------------------------------------------- checkpointing
    def checkpoint(self) -> Path:
        """Persist the op log (committed-but-unmerged AND pending ops —
        neither tier lives in any on-disk generation) next to the
        generation manifests; :meth:`restore` replays it after a
        restart.  Atomic: written to a temp file and renamed, so a crash
        mid-write leaves the previous checkpoint intact."""
        if not self.storage_dir:
            raise RuntimeError("checkpoint() needs a storage_dir")
        with self._mu:
            payload = pickle.dumps({
                "generation": self._base.generation,
                "n_committed": self._n_committed,
                "next_doc": self._next_doc,
                "ops": list(self._ops),
            }, protocol=4)
        path = self.storage_dir / OPLOG_NAME
        tmp = path.with_suffix(".tmp")
        tmp.write_bytes(payload)
        tmp.replace(path)
        return path

    @classmethod
    def restore(cls, storage_dir, **kwargs) -> "LiveIndex":
        """Reopen a live index from ``storage_dir``: load the newest
        base generation (mmapped) and replay the op-log checkpoint —
        committed ops are republished as an epoch (bit-parity with the
        never-crashed index's head view), pending ops wait for the next
        ``commit``.  A checkpoint written against an older generation
        than the newest on disk is stale (the crash hit between a merge
        and its checkpoint refresh) and is discarded."""
        storage_dir = Path(storage_dir)
        gens = sorted(storage_dir.glob("gen-*"))
        if not gens:
            raise FileNotFoundError(f"no gen-* under {storage_dir}")
        base = BaseSegment.load(gens[-1])
        li = cls(base, storage_dir=storage_dir, **kwargs)
        ckpt = storage_dir / OPLOG_NAME
        if not ckpt.exists():
            return li
        data = pickle.loads(ckpt.read_bytes())
        if data["generation"] != base.generation:
            return li                    # stale: ops already merged
        with li._mu:
            li._ops = list(data["ops"])
            li._n_committed = int(data["n_committed"])
            li._next_doc = int(data["next_doc"])
            if li._n_committed:
                li._publish_locked(li._base,
                                   li._ops[: li._n_committed])
        return li

    @staticmethod
    def _compact(base: BaseSegment, ops: List[DeltaOp]) -> BaseSegment:
        """Vectorized postings merge: (base pairs minus tombstones) +
        delta pairs, re-sorted into canonical CSR — bit-identical to a
        from-scratch build of the logical corpus (parity harness)."""
        delta = DeltaSegment(base, ops)
        vocab = base.index.vocab_size
        n_docs = base.n_docs + delta.n_new_docs
        pair_docs, pair_terms = [], []
        own = sorted(delta.doc_fields)
        for f in range(N_FIELDS):
            b_docs = np.asarray(base.index.doc_ids[f], dtype=np.int64)
            b_terms = np.repeat(np.arange(vocab, dtype=np.int64),
                                np.diff(base.index.indptr[f]))
            if delta.tombstones.size:
                keep = ~delta.tomb_mask[b_docs]
                b_docs, b_terms = b_docs[keep], b_terms[keep]
            d_docs = [np.full(len(delta.doc_fields[d][f]), d, np.int64)
                      for d in own]
            d_terms = [np.asarray(delta.doc_fields[d][f], np.int64)
                       for d in own]
            pair_docs.append(np.concatenate([b_docs] + d_docs)
                             if d_docs else b_docs)
            pair_terms.append(np.concatenate([b_terms] + d_terms)
                              if d_terms else b_terms)
        static_rank = np.concatenate(
            [np.asarray(base.index.static_rank), delta.static_rank_new])
        idx = build_index_from_pairs(
            pair_docs, pair_terms, n_docs=n_docs, vocab_size=vocab,
            static_rank=static_rank, block_docs=base.index.block_docs,
            dedup=True)
        return BaseSegment.from_index(idx, generation=base.generation + 1)

    def _gc_generations(self) -> None:
        """Drop generation dirs beyond ``keep_generations`` (open
        memmaps of pinned views keep working — the inode outlives the
        directory entry)."""
        if not self.storage_dir:
            return
        gens = sorted(self.storage_dir.glob("gen-*"))
        for d in gens[:-self.keep_generations]:
            for p in d.iterdir():
                p.unlink(missing_ok=True)
            d.rmdir()

    # -------------------------------------------------------------- info
    def stats(self) -> dict:
        head = self.store.snapshot()
        q = max(1, self._c_queries.value)
        return {
            "epoch": head.version,
            "generation": head.generation,
            "n_docs": head.view.n_docs,
            "capacity_docs": self.capacity_docs,
            "capacity_blocks": self.capacity_blocks,
            "delta_docs": head.view.delta.n_docs_owned,
            "pending_ops": self.pending_ops,
            "docs_added": self._c_added.value,
            "docs_updated": self._c_updated.value,
            "commits": self._c_commits.value,
            "merges": self._c_merges.value,
            "base_mmapped": self._base.mmapped,
            "base_nbytes": self._base.nbytes,
            "bytes_per_query_base": self._c_bytes_base.value / q,
            "bytes_per_query_delta": self._c_bytes_delta.value / q,
        }
