"""SLO burn-rate monitor: multi-window error-budget burn as a pure
fold over merged metrics snapshots.

The SLI is ticket-level goodness: a ticket is **good** when it was
served at or under the latency threshold, **bad** when it was served
slower or shed.  Both signals already live in every fleet snapshot —
the per-(level, category) ``serve.latency_ms`` histograms and the
``cluster.shed{where=...}`` counters — so the monitor never touches
the serving path: feed it ``ReplicaSet.metrics_snapshot()`` outputs
and it differences them over time.

Window math (the standard multi-window burn-rate alert, Google
SRE-workbook shape): with error budget ``1 - target``,

    burn(w) = error_rate_over_last_w / (1 - target)

burn 1.0 spends the budget exactly over the SLO period; a **fast**
window (minutes) catches cliffs, a **slow** window (the fast one ×10
by default) suppresses blips.  ``check()`` pages only when BOTH
windows burn past ``page_burn`` — a cliff sustained long enough to
matter — and warns when either exceeds ``warn_burn``.

The latency threshold is snapped UP to the nearest histogram edge
(fixed 1-2-5 decade edges, ``LATENCY_MS_EDGES``), because bucket
counts can only answer "how many were ≤ this edge"; the snapped value
is reported back as ``effective_latency_slo_ms``.

Verdicts ride the registry as ``slo.*`` gauges so they merge/export
like everything else; the future admission controller subscribes to
``check()`` — this PR wires it read-only into
``repro.launch.cluster --slo-target``.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from collections import deque
from typing import Deque, Optional, Tuple

__all__ = ["SLOConfig", "SLOMonitor", "fold_snapshot"]


def fold_snapshot(snap: dict, latency_slo_ms: float) -> dict:
    """Fold one merged metrics snapshot into SLI totals.

    Returns ``{"total", "good", "bad", "served", "slow", "shed",
    "effective_latency_slo_ms"}`` — cumulative since the fleet
    started, monotone between snapshots of a live registry (which is
    what lets the monitor difference them into windows).
    """
    served = slow = 0
    eff = float(latency_slo_ms)
    for key, m in snap.items():
        if not key.startswith("serve.latency_ms"):
            continue
        if m.get("type") != "histogram":
            continue
        edges = m["edges"]
        counts = m["counts"]
        served += m["count"]
        # Buckets hold (edges[i-1], edges[i]]: snapping the threshold
        # up to edges[k] makes "good" exactly counts[:k+1].
        k = bisect.bisect_left(edges, float(latency_slo_ms))
        if k < len(edges):
            eff = float(edges[k])
            slow += sum(counts[k + 1:])
        # threshold above every finite edge: even overflow counts good
    shed = sum(m["value"] for key, m in snap.items()
               if key.startswith("cluster.shed")
               and m.get("type") == "counter")
    return {"total": served + shed, "good": served - slow,
            "bad": slow + shed, "served": served, "slow": slow,
            "shed": shed, "effective_latency_slo_ms": eff}


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    target: float = 0.999              # fraction of tickets that must be good
    latency_slo_ms: float = 50.0       # served slower than this = bad
    fast_window_s: float = 60.0
    slow_window_s: float = 600.0
    warn_burn: float = 2.0             # either window past this -> warn
    page_burn: float = 10.0            # BOTH windows past this -> page
    max_samples: int = 4096            # bounded sample ring

    def __post_init__(self):
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"target must be in (0, 1), got {self.target}")
        if self.fast_window_s >= self.slow_window_s:
            raise ValueError("fast window must be shorter than slow")


class SLOMonitor:
    """Rolling burn-rate monitor over snapshot observations.

    Not thread-safe by design — one monitoring loop owns it (the
    registry gauges it publishes ARE safe to read concurrently).
    """

    def __init__(self, cfg: SLOConfig = SLOConfig(), registry=None,
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        # (t, total, bad) samples, oldest first, spanning >= slow window
        self._samples: Deque[Tuple[float, int, int]] = deque(
            maxlen=cfg.max_samples)
        self._last_fold: dict = {}
        self._gauges = {}
        if registry is not None:
            self._gauges = {
                ("burn", "fast"): registry.gauge("slo.burn_rate",
                                                 window="fast"),
                ("burn", "slow"): registry.gauge("slo.burn_rate",
                                                 window="slow"),
                ("err", "fast"): registry.gauge("slo.error_rate",
                                                window="fast"),
                ("err", "slow"): registry.gauge("slo.error_rate",
                                                window="slow"),
            }

    @property
    def budget(self) -> float:
        return 1.0 - self.cfg.target

    def observe(self, snap: dict, t: Optional[float] = None) -> dict:
        """Fold one fleet snapshot in; returns the cumulative fold."""
        fold = fold_snapshot(snap, self.cfg.latency_slo_ms)
        self._last_fold = fold
        self._samples.append((self.clock() if t is None else float(t),
                              fold["total"], fold["bad"]))
        if self._gauges:
            v = self.check()
            self._gauges["burn", "fast"].set(v["burn_fast"])
            self._gauges["burn", "slow"].set(v["burn_slow"])
            self._gauges["err", "fast"].set(v["error_rate_fast"])
            self._gauges["err", "slow"].set(v["error_rate_slow"])
        return fold

    def _window_rate(self, window_s: float) -> float:
        """Error rate over the last ``window_s``: difference the newest
        sample against the oldest one still inside the window (or the
        oldest we have — early in a run every window sees the whole
        history, which is the honest answer)."""
        if len(self._samples) < 1:
            return 0.0
        t_now, total_now, bad_now = self._samples[-1]
        base = self._samples[0]
        for s in self._samples:
            if s[0] >= t_now - window_s:
                break
            base = s
        d_total = total_now - base[1]
        d_bad = bad_now - base[2]
        if d_total <= 0:
            return 0.0
        return d_bad / d_total

    def burn_rate(self, window_s: float) -> float:
        return self._window_rate(window_s) / self.budget

    def check(self) -> dict:
        """Multi-window verdict: ``ok`` / ``warn`` / ``page``."""
        cfg = self.cfg
        err_fast = self._window_rate(cfg.fast_window_s)
        err_slow = self._window_rate(cfg.slow_window_s)
        burn_fast = err_fast / self.budget
        burn_slow = err_slow / self.budget
        if burn_fast >= cfg.page_burn and burn_slow >= cfg.page_burn:
            verdict = "page"
        elif burn_fast >= cfg.warn_burn or burn_slow >= cfg.warn_burn:
            verdict = "warn"
        else:
            verdict = "ok"
        return {
            "verdict": verdict,
            "target": cfg.target,
            "latency_slo_ms": cfg.latency_slo_ms,
            "effective_latency_slo_ms": self._last_fold.get(
                "effective_latency_slo_ms", cfg.latency_slo_ms),
            "budget": self.budget,
            "burn_fast": burn_fast,
            "burn_slow": burn_slow,
            "error_rate_fast": err_fast,
            "error_rate_slow": err_slow,
            "warn_burn": cfg.warn_burn,
            "page_burn": cfg.page_burn,
            **{k: self._last_fold.get(k, 0)
               for k in ("total", "good", "bad", "served", "slow", "shed")},
        }
