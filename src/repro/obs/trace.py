"""Ticket-scoped tracing: spans from admission to kernel, exportable
to Perfetto.

A query's latency is spent across four threads (submitter → router →
replica worker → batch execution) and none of the per-layer summaries
can say *where*: queue wait, batch assembly, compile, or the scan
itself.  The tracer records that path as a tree of :class:`Span`s
propagated on the cluster ticket:

    ticket #17 qid=42            ──────────────────────────────
      admit                      ─
      queue                       ───────
      batch                              ──
      execute                              ────────
      respond                                      ─

Each ticket owns its own *track* (Perfetto row), so concurrent tickets
never interleave B/E events; thread-level work (micro-batches, compiles,
trainer epochs / eval gates / publishes, tap draws) lands on the owning
thread's track.  Everything shares one clock (`time.perf_counter`), so
a snapshot hot-swap on the trainer track is visually aligned with the
requests it flushes on the ticket tracks.

Cost model: tracing is **off by default** — a disabled tracer returns
the ``NULL_SPAN`` singleton from every call, so instrumentation costs
one attribute check per site.  Enabled, spans are plain ``__slots__``
objects appended to a bounded ring (:class:`TraceLog`) *when they end*;
nothing is serialized until :meth:`TraceLog.export_chrome`.

Export is the Chrome trace-event JSON flavor Perfetto loads directly
(``ui.perfetto.dev`` → Open trace file): sorted, matched B/E duration
events plus ``i`` instants, with per-track ``thread_name`` metadata.
Ring eviction drops oldest-ended spans first; because a parent always
ends after its children, eviction can orphan a surviving span's
``parent_id`` — :meth:`TraceLog.snapshot` re-roots those instead of
exporting dangling ids.

Multi-process cells merge several logs into one timeline: each worker
ships entry deltas (:meth:`TraceLog.drain_since`) over its control
pipe, the parent rebases them onto its own clock and id space
(:func:`adjust_remote_entries` — the offset comes from a ping handshake
at worker startup), and :func:`export_chrome_entries` namespaces tracks
by (pid, track) so worker threads from different processes never share
a tid.  Entries whose track is a ticket track (``ticket #<id>``) keep
the parent's pid — the worker-side execute/respond spans land on the
SAME Perfetto row as the parent's admit/ring spans.  Residual
clock-offset error is absorbed at export by clamping a shipped span to
the bounds of the span that encloses it on its track, so B/E stacks
nest by construction no matter how skewed the estimate was.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Span", "TraceLog", "Tracer", "NULL_SPAN", "NULL_TRACER",
           "adjust_remote_entries", "export_chrome_entries",
           "write_chrome_entries"]

#: Track-name prefix of per-ticket rows (``Tracer.root_span("ticket")``
#: makes ``ticket #<id>``); merged remote entries on these tracks join
#: the parent process's row instead of opening a per-worker one.
TICKET_TRACK_PREFIX = "ticket #"


class Span:
    """One timed operation.  Create via ``Tracer.span``/``root_span``
    or ``Span.child``; close with :meth:`end` (or use as a context
    manager).  The record enters the trace ring only at ``end``."""

    __slots__ = ("_tracer", "name", "track", "span_id", "parent_id",
                 "t0", "t1", "args")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 span_id: int, parent_id: Optional[int], t0: float,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args

    def __bool__(self) -> bool:
        return True

    def child(self, name: str, **args) -> "Span":
        """A child span on this span's track, starting now."""
        return self._tracer.span(name, track=self.track,
                                 parent=self, **args)

    def child_at(self, name: str, t0: float, t1: float, **args) -> "Span":
        """A retroactive, already-finished child for work whose
        boundaries were measured before the span objects existed
        (per-lane views of a batch execution)."""
        return self._tracer.span_at(name, t0, t1, track=self.track,
                                    parent=self, **args)

    def instant(self, name: str, **args) -> None:
        self._tracer.instant(name, track=self.track, parent=self, **args)

    def end(self, t1: Optional[float] = None, **args) -> None:
        if self.t1 is not None:        # double-end: keep the first
            return
        self.t1 = self._tracer.clock() if t1 is None else t1
        if args:
            self.args = {**(self.args or {}), **args}
        self._tracer.log.append_span(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        self.end(error=exc_type.__name__) if exc_type else self.end()


class _NullSpan:
    """Inert stand-in returned by a disabled tracer: every method
    no-ops, children are itself, truthiness is False so callers can
    gate optional work with ``if span:``."""

    __slots__ = ()
    name = track = ""
    span_id = parent_id = t0 = t1 = args = None

    def __bool__(self) -> bool:
        return False

    def child(self, name, **args) -> "_NullSpan":
        return self

    def child_at(self, name, t0, t1, **args) -> "_NullSpan":
        return self

    def instant(self, name, **args) -> None:
        pass

    def end(self, t1=None, **args) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class TraceLog:
    """Bounded ring of finished spans + instant events.

    Entries are appended in end-time order, so eviction drops the
    oldest-*ended* work first; a parent (which ends after its children)
    therefore always outlives its children in the ring, and the only
    dangling edge eviction can create is a surviving span whose
    ``parent_id`` left the ring — ``snapshot()`` re-roots those.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self.n_recorded = 0

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def n_evicted(self) -> int:
        return self.n_recorded - len(self._ring)

    def append_span(self, span: Span) -> None:
        with self._lock:
            self._ring.append(("span", span.name, span.track, span.span_id,
                               span.parent_id, span.t0, span.t1, span.args))
            self.n_recorded += 1

    def append_instant(self, name: str, track: str, t: float,
                       parent_id: Optional[int], args: Optional[dict]) -> None:
        with self._lock:
            self._ring.append(("instant", name, track, None, parent_id,
                               t, t, args))
            self.n_recorded += 1

    # ---------------------------------------------------------- export
    def snapshot(self) -> List[dict]:
        """Finished entries as dicts, oldest first, with parent ids
        that left the ring re-rooted to None (no dangling references
        survive into an export)."""
        with self._lock:
            entries = list(self._ring)
        live = {e[3] for e in entries if e[3] is not None}
        return [{"kind": kind, "name": name, "track": track, "id": sid,
                 "parent": parent if parent in live else None,
                 "t0": t0, "t1": t1, "args": args}
                for kind, name, track, sid, parent, t0, t1, args in entries]

    def drain_since(self, cursor: int) -> Tuple[List[dict], int]:
        """Entries recorded after ``cursor`` (a previous return's new
        cursor; 0 for everything), as snapshot-shaped dicts.  The
        worker→parent shipping primitive: each control-pipe stats reply
        carries only the delta, and entries the ring already evicted
        are silently skipped (the parent's tail is best-effort by
        design).  Parent ids are NOT re-rooted here — earlier deltas
        may hold the parent; the exporter re-roots whatever is still
        dangling at merge time."""
        with self._lock:
            total = self.n_recorded
            ring = list(self._ring)
        start = max(int(cursor), total - len(ring))
        entries = ring[len(ring) - (total - start):] if start < total else []
        return ([{"kind": kind, "name": name, "track": track, "id": sid,
                  "parent": parent, "t0": t0, "t1": t1, "args": args}
                 for kind, name, track, sid, parent, t0, t1, args in entries],
                total)

    def export_chrome(self, process_name: str = "repro") -> dict:
        """Chrome trace-event JSON (Perfetto-loadable) of this log's
        entries — see :func:`export_chrome_entries`."""
        return export_chrome_entries(self.snapshot(),
                                     process_name=process_name)

    def write_chrome(self, path, process_name: str = "repro") -> None:
        write_chrome_entries(path, self.snapshot(),
                             process_name=process_name)


# ---------------------------------------------------------------- merge
def adjust_remote_entries(entries: Iterable[dict], *, dt: float = 0.0,
                          id_offset: int = 0, pid: Optional[int] = None,
                          ticket_args: Optional[dict] = None) -> List[dict]:
    """Rebase another process's trace entries into the local timeline.

    - ``dt`` shifts every timestamp onto the local clock (local ≈
      remote + dt, estimated from the ping handshake's min-RTT sample);
    - ``id_offset`` moves span/parent ids into a per-worker range so
      two processes' independent id counters can't collide;
    - entries on ticket tracks (``ticket #<id>``) stay pid-less — they
      join the parent's Perfetto row under the parent-side ring span —
      and pick up ``ticket_args`` (e.g. ``{"wpid": 1234}``) so the
      chain checker can count worker pids; every other track is stamped
      with ``pid`` and becomes its own (pid, track) row at export.
    """
    out = []
    for e in entries:
        e = dict(e)
        e["t0"] = e["t0"] + dt
        if e["t1"] is not None:
            e["t1"] = e["t1"] + dt
        if e["id"] is not None:
            e["id"] = e["id"] + id_offset
        if e["parent"] is not None:
            e["parent"] = e["parent"] + id_offset
        if e["track"].startswith(TICKET_TRACK_PREFIX):
            if ticket_args:
                e["args"] = {**(e["args"] or {}), **ticket_args}
        elif pid is not None:
            e["pid"] = pid
        out.append(e)
    return out


def _clamp_nesting(entries: List[dict]) -> None:
    """Clamp partially-overlapping spans per (pid, track) so B/E events
    nest.  Cross-process spans are aligned by an *estimated* clock
    offset; the residual error can push a shipped span past the bounds
    of the span that logically encloses it.  Snapping the child into
    the enclosing span's window keeps every track a proper tree without
    reordering — the invariant check_trace.py asserts.

    Each span is also stamped with its stack depth (``_depth``).
    Clamping routinely makes a child share its parent's exact boundary,
    and at equal timestamps only containment can order the B/E events —
    the exporter breaks those ties with the depth (deepest E closes
    first, shallowest B opens first)."""
    by_track: Dict[tuple, List[dict]] = {}
    for e in entries:
        if e["kind"] == "span":
            by_track.setdefault((e.get("pid"), e["track"]), []).append(e)
    for spans in by_track.values():
        # At equal t0 the longer span is the parent; it must sort first.
        spans.sort(key=lambda e: (e["t0"], -(e["t1"] - e["t0"])))
        stack: List[dict] = []
        for e in spans:
            while stack and stack[-1]["t1"] <= e["t0"]:
                stack.pop()
            if stack:
                top = stack[-1]
                if e["t0"] < top["t0"]:
                    e["t0"] = top["t0"]
                if e["t1"] > top["t1"]:
                    e["t1"] = top["t1"]
                if e["t1"] < e["t0"]:
                    e["t1"] = e["t0"]
            e["_depth"] = len(stack)
            stack.append(e)


def export_chrome_entries(entries: Iterable[dict],
                          process_name: str = "repro",
                          pid_names: Optional[Dict[int, str]] = None) -> dict:
    """Chrome trace-event JSON (Perfetto-loadable) from snapshot-shaped
    entries, possibly merged from several processes.

    Every span becomes a matched B/E pair; instants become ``i``
    events.  Events are sorted by timestamp with closes before opens at
    equal ts, so per-tid B/E stacks nest by construction.  Timestamps
    are µs from the earliest entry.

    Entries may carry an optional ``pid`` (absent/None = the exporting
    process, emitted as pid 1).  Tids are assigned per **(pid, track)**
    — worker threads from different processes never share a tid even
    when their thread names collide — and each pid gets its own
    ``process_name`` metadata row (``pid_names`` overrides the default
    ``<process_name>/pid <pid>`` label)."""
    entries = [dict(e) if e["kind"] == "span" else e for e in entries]
    _clamp_nesting(entries)
    # Deltas shipped ring-by-ring can strand a parent id whose entry
    # was evicted remotely — re-root those like TraceLog.snapshot does.
    live = {e["id"] for e in entries if e["id"] is not None}

    tids: Dict[tuple, int] = {}
    for e in entries:
        tids.setdefault((e.get("pid"), e["track"]), len(tids) + 1)
    t_min = min((e["t0"] for e in entries), default=0.0)
    us = lambda t: (t - t_min) * 1e6

    events = []
    # priority orders equal-ts events: E closes before i, i before
    # B opens — adjacent spans sharing a boundary still nest.  Within a
    # priority class, clamp depth breaks the tie: a clamped child shares
    # its parent's exact boundary, where only containment can order the
    # events — the deepest E closes first, the shallowest B opens first.
    for e in entries:
        pid = e.get("pid") or 1
        tid = tids[(e.get("pid"), e["track"])]
        args = e["args"] or {}
        depth = e.get("_depth", 0)
        if e["parent"] is not None and e["parent"] in live:
            args = {**args, "parent_span": e["parent"]}
        if e["kind"] == "instant":
            events.append((us(e["t0"]), 1, 0, {
                "name": e["name"], "ph": "i", "s": "t",
                "ts": us(e["t0"]), "pid": pid, "tid": tid, "args": args}))
        else:
            common = {"name": e["name"], "pid": pid, "tid": tid}
            if e["id"] is not None:
                args = {**args, "span_id": e["id"]}
            events.append((us(e["t0"]), 2, depth, {
                **common, "ph": "B", "ts": us(e["t0"]), "args": args}))
            events.append((us(e["t1"]), 0, -depth, {
                **common, "ph": "E", "ts": us(e["t1"])}))
    events.sort(key=lambda ev: ev[:3])

    pids = sorted({p for p, _track in tids}, key=lambda p: (p is not None, p))
    meta = []
    for p in pids:
        emitted = p or 1
        name = (process_name if p is None
                else (pid_names or {}).get(p, f"{process_name}/pid {p}"))
        meta.append({"name": "process_name", "ph": "M", "ts": 0,
                     "pid": emitted, "tid": 0, "args": {"name": name}})
    for (p, track), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        emitted = p or 1
        meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                     "pid": emitted, "tid": tid, "args": {"name": track}})
        meta.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                     "pid": emitted, "tid": tid,
                     "args": {"sort_index": tid}})
    return {"traceEvents": meta + [ev for *_, ev in events],
            "displayTimeUnit": "ms"}


def write_chrome_entries(path, entries: Iterable[dict],
                         process_name: str = "repro",
                         pid_names: Optional[Dict[int, str]] = None) -> None:
    from pathlib import Path
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(export_chrome_entries(
        entries, process_name=process_name, pid_names=pid_names)))


class Tracer:
    """Span factory over one :class:`TraceLog` and one clock.

    ``enabled=False`` (the serving default) makes every factory method
    return :data:`NULL_SPAN` / no-op after a single attribute check —
    the off-path cost the serve_bench obs-overhead section pins down.
    """

    def __init__(self, log: Optional[TraceLog] = None, enabled: bool = True,
                 clock=time.perf_counter):
        self.log = log if log is not None else TraceLog()
        self.enabled = bool(enabled)
        self.clock = clock
        self._ids = itertools.count(1)

    @staticmethod
    def _track(track: Optional[str], parent: Optional[Span]) -> str:
        if track is not None:
            return track
        if parent is not None and parent.track:
            return parent.track
        return threading.current_thread().name

    def span(self, name: str, track: Optional[str] = None,
             parent: Optional[Span] = None, **args) -> Span:
        if not self.enabled:
            return NULL_SPAN
        parent = parent or None       # NULL_SPAN parents read as None
        return Span(self, name, self._track(track, parent),
                    next(self._ids),
                    parent.span_id if parent else None,
                    self.clock(), args or None)

    def span_at(self, name: str, t0: float, t1: float,
                track: Optional[str] = None, parent: Optional[Span] = None,
                **args) -> Span:
        """Record an already-finished span from measured boundaries."""
        if not self.enabled:
            return NULL_SPAN
        parent = parent or None
        s = Span(self, name, self._track(track, parent), next(self._ids),
                 parent.span_id if parent else None, t0, args or None)
        s.end(t1=t1)
        return s

    def root_span(self, name: str, **args) -> Span:
        """A span opening its own unique track — one Perfetto row per
        ticket, so concurrent tickets never interleave B/E events."""
        if not self.enabled:
            return NULL_SPAN
        span_id = next(self._ids)
        return Span(self, name, f"{name} #{span_id}", span_id, None,
                    self.clock(), args or None)

    def instant(self, name: str, track: Optional[str] = None,
                parent: Optional[Span] = None, **args) -> None:
        if not self.enabled:
            return
        parent = parent or None
        self.log.append_instant(name, self._track(track, parent),
                                self.clock(),
                                parent.span_id if parent else None,
                                args or None)


#: Shared disabled tracer — the default everywhere a tracer is optional.
NULL_TRACER = Tracer(log=TraceLog(capacity=1), enabled=False)
