"""Ticket-scoped tracing: spans from admission to kernel, exportable
to Perfetto.

A query's latency is spent across four threads (submitter → router →
replica worker → batch execution) and none of the per-layer summaries
can say *where*: queue wait, batch assembly, compile, or the scan
itself.  The tracer records that path as a tree of :class:`Span`s
propagated on the cluster ticket:

    ticket #17 qid=42            ──────────────────────────────
      admit                      ─
      queue                       ───────
      batch                              ──
      execute                              ────────
      respond                                      ─

Each ticket owns its own *track* (Perfetto row), so concurrent tickets
never interleave B/E events; thread-level work (micro-batches, compiles,
trainer epochs / eval gates / publishes, tap draws) lands on the owning
thread's track.  Everything shares one clock (`time.perf_counter`), so
a snapshot hot-swap on the trainer track is visually aligned with the
requests it flushes on the ticket tracks.

Cost model: tracing is **off by default** — a disabled tracer returns
the ``NULL_SPAN`` singleton from every call, so instrumentation costs
one attribute check per site.  Enabled, spans are plain ``__slots__``
objects appended to a bounded ring (:class:`TraceLog`) *when they end*;
nothing is serialized until :meth:`TraceLog.export_chrome`.

Export is the Chrome trace-event JSON flavor Perfetto loads directly
(``ui.perfetto.dev`` → Open trace file): sorted, matched B/E duration
events plus ``i`` instants, with per-track ``thread_name`` metadata.
Ring eviction drops oldest-ended spans first; because a parent always
ends after its children, eviction can orphan a surviving span's
``parent_id`` — :meth:`TraceLog.snapshot` re-roots those instead of
exporting dangling ids.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "TraceLog", "Tracer", "NULL_SPAN", "NULL_TRACER"]


class Span:
    """One timed operation.  Create via ``Tracer.span``/``root_span``
    or ``Span.child``; close with :meth:`end` (or use as a context
    manager).  The record enters the trace ring only at ``end``."""

    __slots__ = ("_tracer", "name", "track", "span_id", "parent_id",
                 "t0", "t1", "args")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 span_id: int, parent_id: Optional[int], t0: float,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args

    def __bool__(self) -> bool:
        return True

    def child(self, name: str, **args) -> "Span":
        """A child span on this span's track, starting now."""
        return self._tracer.span(name, track=self.track,
                                 parent=self, **args)

    def child_at(self, name: str, t0: float, t1: float, **args) -> "Span":
        """A retroactive, already-finished child for work whose
        boundaries were measured before the span objects existed
        (per-lane views of a batch execution)."""
        return self._tracer.span_at(name, t0, t1, track=self.track,
                                    parent=self, **args)

    def instant(self, name: str, **args) -> None:
        self._tracer.instant(name, track=self.track, parent=self, **args)

    def end(self, t1: Optional[float] = None, **args) -> None:
        if self.t1 is not None:        # double-end: keep the first
            return
        self.t1 = self._tracer.clock() if t1 is None else t1
        if args:
            self.args = {**(self.args or {}), **args}
        self._tracer.log.append_span(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        self.end(error=exc_type.__name__) if exc_type else self.end()


class _NullSpan:
    """Inert stand-in returned by a disabled tracer: every method
    no-ops, children are itself, truthiness is False so callers can
    gate optional work with ``if span:``."""

    __slots__ = ()
    name = track = ""
    span_id = parent_id = t0 = t1 = args = None

    def __bool__(self) -> bool:
        return False

    def child(self, name, **args) -> "_NullSpan":
        return self

    def child_at(self, name, t0, t1, **args) -> "_NullSpan":
        return self

    def instant(self, name, **args) -> None:
        pass

    def end(self, t1=None, **args) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class TraceLog:
    """Bounded ring of finished spans + instant events.

    Entries are appended in end-time order, so eviction drops the
    oldest-*ended* work first; a parent (which ends after its children)
    therefore always outlives its children in the ring, and the only
    dangling edge eviction can create is a surviving span whose
    ``parent_id`` left the ring — ``snapshot()`` re-roots those.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self.n_recorded = 0

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def n_evicted(self) -> int:
        return self.n_recorded - len(self._ring)

    def append_span(self, span: Span) -> None:
        with self._lock:
            self._ring.append(("span", span.name, span.track, span.span_id,
                               span.parent_id, span.t0, span.t1, span.args))
            self.n_recorded += 1

    def append_instant(self, name: str, track: str, t: float,
                       parent_id: Optional[int], args: Optional[dict]) -> None:
        with self._lock:
            self._ring.append(("instant", name, track, None, parent_id,
                               t, t, args))
            self.n_recorded += 1

    # ---------------------------------------------------------- export
    def snapshot(self) -> List[dict]:
        """Finished entries as dicts, oldest first, with parent ids
        that left the ring re-rooted to None (no dangling references
        survive into an export)."""
        with self._lock:
            entries = list(self._ring)
        live = {e[3] for e in entries if e[3] is not None}
        return [{"kind": kind, "name": name, "track": track, "id": sid,
                 "parent": parent if parent in live else None,
                 "t0": t0, "t1": t1, "args": args}
                for kind, name, track, sid, parent, t0, t1, args in entries]

    def export_chrome(self, process_name: str = "repro") -> dict:
        """Chrome trace-event JSON (Perfetto-loadable).

        Every span becomes a matched B/E pair on its track's tid;
        instants become ``i`` events.  Events are sorted by timestamp
        with closes before opens at equal ts, so per-tid B/E stacks
        nest by construction.  Timestamps are µs from the earliest
        entry.
        """
        entries = self.snapshot()
        tids: Dict[str, int] = {}
        for e in entries:
            tids.setdefault(e["track"], len(tids) + 1)
        t_min = min((e["t0"] for e in entries), default=0.0)
        us = lambda t: (t - t_min) * 1e6

        events = []
        # priority orders equal-ts events: E closes before i, i before
        # B opens — adjacent spans sharing a boundary still nest.
        for e in entries:
            tid = tids[e["track"]]
            args = e["args"] or {}
            if e["parent"] is not None:
                args = {**args, "parent_span": e["parent"]}
            if e["kind"] == "instant":
                events.append((us(e["t0"]), 1, {
                    "name": e["name"], "ph": "i", "s": "t",
                    "ts": us(e["t0"]), "pid": 1, "tid": tid, "args": args}))
            else:
                common = {"name": e["name"], "pid": 1, "tid": tid}
                if e["id"] is not None:
                    args = {**args, "span_id": e["id"]}
                events.append((us(e["t0"]), 2, {
                    **common, "ph": "B", "ts": us(e["t0"]), "args": args}))
                events.append((us(e["t1"]), 0, {
                    **common, "ph": "E", "ts": us(e["t1"])}))
        events.sort(key=lambda ev: (ev[0], ev[1]))

        meta = [{"name": "process_name", "ph": "M", "ts": 0, "pid": 1,
                 "tid": 0, "args": {"name": process_name}}]
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                         "pid": 1, "tid": tid, "args": {"name": track}})
            meta.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                         "pid": 1, "tid": tid, "args": {"sort_index": tid}})
        return {"traceEvents": meta + [ev for _, _, ev in events],
                "displayTimeUnit": "ms"}

    def write_chrome(self, path, process_name: str = "repro") -> None:
        from pathlib import Path
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.export_chrome(process_name)))


class Tracer:
    """Span factory over one :class:`TraceLog` and one clock.

    ``enabled=False`` (the serving default) makes every factory method
    return :data:`NULL_SPAN` / no-op after a single attribute check —
    the off-path cost the serve_bench obs-overhead section pins down.
    """

    def __init__(self, log: Optional[TraceLog] = None, enabled: bool = True,
                 clock=time.perf_counter):
        self.log = log if log is not None else TraceLog()
        self.enabled = bool(enabled)
        self.clock = clock
        self._ids = itertools.count(1)

    @staticmethod
    def _track(track: Optional[str], parent: Optional[Span]) -> str:
        if track is not None:
            return track
        if parent is not None and parent.track:
            return parent.track
        return threading.current_thread().name

    def span(self, name: str, track: Optional[str] = None,
             parent: Optional[Span] = None, **args) -> Span:
        if not self.enabled:
            return NULL_SPAN
        parent = parent or None       # NULL_SPAN parents read as None
        return Span(self, name, self._track(track, parent),
                    next(self._ids),
                    parent.span_id if parent else None,
                    self.clock(), args or None)

    def span_at(self, name: str, t0: float, t1: float,
                track: Optional[str] = None, parent: Optional[Span] = None,
                **args) -> Span:
        """Record an already-finished span from measured boundaries."""
        if not self.enabled:
            return NULL_SPAN
        parent = parent or None
        s = Span(self, name, self._track(track, parent), next(self._ids),
                 parent.span_id if parent else None, t0, args or None)
        s.end(t1=t1)
        return s

    def root_span(self, name: str, **args) -> Span:
        """A span opening its own unique track — one Perfetto row per
        ticket, so concurrent tickets never interleave B/E events."""
        if not self.enabled:
            return NULL_SPAN
        span_id = next(self._ids)
        return Span(self, name, f"{name} #{span_id}", span_id, None,
                    self.clock(), args or None)

    def instant(self, name: str, track: Optional[str] = None,
                parent: Optional[Span] = None, **args) -> None:
        if not self.enabled:
            return
        parent = parent or None
        self.log.append_instant(name, self._track(track, parent),
                                self.clock(),
                                parent.span_id if parent else None,
                                args or None)


#: Shared disabled tracer — the default everywhere a tracer is optional.
NULL_TRACER = Tracer(log=TraceLog(capacity=1), enabled=False)
