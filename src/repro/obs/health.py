"""Health/introspection plane: per-cell statusz + heartbeat watchdog.

``statusz(cluster)`` is the cell's one-page answer to "what state is
the fleet in RIGHT NOW": head policy version and index epoch, per
replica the versions it has actually applied (and the lag against the
head), queue depths, ring occupancy/park counters straight from the
shm ring headers, restart counts, and a watchdog verdict per worker.
It reads only parent-side state (ring headers, cached acks, process
liveness) — no control-pipe round trips — so it is safe to dump from a
signal handler or a tight monitoring loop.  `tools/obsctl.py` renders
the JSON; ``repro.launch.cluster --statusz-out`` writes it.

The :class:`HeartbeatWatchdog` reads the worker heartbeat each worker
stamps into its request ring header (``time.monotonic``, comparable
across processes — CLOCK_MONOTONIC is system-wide).  The subtlety is
that a stale heartbeat alone is NOT a hang: a parked idle consumer
blocks in ``conn.poll`` with an empty ring and may legitimately stop
stamping.  The watchdog therefore folds in the pending-work signal
(ring occupancy + the worker's published engine depth) and only calls
"wedged" when the heartbeat is stale *while work is waiting*:

    dead         process gone (or restarts exhausted)
    healthy      heartbeat fresh (< stale_after_s)
    parked_idle  heartbeat stale, but nothing pending — parked, fine
    busy         heartbeat stale with work pending, but within the
                 wedge grace (a long rollout pauses stamping)
    wedged       heartbeat stale past wedge_after_s with work pending
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

__all__ = ["HeartbeatWatchdog", "statusz"]

#: Watchdog verdicts, worst-last (statusz reports the fleet's worst).
WORKER_STATES = ("healthy", "parked_idle", "busy", "wedged", "dead")


@dataclasses.dataclass(frozen=True)
class HeartbeatWatchdog:
    """Stateless classifier over (alive, heartbeat age, pending work).

    ``stale_after_s`` is the stamping cadence budget (workers stamp
    every loop iteration — ~ms when serving, so 1 s of silence means
    the loop is not spinning).  ``wedge_after_s`` is the grace a busy
    worker gets before stale + pending work is declared a hang — it
    must comfortably exceed the longest legitimate single rollout.
    """

    stale_after_s: float = 1.0
    wedge_after_s: float = 10.0

    def assess(self, *, alive: bool,
               heartbeat_age_s: Optional[float],
               pending: int) -> str:
        if not alive:
            return "dead"
        if heartbeat_age_s is None or heartbeat_age_s < self.stale_after_s:
            return "healthy"
        if pending <= 0:
            # The no-false-positive case: an idle parked consumer
            # (empty ring, blocked on its control pipe) is healthy
            # no matter how old its last stamp is.
            return "parked_idle"
        if heartbeat_age_s < self.wedge_after_s:
            return "busy"
        return "wedged"


def _worst(states) -> str:
    states = list(states)
    if not states:
        return "healthy"
    return max(states, key=WORKER_STATES.index)


def statusz(cluster, watchdog: Optional[HeartbeatWatchdog] = None) -> dict:
    """One-page cell status JSON for a ``ReplicaSet`` (either backend).

    Field reference lives in docs/observability.md; everything here is
    parent-side state only — calling this never blocks on a worker.
    """
    wd = watchdog or HeartbeatWatchdog()
    head_version = cluster.store.version
    head_epoch = getattr(cluster.system, "index_epoch", 0)
    replicas = []
    for r in cluster.replicas:
        h = r.health()
        h["state"] = wd.assess(alive=h.get("alive", False),
                               heartbeat_age_s=h.get("heartbeat_age_s"),
                               pending=h.get("pending", 0))
        h["policy_version"] = r.policy_version
        h["index_epoch"] = r.index_epoch
        h["policy_lag"] = max(0, head_version - r.policy_version)
        h["epoch_lag"] = max(0, head_epoch - r.index_epoch)
        replicas.append(h)
    doc = {
        "t_wall": time.time(),
        "backend": cluster.cfg.backend,
        "n_replicas": len(cluster.replicas),
        "head_policy_version": head_version,
        "head_index_epoch": head_epoch,
        "state": _worst(h["state"] for h in replicas),
        "watchdog": {"stale_after_s": wd.stale_after_s,
                     "wedge_after_s": wd.wedge_after_s},
        "replicas": replicas,
        "admission": cluster.admission.stats(),
        "events_recorded": cluster.events.n_recorded,
        "events_tail_kinds": [e["kind"] for e in cluster.events.tail(16)],
    }
    cell_dir = getattr(cluster, "proc_cell_dir", None)
    if cell_dir:
        doc["cell_dir"] = cell_dir
    return doc
