"""Flight recorder: bounded structured event ring + postmortem bundles.

Metrics say *how much* and traces say *where the time went*; neither
answers "what was the cell DOING around the crash".  The
:class:`EventLog` records the fleet's state transitions — policy
publishes, index epoch swaps, merges, service-level transitions,
worker restarts, sheds with reason — as bounded structured events in
the same vocabulary the metrics registry uses (each kind also bumps an
``events.recorded{kind=...}`` counter when a registry is attached), so
the tail is cheap to keep forever and cheap to dump.

The :class:`FlightRecorder` owns one event log plus the static run
config and writes **postmortem bundles**: a single JSON file with the
event-ring tail, the last metrics snapshot, the trace tail, and
whatever the caller adds — written by ``ProcessReplica`` whenever it
salvages a dead worker, so a SIGKILL'd replica leaves forensics behind
instead of just a respawn counter.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["EventLog", "FlightRecorder"]


class EventLog:
    """Bounded ring of structured fleet events.

    ``record(kind, **fields)`` is lock-cheap and never grows past
    ``capacity`` — old events fall off the back, which is the point: a
    postmortem needs the *recent* history.  Events carry both clocks:
    ``t`` (``time.monotonic``, orderable against heartbeats) and
    ``t_wall`` (``time.time``, readable in a bundle).
    """

    def __init__(self, capacity: int = 4096, registry=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self._registry = registry
        self._counters: Dict[str, object] = {}
        self.n_recorded = 0

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def n_evicted(self) -> int:
        return self.n_recorded - len(self._ring)

    def record(self, kind: str, **fields) -> dict:
        ev = {"t": time.monotonic(), "t_wall": time.time(),
              "kind": str(kind), **fields}
        with self._lock:
            self._ring.append(ev)
            self.n_recorded += 1
        if self._registry is not None:
            c = self._counters.get(kind)
            if c is None:
                c = self._counters[kind] = self._registry.counter(
                    "events.recorded", kind=kind)
            c.inc()
        return ev

    def tail(self, n: Optional[int] = None) -> List[dict]:
        """The most recent ``n`` events (all, when None), oldest first."""
        with self._lock:
            events = list(self._ring)
        return events if n is None else events[-int(n):]

    def snapshot(self) -> List[dict]:
        return self.tail(None)


class FlightRecorder:
    """Event log + run config + a bundle directory = crash forensics.

    ``dump(name, payload)`` writes ``<bundle_dir>/<name>-NNN.json``
    holding the event-ring tail, the static config, and the caller's
    payload (metrics snapshot, trace tail, death traceback…).  With no
    ``bundle_dir`` the recorder still collects events but ``dump`` is a
    no-op returning None — the thread backend records transitions
    without ever writing bundles.
    """

    #: Bounds on what one bundle carries — a postmortem wants the tail,
    #: not the life story.
    EVENTS_TAIL = 256
    TRACE_TAIL = 512

    def __init__(self, events: Optional[EventLog] = None,
                 bundle_dir=None, config: Optional[dict] = None):
        self.events = events if events is not None else EventLog()
        self.bundle_dir = Path(bundle_dir) if bundle_dir else None
        self.config = config or {}
        self._lock = threading.Lock()
        self._seq = 0
        self.last_bundle_path: Optional[Path] = None

    def record(self, kind: str, **fields) -> dict:
        return self.events.record(kind, **fields)

    def dump(self, name: str, payload: Optional[dict] = None):
        """Write one postmortem bundle; returns its path (None when no
        bundle dir is configured)."""
        if self.bundle_dir is None:
            return None
        with self._lock:
            self._seq += 1
            seq = self._seq
        bundle = {
            "bundle": name,
            "seq": seq,
            "t_wall": time.time(),
            "config": self.config,
            "events_tail": self.events.tail(self.EVENTS_TAIL),
            "events_recorded": self.events.n_recorded,
        }
        if payload:
            trace = payload.get("trace_tail")
            if trace is not None:
                payload = {**payload,
                           "trace_tail": list(trace)[-self.TRACE_TAIL:]}
            bundle.update(payload)
        self.bundle_dir.mkdir(parents=True, exist_ok=True)
        path = self.bundle_dir / f"{name}-{seq:03d}.json"
        path.write_text(json.dumps(bundle, indent=1, default=str))
        with self._lock:
            self.last_bundle_path = path
        return path
