"""Unified metrics plane: counters, gauges, fixed-bucket histograms.

Every serving layer used to keep its own ad-hoc counts (`Telemetry`'s
ints, `AdmissionController.level_counts`, router pick tallies) and
`ClusterStats` stitched them together with per-layer dict math.  The
:class:`MetricsRegistry` replaces that with one vocabulary:

- **Counter** — monotone event count (requests served, cache hits).
- **Gauge** — a level that goes up and down (queue depth, reserved u);
  the peak since construction rides along.
- **Histogram** — fixed-bucket distribution (per-(level, category)
  latency / u / queue-wait); fixed edges make snapshots mergeable by
  elementwise addition, which quantile-deque windows are not.

Recording is lock-cheap: each instrument carries its own uncontended
lock (most instruments are written by exactly one thread — the replica
worker for serve metrics, the trainer thread for trainer metrics — so
acquisition never blocks), and hot paths hold instrument *handles*
instead of re-resolving ``(name, labels)`` per event.

``snapshot()`` returns a plain-dict, JSON-serializable view, and
``merge()`` folds any number of snapshots associatively — counters and
histogram buckets add, gauges take the max (a fleet's merged queue
depth is its hottest replica), so cluster-level stats are a fold over
replica snapshots and, later, over *process* snapshots shipped as JSON.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "metric_key", "merge_snapshots"]


def metric_key(name: str, labels: Mapping[str, object]) -> str:
    """Stable string key for (name, labels) — the snapshot/JSON key.
    Labels are sorted so construction order never changes the key."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone event counter."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """A level that moves both ways; remembers its peak.

    ``agg`` declares how replicas' snapshots of this gauge fold in
    ``merge_snapshots``: ``"max"`` (default) answers "how hot is the
    hottest replica" — right for saturation gauges like reserved u —
    while ``"sum"`` answers "how much is pending fleet-wide" — right
    for depth gauges, where max-of-replicas undercounts capacity math
    by a factor of N.  The tag rides the snapshot so merging stays a
    pure fold over JSON.
    """

    __slots__ = ("_lock", "value", "max", "agg")

    def __init__(self, agg: str = "max"):
        if agg not in ("max", "sum"):
            raise ValueError(f"gauge agg must be 'max' or 'sum', got {agg!r}")
        self._lock = threading.Lock()
        self.value = 0.0
        self.max = 0.0
        self.agg = agg

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v
            if v > self.max:
                self.max = v

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value, "max": self.max,
                "agg": self.agg}


class Histogram:
    """Fixed-bucket histogram: ``edges`` are the finite upper bounds of
    the first ``len(edges)`` buckets, plus an implicit +inf overflow
    bucket — ``counts`` has ``len(edges) + 1`` entries.  Sum/count/min/
    max ride along so means survive merging exactly."""

    __slots__ = ("_lock", "edges", "counts", "sum", "count", "min", "max")

    def __init__(self, edges: Sequence[float]):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram edges must be sorted, got {edges!r}")
        self._lock = threading.Lock()
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def record(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v

    def record_many(self, values) -> None:
        """Record a batch of observations under ONE lock acquisition.

        Bucketing matches :meth:`record` exactly —
        ``np.searchsorted(edges, v, side="left")`` is
        ``bisect.bisect_left`` elementwise — so a slab recorded here is
        indistinguishable from a loop of scalar records."""
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(np.asarray(self.edges), v, side="left")
        binned = np.bincount(idx, minlength=len(self.edges) + 1)
        vmin = float(v.min())
        vmax = float(v.max())
        vsum = float(v.sum())
        n = int(v.size)
        with self._lock:
            for i in np.nonzero(binned)[0]:
                self.counts[int(i)] += int(binned[i])
            self.sum += vsum
            self.count += n
            if self.min is None or vmin < self.min:
                self.min = vmin
            if self.max is None or vmax > self.max:
                self.max = vmax

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the bucket holding
        the q-th observation) — coarse by design; exact percentiles
        come from `Telemetry`'s sliding window, this one is for merged
        fleet views where no window exists."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.edges[i] if i < len(self.edges) else self.max
        return self.max if self.max is not None else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": "histogram", "edges": list(self.edges),
                    "counts": list(self.counts), "sum": self.sum,
                    "count": self.count, "min": self.min, "max": self.max}


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named, labeled instruments with a mergeable JSON snapshot.

    ``counter/gauge/histogram(name, **labels)`` get-or-create and
    return the instrument — callers on hot paths should hold the
    returned handle rather than re-resolving per event.  A name must
    keep one type and (for histograms) one edge layout for its
    lifetime; mismatches raise rather than silently fork the metric.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "Dict[str, object]" = {}

    def _get(self, cls, name: str, labels: dict, *args):
        key = metric_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(*args)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {key!r} is a "
                                f"{type(m).__name__}, not a {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, agg: str = "max", **labels) -> Gauge:
        g = self._get(Gauge, name, labels, agg)
        if g.agg != agg:
            raise ValueError(f"gauge {metric_key(name, labels)!r} already "
                             f"registered with agg={g.agg!r}, not {agg!r}")
        return g

    def histogram(self, name: str, edges: Sequence[float],
                  **labels) -> Histogram:
        h = self._get(Histogram, name, labels, edges)
        if h.edges != tuple(float(e) for e in edges):
            raise ValueError(f"histogram {metric_key(name, labels)!r} "
                             f"already registered with different edges")
        return h

    def collect(self, name: str) -> Dict[str, object]:
        """Instruments whose key starts with ``name`` (exact name or
        any labeling of it) — for summary aggregations."""
        with self._lock:
            return {k: m for k, m in self._metrics.items()
                    if k == name or k.startswith(name + "{")}

    def snapshot(self) -> Dict[str, dict]:
        """JSON-serializable view of every instrument."""
        with self._lock:
            metrics = dict(self._metrics)
        return {k: m.snapshot() for k, m in sorted(metrics.items())}


def _merge_two(a: dict, b: dict) -> dict:
    if a["type"] != b["type"]:
        raise ValueError(f"cannot merge {a['type']} with {b['type']}")
    if a["type"] == "counter":
        return {"type": "counter", "value": a["value"] + b["value"]}
    if a["type"] == "gauge":
        agg = a.get("agg", "max")
        if agg != b.get("agg", "max"):
            raise ValueError(f"cannot merge gauge agg={agg!r} with "
                             f"agg={b.get('agg', 'max')!r}")
        if agg == "sum":
            # Fleet-wide level: depth gauges add across replicas (the
            # per-replica peaks add too — an upper bound on the worst
            # co-occurring fleet level, not an observed instant).
            return {"type": "gauge", "value": a["value"] + b["value"],
                    "max": a["max"] + b["max"], "agg": "sum"}
        # max: a merged gauge answers "how hot is the hottest replica",
        # which is the admission/routing question
        return {"type": "gauge", "value": max(a["value"], b["value"]),
                "max": max(a["max"], b["max"]), "agg": "max"}
    if a["edges"] != b["edges"]:
        raise ValueError("cannot merge histograms with different edges")
    mins = [m for m in (a["min"], b["min"]) if m is not None]
    maxs = [m for m in (a["max"], b["max"]) if m is not None]
    return {"type": "histogram", "edges": list(a["edges"]),
            "counts": [x + y for x, y in zip(a["counts"], b["counts"])],
            "sum": a["sum"] + b["sum"], "count": a["count"] + b["count"],
            "min": min(mins) if mins else None,
            "max": max(maxs) if maxs else None}


def merge_snapshots(snapshots: Iterable[Dict[str, dict]]) -> Dict[str, dict]:
    """Associative, commutative fold over registry snapshots: counters
    and histograms add, gauges take the max (or the sum, when declared
    ``agg="sum"`` — depth gauges).  ``ClusterStats`` is this fold over
    replica snapshots; the multi-process fleet is the same fold over
    JSON shipped across the IPC seam."""
    out: Dict[str, dict] = {}
    for snap in snapshots:
        for key, m in snap.items():
            out[key] = _merge_two(out[key], m) if key in out else dict(m)
    return dict(sorted(out.items()))
