"""Observability plane: metrics, tracing, health, SLO, flight recorder.

`metrics` holds the mergeable counters/gauges/histograms every serving
layer records into; `trace` holds the Span/Tracer/TraceLog machinery
that follows a ticket from admission to kernel — across the process
boundary — and exports a Perfetto-loadable Chrome trace; `health` is
the statusz/watchdog introspection plane; `slo` computes multi-window
error-budget burn over merged snapshots; `events` is the bounded
flight-recorder ring behind postmortem bundles.  See
docs/observability.md.
"""
from .events import EventLog, FlightRecorder
from .health import HeartbeatWatchdog, statusz
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    merge_snapshots,
    metric_key,
)
from .slo import SLOConfig, SLOMonitor, fold_snapshot
from .trace import (
    NULL_SPAN,
    NULL_TRACER,
    Span,
    TraceLog,
    Tracer,
    adjust_remote_entries,
    export_chrome_entries,
    write_chrome_entries,
)

__all__ = [
    "Counter",
    "EventLog",
    "FlightRecorder",
    "Gauge",
    "HeartbeatWatchdog",
    "Histogram",
    "MetricsRegistry",
    "SLOConfig",
    "SLOMonitor",
    "adjust_remote_entries",
    "export_chrome_entries",
    "fold_snapshot",
    "merge_snapshots",
    "metric_key",
    "statusz",
    "write_chrome_entries",
    "NULL_SPAN",
    "NULL_TRACER",
    "Span",
    "TraceLog",
    "Tracer",
]
