"""Observability plane: unified metrics registry + ticket-scoped tracing.

`metrics` holds the mergeable counters/gauges/histograms every serving
layer records into; `trace` holds the Span/Tracer/TraceLog machinery
that follows a ticket from admission to kernel and exports a
Perfetto-loadable Chrome trace.  See docs/observability.md.
"""
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    merge_snapshots,
    metric_key,
)
from .trace import NULL_SPAN, NULL_TRACER, Span, TraceLog, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "merge_snapshots",
    "metric_key",
    "NULL_SPAN",
    "NULL_TRACER",
    "Span",
    "TraceLog",
    "Tracer",
]
