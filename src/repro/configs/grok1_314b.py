"""grok-1-314b [hf:xai-org/grok-1; unverified] — MoE:
64L d_model=6144 48H (GQA kv=8) expert d_ff=32768, 8 experts top-2,
vocab=131072."""
from .lm_family import make_lm_arch

ARCH = make_lm_arch(
    "grok-1-314b",
    "[hf:xai-org/grok-1; unverified]",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=32768, vocab=131072, mlp_kind="swiglu",
    moe=dict(n_experts=8, top_k=2, n_shared=0, d_ff=32768),
    rope_theta=1e4,
    fsdp=True,   # 314B params: expert weights shard over data×model (ZeRO-3)
)
