"""starcoder2-3b [arXiv:2402.19173; hf] — dense, 30L d_model=3072 24H
(GQA kv=2) d_ff=12288 vocab=49152, RoPE, plain-GELU MLP."""
from .lm_family import make_lm_arch

ARCH = make_lm_arch(
    "starcoder2-3b",
    "[arXiv:2402.19173; hf]",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_head=128,
    d_ff=12288, vocab=49152, mlp_kind="gelu", rope_theta=1e5,
)
