"""Shared shape set + builder for the 5 LM-family transformer archs."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from .base import ArchDef, ShapeSpec, register

__all__ = ["lm_shapes", "make_lm_arch"]


def lm_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 dict(seq_len=32768, global_batch=32)),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                dict(seq_len=32768, global_batch=128)),
        "long_500k": ShapeSpec(
            "long_500k", "decode", dict(seq_len=524288, global_batch=1),
            note="decode-only: KV sequence-sharded over `model` + LSE merge; "
                 "500K PREFILL would be quadratic for these full-attention "
                 "archs and is skipped (DESIGN.md §6).",
        ),
    }


def make_lm_arch(
    arch_id: str,
    source: str,
    *,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv: int,
    d_ff: int,
    vocab: int,
    d_head: Optional[int] = None,
    mlp_kind: str = "swiglu",
    moe: Optional[dict] = None,          # dict(n_experts, top_k, n_shared, d_ff)
    mla: Optional[dict] = None,          # dict(kv_lora_rank, d_nope, d_rope, d_v)
    rope_theta: float = 1e6,
    fsdp: bool = False,
    notes: str = "",
) -> ArchDef:
    d_head = d_head or d_model // n_heads

    def model_cfg(reduced: bool) -> TransformerConfig:
        if reduced:
            moe_cfg = (
                MoEConfig(n_experts=4, top_k=min(2, moe["top_k"]), d_model=128,
                          d_ff=128, n_shared=min(1, moe.get("n_shared", 0)))
                if moe else None
            )
            mla_cfg = (
                MLAConfig(d_model=128, n_heads=4, kv_lora_rank=32, d_nope=16,
                          d_rope=8, d_v=16, q_chunk=64)
                if mla else None
            )
            return TransformerConfig(
                n_layers=2, d_model=128, n_heads=4, n_kv=(2 if n_kv < n_heads else 4),
                d_head=32, d_ff=256, vocab=512,
                mlp_kind=mlp_kind, attn_kind="mla" if mla else "gqa",
                moe=moe_cfg, mla=mla_cfg, max_seq=128, q_chunk=64, loss_chunk=128,
                remat=False, param_dtype=jnp.float32,
            )
        moe_cfg = (
            MoEConfig(n_experts=moe["n_experts"], top_k=moe["top_k"],
                      d_model=d_model, d_ff=moe["d_ff"],
                      n_shared=moe.get("n_shared", 0))
            if moe else None
        )
        mla_cfg = (
            MLAConfig(d_model=d_model, n_heads=n_heads,
                      kv_lora_rank=mla["kv_lora_rank"], d_nope=mla["d_nope"],
                      d_rope=mla["d_rope"], d_v=mla["d_v"], q_chunk=512)
            if mla else None
        )
        return TransformerConfig(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv=n_kv,
            d_head=d_head, d_ff=d_ff, vocab=vocab, mlp_kind=mlp_kind,
            attn_kind="mla" if mla else "gqa", moe=moe_cfg, mla=mla_cfg,
            rope_theta=rope_theta, max_seq=4096, q_chunk=512, loss_chunk=4096,
            remat=True, param_dtype=jnp.bfloat16, sp_carry=True, microbatch=4,
            fsdp=fsdp, grad_accum_dtype=jnp.bfloat16 if fsdp else jnp.float32,
        )

    return register(ArchDef(
        arch_id=arch_id, family="lm", source=source,
        model_cfg=model_cfg, shapes=lm_shapes(), notes=notes,
    ))
