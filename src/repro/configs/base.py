"""Config surface: every assigned architecture is a selectable ArchDef
(``--arch <id>``) carrying its exact published config, a reduced smoke
variant, and its own input-shape set (the 40 dry-run cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["ShapeSpec", "ArchDef", "register", "get_arch", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | serve | retrieval | train_graph ...
    params: Dict[str, Any]
    note: str = ""


@dataclasses.dataclass
class ArchDef:
    arch_id: str
    family: str                          # lm | gnn | recsys | websearch
    source: str                          # [citation; verification tier]
    model_cfg: Callable[[bool], Any]     # reduced -> config object
    shapes: Dict[str, ShapeSpec]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


_REGISTRY: Dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.arch_id] = arch
    return arch


def get_arch(arch_id: str) -> ArchDef:
    from . import _load_all
    _load_all()
    return _REGISTRY[arch_id]


def list_archs():
    from . import _load_all
    _load_all()
    return dict(_REGISTRY)
