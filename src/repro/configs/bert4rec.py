"""bert4rec [arXiv:1904.06690; paper] — bidirectional self-attention
over item sequences: embed_dim=64, 2 blocks, 2 heads, seq_len=200,
masked-item training, tied-weight item scoring.  Item vocab 1M
(huge_embedding regime); retrieval_cand dots the encoded user state
against the (model-sharded) item table."""
from __future__ import annotations

from repro.models.recsys import B4RConfig
from .base import ArchDef, register
from .recsys_family import recsys_shapes


def model_cfg(reduced: bool) -> B4RConfig:
    if reduced:
        return B4RConfig(n_items=512, embed_dim=32, n_blocks=2, n_heads=2, seq_len=32)
    return B4RConfig(n_items=1_000_000, embed_dim=64, n_blocks=2, n_heads=2, seq_len=200)


ARCH = register(ArchDef(
    arch_id="bert4rec", family="recsys",
    source="[arXiv:1904.06690; paper]",
    model_cfg=model_cfg, shapes=recsys_shapes(),
))
