"""dcn-v2 [arXiv:2008.13535; paper] — 13 dense + 26 sparse fields,
embed_dim=16, 3 full-rank cross layers, parallel deep tower
1024-1024-512."""
from __future__ import annotations

from repro.models.recsys import RecsysConfig
from .base import ArchDef, register
from .recsys_family import recsys_shapes


def model_cfg(reduced: bool) -> RecsysConfig:
    if reduced:
        return RecsysConfig(n_sparse=6, vocab_per_field=64, embed_dim=8,
                            mlp_dims=(32, 16), n_dense=4, n_cross_layers=2,
                            interaction="cross")
    return RecsysConfig(n_sparse=26, vocab_per_field=1_000_000, embed_dim=16,
                        mlp_dims=(1024, 1024, 512), n_dense=13,
                        n_cross_layers=3, interaction="cross")


ARCH = register(ArchDef(
    arch_id="dcn-v2", family="recsys",
    source="[arXiv:2008.13535; paper]",
    model_cfg=model_cfg, shapes=recsys_shapes(),
))
