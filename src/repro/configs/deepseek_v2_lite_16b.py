"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MoE + MLA:
27L d_model=2048 16H, MLA kv_lora_rank=512 (d_nope=128, d_rope=64,
d_v=128), 64 routed experts top-6 + 2 shared, expert d_ff=1408,
vocab=102400.

Spec-sheet discrepancy ("2 shared + 160 routed" belongs to full V2) is
resolved to the Lite config per hf:DeepSeek-V2-Lite — see DESIGN.md §6.
"""
from .lm_family import make_lm_arch

ARCH = make_lm_arch(
    "deepseek-v2-lite-16b",
    "[arXiv:2405.04434; hf]",
    n_layers=27, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=102400, mlp_kind="swiglu",
    moe=dict(n_experts=64, top_k=6, n_shared=2, d_ff=1408),
    mla=dict(kv_lora_rank=512, d_nope=128, d_rope=64, d_v=128),
    rope_theta=1e4,
)
