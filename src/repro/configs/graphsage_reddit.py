"""graphsage-reddit [arXiv:1706.02216; paper] — 2 layers, d_hidden=128,
mean aggregator, sample sizes 25-10.  Four graph regimes as shape cells.
"""
from __future__ import annotations

from repro.models.gnn import SAGEConfig
from .base import ArchDef, ShapeSpec, register


def model_cfg(reduced: bool) -> SAGEConfig:
    if reduced:
        return SAGEConfig(d_in=16, d_hidden=32, n_classes=7, n_layers=2)
    # d_in is shape-dependent (per-cell d_feat); launch/steps resolves it.
    return SAGEConfig(d_in=-1, d_hidden=128, n_classes=41, n_layers=2)


ARCH = register(ArchDef(
    arch_id="graphsage-reddit",
    family="gnn",
    source="[arXiv:1706.02216; paper]",
    model_cfg=model_cfg,
    shapes={
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "train_graph",
            dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
            note="cora-scale full-batch",
        ),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg", "train_minibatch",
            dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                 fanout=(15, 10), d_feat=602, n_classes=41),
            note="reddit; real neighbor sampler feeds fixed-shape blocks",
        ),
        "ogb_products": ShapeSpec(
            "ogb_products", "train_graph",
            dict(n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47),
            note="full-batch-large; edges sharded over data axes, "
                 "node states replicated + psum",
        ),
        "molecule": ShapeSpec(
            "molecule", "train_batched_graphs",
            dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=2),
            note="block-diagonal batching + segment-mean readout",
        ),
    },
    notes="Arch spec says sample_sizes=25-10; the minibatch_lg CELL "
          "specifies fanout 15-10 — the cell wins for that shape.",
))
