"""websearch-rl — the paper's own system as a selectable arch.

Serve shape: a batch of queries scanned against a block-sharded index
(documents over `model`, queries over `pod`×`data`), greedy Q-policy,
per-shard candidate buffers merged by static rank.
"""
from __future__ import annotations

import dataclasses

from .base import ArchDef, ShapeSpec, register


@dataclasses.dataclass(frozen=True)
class WebSearchCfg:
    n_blocks: int          # global index blocks (docs = n_blocks * block_docs)
    block_docs: int
    k_rules: int = 6
    max_candidates: int = 512
    n_top: int = 5
    p_bins: int = 10_000   # paper's p
    t_max: int = 8
    u_budget: int = 65536
    # Index-scan backend for the serve/train cells (core/scan_backends.py):
    # "xla" full-tile block scanning, "pallas_block_scan" chunked
    # plane-pruned kernel (bytes streamed ∝ u).
    backend: str = "xla"


def model_cfg(reduced: bool) -> WebSearchCfg:
    if reduced:
        return WebSearchCfg(n_blocks=16, block_docs=256, p_bins=256, u_budget=512)
    # 4096 blocks × 4096 docs = 16.7M docs per index slice
    return WebSearchCfg(n_blocks=4096, block_docs=4096)


ARCH = register(ArchDef(
    arch_id="websearch-rl", family="websearch",
    source="[SIGIR'18 Rosset et al.; the paper]",
    model_cfg=model_cfg,
    shapes={
        "serve_queries": ShapeSpec(
            "serve_queries", "serve_websearch",
            dict(query_batch=256),
            note="L0 candidate generation under the greedy learned policy, "
                 "index sharded over `model`",
        ),
        "rl_rollout": ShapeSpec(
            "rl_rollout", "train_websearch",
            dict(query_batch=256),
            note="ε-greedy rollout + batched TD update (policy training step)",
        ),
    },
))
