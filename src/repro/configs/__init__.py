"""Arch registry: importing this package registers all configs."""
from .base import ArchDef, ShapeSpec, get_arch, list_archs

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        mistral_nemo_12b,
        starcoder2_3b,
        phi4_mini_3_8b,
        deepseek_v2_lite_16b,
        grok1_314b,
        graphsage_reddit,
        bert4rec,
        wide_deep,
        deepfm,
        dcn_v2,
        websearch_rl,
    )
