"""phi4-mini-3.8b [arXiv:2412.08905; hf] — dense, 32L d_model=3072 24H
(GQA kv=8) d_ff=8192 vocab=200064, RoPE + SwiGLU."""
from .lm_family import make_lm_arch

ARCH = make_lm_arch(
    "phi4-mini-3.8b",
    "[arXiv:2412.08905; hf]",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_head=128,
    d_ff=8192, vocab=200064, mlp_kind="swiglu", rope_theta=1e4,
)
