"""Shared shape set for the 4 recsys archs."""
from __future__ import annotations

from typing import Dict

from .base import ShapeSpec

__all__ = ["recsys_shapes"]


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
        "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512),
                               note="online-inference latency shape"),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144),
                                note="offline scoring"),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000),
            note="one query scored against 1M candidates: batched dot / "
                 "full forward over candidate rows + sharded top-k",
        ),
    }
