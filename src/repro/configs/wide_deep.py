"""wide-deep [arXiv:1606.07792; paper] — 40 sparse fields, embed_dim=32,
MLP 1024-512-256, wide linear + deep concat interaction.  Tables are
40 × 1M rows (huge_embedding regime), row-sharded over `model`."""
from __future__ import annotations

from repro.models.recsys import RecsysConfig
from .base import ArchDef, register
from .recsys_family import recsys_shapes


def model_cfg(reduced: bool) -> RecsysConfig:
    if reduced:
        return RecsysConfig(n_sparse=6, vocab_per_field=64, embed_dim=8,
                            mlp_dims=(32, 16), interaction="concat")
    return RecsysConfig(n_sparse=40, vocab_per_field=1_000_000, embed_dim=32,
                        mlp_dims=(1024, 512, 256), interaction="concat")


ARCH = register(ArchDef(
    arch_id="wide-deep", family="recsys",
    source="[arXiv:1606.07792; paper]",
    model_cfg=model_cfg, shapes=recsys_shapes(),
))
