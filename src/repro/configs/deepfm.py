"""deepfm [arXiv:1703.04247; paper] — 39 sparse fields, embed_dim=10,
MLP 400-400-400, FM interaction (first order + pairwise via the
(Σv)²−Σv² identity) sharing embeddings with the deep tower."""
from __future__ import annotations

from repro.models.recsys import RecsysConfig
from .base import ArchDef, register
from .recsys_family import recsys_shapes


def model_cfg(reduced: bool) -> RecsysConfig:
    if reduced:
        return RecsysConfig(n_sparse=6, vocab_per_field=64, embed_dim=8,
                            mlp_dims=(32, 16), interaction="fm")
    return RecsysConfig(n_sparse=39, vocab_per_field=1_000_000, embed_dim=10,
                        mlp_dims=(400, 400, 400), interaction="fm")


ARCH = register(ArchDef(
    arch_id="deepfm", family="recsys",
    source="[arXiv:1703.04247; paper]",
    model_cfg=model_cfg, shapes=recsys_shapes(),
))
