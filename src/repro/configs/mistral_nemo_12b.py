"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407; hf] — dense,
40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072,
128k context (rope_theta=1e6)."""
from .lm_family import make_lm_arch

ARCH = make_lm_arch(
    "mistral-nemo-12b",
    "[hf:mistralai/Mistral-Nemo-Base-2407; hf]",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_head=128,
    d_ff=14336, vocab=131072, mlp_kind="swiglu", rope_theta=1e6,
    notes="head_dim=128 explicit (5120/32=160 is NOT the head dim in Nemo).",
)
