"""The L1 ranker — first rank-and-prune stage of the telescope (paper §3).

A small MLP over query-document features; its score is the paper's
``g(d)`` relevance estimate inside the reward (Eq. 3) and the ranking
function for candidate pruning between L0 and L2.  Trained on the
synthetic graded judgments.  The cascade accepts any scorer with the
same signature — configs may swap in a recsys arch (wide_deep / deepfm)
as the g(d) estimator (DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .features import FEATURE_DIM, doc_features

__all__ = ["init_l1", "l1_score", "score_all_docs", "train_l1", "idf_for_terms"]

Params = Dict[str, jnp.ndarray]


def init_l1(rng: jax.Array, hidden: int = 32, feature_dim: int = FEATURE_DIM) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    s1 = 1.0 / np.sqrt(feature_dim)
    s2 = 1.0 / np.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (feature_dim, hidden), jnp.float32) * s1,
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, hidden), jnp.float32) * s2,
        "b2": jnp.zeros((hidden,), jnp.float32),
        "w3": jax.random.normal(k3, (hidden, 1), jnp.float32) * s2,
        "b3": jnp.zeros((1,), jnp.float32),
    }


def l1_score(params: Params, feats: jnp.ndarray) -> jnp.ndarray:
    """(..., FEATURE_DIM) -> (...,) score in (0, 1)."""
    h = jax.nn.relu(feats @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return jax.nn.sigmoid((h @ params["w3"] + params["b3"])[..., 0])


def score_all_docs(params, occ, idf, term_present, static_rank, doc_len):
    """Precompute g(d) for every document of one query's occupancy.
    (Used by the environment: the reward gathers these as docs are
    recalled.)"""
    feats = doc_features(occ, idf, term_present, static_rank, doc_len)
    return l1_score(params, feats)


def idf_for_terms(df_body: np.ndarray, n_docs: int, terms: np.ndarray) -> np.ndarray:
    """Per-query-slot IDF, 0 for padded slots. terms: (Q, T) with -1 pad."""
    safe = np.clip(terms, 0, None)
    idf = np.log(n_docs / (1.0 + df_body[safe]))
    return np.where(terms >= 0, idf, 0.0).astype(np.float32)


@jax.jit
def _l1_adam_step(params, opt_state, feats, targets, weights):
    from repro.train.optimizer import AdamWConfig, adamw_update

    def loss_fn(p):
        pred = l1_score(p, feats)
        return jnp.sum(weights * (pred - targets) ** 2) / jnp.maximum(weights.sum(), 1.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state = adamw_update(params, grads, opt_state, AdamWConfig(lr=3e-3))
    return params, opt_state, loss


def train_l1(params, feats, gains, weights, steps: int = 300, batch: int = 4096, seed: int = 0):
    """Pointwise regression of gain/4 on features (Adam).

    feats: (N, FEATURE_DIM), gains: (N,) in [0,4], weights: (N,).
    """
    from repro.train.optimizer import adamw_init

    rng = np.random.default_rng(seed)
    targets = jnp.asarray(gains, jnp.float32) / 4.0
    feats = jnp.asarray(feats, jnp.float32)
    weights = jnp.asarray(weights, jnp.float32)
    opt_state = adamw_init(params)
    n = feats.shape[0]
    losses = []
    for _ in range(steps):
        idx = rng.integers(0, n, size=min(batch, n))
        params, opt_state, loss = _l1_adam_step(params, opt_state, feats[idx], targets[idx], weights[idx])
        losses.append(float(loss))
    return params, losses
