"""Candidate-set quality and efficiency metrics (paper §5).

NCG — NDCG without position discounting, because L0 candidate sets are
unordered (Eq. 5–6)::

    CumGain = Σ_{i=1..|D|} gain_i ,  NCG = CumGain / CumGain_ideal

|D| capped at 100 (candidates kept in scan order = static-rank order).
Efficiency metric is the blocks-accessed accumulator ``u``.  Paired
relative deltas + a sign-permutation significance test reproduce
Table 1's reporting.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ncg_at_k", "batched_ncg", "relative_delta", "paired_permutation_pvalue"]


def ncg_at_k(
    cand: jnp.ndarray,         # (K,) int32 doc ids, -1 pad, scan order
    judged_ids: jnp.ndarray,   # (J,) int32, -1 pad
    judged_gains: jnp.ndarray, # (J,) float32
    k: int = 100,
) -> jnp.ndarray:
    cand_k = cand[:k]
    valid = cand_k >= 0
    eq = (cand_k[:, None] == judged_ids[None, :]) & (judged_ids[None, :] >= 0)
    gains = jnp.sum(jnp.where(eq, judged_gains[None, :], 0.0), axis=1)
    cum_gain = jnp.sum(jnp.where(valid, gains, 0.0))

    j_valid = judged_ids >= 0
    sorted_gains = jnp.sort(jnp.where(j_valid, judged_gains, 0.0))[::-1]
    ideal = jnp.sum(sorted_gains[:k])
    return jnp.where(ideal > 0, cum_gain / ideal, 0.0)


@jax.jit
def batched_ncg(cand, judged_ids, judged_gains):
    return jax.vmap(ncg_at_k)(cand, judged_ids, judged_gains.astype(jnp.float32))


def relative_delta(treatment: np.ndarray, baseline: np.ndarray) -> float:
    """Mean relative change, as Table 1 reports (%)."""
    b = np.mean(baseline)
    return float((np.mean(treatment) - b) / max(b, 1e-9) * 100.0)


def paired_permutation_pvalue(
    treatment: np.ndarray, baseline: np.ndarray, n_perm: int = 2000, seed: int = 0
) -> float:
    """Two-sided paired sign-permutation test on the per-query deltas."""
    rng = np.random.default_rng(seed)
    d = np.asarray(treatment, np.float64) - np.asarray(baseline, np.float64)
    obs = abs(d.mean())
    signs = rng.choice([-1.0, 1.0], size=(n_perm, len(d)))
    null = np.abs((signs * d[None, :]).mean(axis=1))
    return float((np.sum(null >= obs) + 1) / (n_perm + 1))
