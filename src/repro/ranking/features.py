"""Query-document features for the L1 ranker.

Computed directly from the bitpacked occupancy tensor (i.e. from
exactly the evidence the match engine sees) plus per-document side data
(static rank, field lengths) and per-query term IDFs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.index.blocks import WORD_BITS
from repro.index.corpus import N_FIELDS
from repro.index.builder import MAX_QUERY_TERMS

__all__ = ["FEATURE_DIM", "unpack_occupancy", "doc_features"]

FEATURE_DIM = 3 * N_FIELDS + 3  # 15 for 4 fields


def unpack_occupancy(occ: jnp.ndarray) -> jnp.ndarray:
    """(n_blocks, T, F, W) uint32 -> (n_docs_padded, T, F) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (occ[..., None] >> shifts) & jnp.uint32(1)          # (nb, T, F, W, 32)
    nb, t, f = occ.shape[0], occ.shape[1], occ.shape[2]
    bits = bits.reshape(nb, t, f, -1).astype(bool)             # (nb, T, F, D)
    return bits.transpose(0, 3, 1, 2).reshape(nb * bits.shape[3], t, f)


def doc_features(
    occ: jnp.ndarray,          # (n_blocks, T, F, W) uint32
    idf: jnp.ndarray,          # (T,) float32 (0 for padded slots)
    term_present: jnp.ndarray, # (T,) bool
    static_rank: jnp.ndarray,  # (n_docs_padded,) float32
    doc_len: jnp.ndarray,      # (n_docs_padded, F) float32 (normalized log lengths)
) -> jnp.ndarray:
    """Per-document features, (n_docs_padded, FEATURE_DIM) float32."""
    hits = unpack_occupancy(occ).astype(jnp.float32)           # (D, T, F)
    tp = term_present.astype(jnp.float32)
    nt = jnp.maximum(tp.sum(), 1.0)
    hits = hits * tp[None, :, None]

    field_cov = hits.sum(1) / nt                                       # (D, F)
    idf_sum = jnp.maximum((idf * tp).sum(), 1e-6)
    field_idf = (hits * idf[None, :, None]).sum(1) / idf_sum           # (D, F)
    any_field = hits.max(2)                                            # (D, T)
    terms_matched = any_field.sum(1) / nt                              # (D,)
    all_matched = (any_field.sum(1) >= nt).astype(jnp.float32)         # (D,)

    return jnp.concatenate(
        [
            field_cov,
            field_idf,
            terms_matched[:, None],
            all_matched[:, None],
            static_rank[:, None],
            doc_len,
        ],
        axis=1,
    )
