"""NCG metric, query log generation, L1 ranker quality."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.telescope import l1_prune, merge_shard_candidates
from repro.data.querylog import CAT1, CAT2, classify_query, sample_eval_sets
from repro.ranking.metrics import (
    batched_ncg,
    ncg_at_k,
    paired_permutation_pvalue,
    relative_delta,
)


# -------------------------------------------------------------------- NCG
def test_ncg_hand_example():
    cand = jnp.asarray(np.array([3, 7, 9, -1], np.int32))
    judged = jnp.asarray(np.array([3, 9, 11], np.int32))
    gains = jnp.asarray(np.array([4.0, 2.0, 3.0]))
    # cum gain = 4 + 2 = 6; ideal = 4 + 3 + 2 = 9
    assert float(ncg_at_k(cand, judged, gains)) == pytest.approx(6 / 9)


def test_ncg_perfect_and_bounds():
    judged = jnp.asarray(np.arange(10, dtype=np.int32))
    gains = jnp.asarray(np.ones(10, np.float32))
    cand = jnp.asarray(np.concatenate([np.arange(10), -np.ones(20)]).astype(np.int32))
    assert float(ncg_at_k(cand, judged, gains)) == pytest.approx(1.0)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1))
def test_ncg_in_unit_interval(seed):
    rng = np.random.default_rng(seed)
    cand = rng.choice(200, size=50, replace=False).astype(np.int32)
    judged = rng.choice(200, size=30, replace=False).astype(np.int32)
    gains = rng.integers(0, 5, size=30).astype(np.float32)
    v = float(ncg_at_k(jnp.asarray(cand), jnp.asarray(judged), jnp.asarray(gains)))
    assert 0.0 <= v <= 1.0


def test_permutation_test_detects_shift(rng):
    base = rng.normal(0, 1, 400)
    assert paired_permutation_pvalue(base + 0.5, base) < 0.01
    assert paired_permutation_pvalue(base, base.copy()) > 0.5


def test_relative_delta_sign():
    assert relative_delta(np.array([80.0]), np.array([100.0])) == pytest.approx(-20.0)


# -------------------------------------------------------------- query log
def test_querylog_structure(tiny_system):
    log = tiny_system.log
    assert (log.n_terms >= 1).all()
    assert (log.terms[log.terms >= 0] < tiny_system.index.vocab_size).all()
    assert log.popularity.sum() == pytest.approx(1.0)
    # both categories present
    assert (log.category == CAT1).any() and (log.category == CAT2).any()
    # judged gains on the 5-point scale
    assert log.judged_gains.min() >= 0 and log.judged_gains.max() <= 4


def test_seed_doc_judged_relevant(tiny_system):
    """The document a query was generated from should usually be judged
    relevant — the generative link that makes NCG meaningful."""
    log = tiny_system.log
    hits = 0
    for q in range(0, log.n_queries, 7):
        j = log.judged_ids[q]
        mask = j == log.seed_doc[q]
        if mask.any() and log.judged_gains[q][mask][0] >= 2:
            hits += 1
    assert hits > log.n_queries // 7 * 0.5


def test_classifier_agrees_with_generative_labels(tiny_system):
    log, index = tiny_system.log, tiny_system.index
    pred = classify_query(log, index)
    agree = (pred == log.category).mean()
    assert agree > 0.7


def test_eval_sets_weighted_vs_unweighted(tiny_system):
    log = tiny_system.log
    w, u = sample_eval_sets(log, 400, seed=0)
    # weighted set hits popular (head) queries far more often
    assert log.popularity[w].mean() > 2 * log.popularity[u].mean()
    assert len(np.unique(u)) == len(u)


def test_l1_ranker_orders_relevant_docs(tiny_system):
    """L1 scores must correlate with graded relevance — it is g(d) in Eq. 3."""
    sys_ = tiny_system
    qids = np.arange(0, 64)
    occ, scores, _ = sys_.batch_inputs(qids)
    good, bad = [], []
    for row, q in enumerate(qids):
        j, g = sys_.log.judged_ids[q], sys_.log.judged_gains[q]
        valid = j >= 0
        s = np.asarray(scores[row])[np.clip(j, 0, None)]
        good.append(s[valid & (g >= 3)])
        bad.append(s[valid & (g == 0)])
    assert np.concatenate(good).mean() > np.concatenate(bad).mean() + 0.05


# -------------------------------------------------------------- telescope
def test_l1_prune_orders_by_score():
    scores_all = jnp.asarray(np.linspace(0, 1, 100)[None, :].astype(np.float32))
    cand = jnp.asarray(np.array([[5, 50, 99, -1]], np.int32))
    ids, s = l1_prune(scores_all, cand, keep=3)
    assert list(np.asarray(ids)[0]) == [99, 50, 5]
    assert (np.diff(np.asarray(s)[0]) <= 0).all()


def test_merge_shard_candidates_static_rank_order():
    shard = np.full((2, 1, 4), -1, np.int32)
    shard[0, 0, :2] = [7, 19]
    shard[1, 0, :3] = [3, 11, 40]
    merged = np.asarray(merge_shard_candidates(jnp.asarray(shard), keep=4))[0]
    assert list(merged) == [3, 7, 11, 19]
