"""Tiered live index: segments, epochs, merge, parity, epoch-keyed
serving (src/repro/index/live/, docs/index.md)."""
import numpy as np
import pytest

from repro.core.versioned import StaleVersionError, VersionedStore
from repro.data.querylog import CAT2, QueryLogConfig
from repro.index.builder import build_index_from_pairs
from repro.index.corpus import CorpusConfig, N_FIELDS
from repro.index.live import (BaseSegment, DeltaSegment, IndexEpochStore,
                              LiveIndex, LiveRetrievalSystem, MergeConfig,
                              MergeDaemon, StaleIndexEpochError,
                              check_epoch_parity)
from repro.policies.store import PolicyStore, StalePolicyError
from repro.system import SystemConfig


# ----------------------------------------------------------- tiny builders
def tiny_index(n_docs=96, vocab=64, block_docs=32, seed=0):
    rng = np.random.default_rng(seed)
    pair_docs, pair_terms = [], []
    for k in (1, 2, 8, 3):                    # anchor/url/body/title-ish
        pair_docs.append(np.repeat(np.arange(n_docs, dtype=np.int64), k))
        pair_terms.append(rng.integers(0, vocab, size=n_docs * k))
    return build_index_from_pairs(
        pair_docs, pair_terms, n_docs=n_docs, vocab_size=vocab,
        static_rank=np.linspace(1, 0, n_docs, dtype=np.float32),
        block_docs=block_docs, dedup=True)


def rand_doc(rng, vocab=64):
    fields = [np.unique(rng.integers(0, vocab, size=k))
              for k in (1, 2, 8, 3)]
    return [f.astype(np.int32) for f in fields]


@pytest.fixture(scope="module")
def live_sys():
    """One live retrieval system shared by the module; tests only rely
    on RELATIVE epoch/doc-count movement, never absolute values, so
    accumulated mutations from earlier tests are fine."""
    sys_ = LiveRetrievalSystem(SystemConfig(
        corpus=CorpusConfig(n_docs=512, vocab_size=256, seed=5),
        querylog=QueryLogConfig(n_queries=96, seed=5),
        block_docs=128, p_bins=128, u_budget=512, l1_steps=60,
    ), capacity_docs=1536)
    sys_.fit_l1(n_queries=48, batch=16)
    sys_.fit_state_bins(n_queries=32, batch=16)
    return sys_


# ------------------------------------------------------- versioned core
def test_stale_errors_share_base():
    # One except-clause covers both publish planes (replica.py relies
    # on it): policy and index staleness are the same root error.
    assert issubclass(StalePolicyError, StaleVersionError)
    assert issubclass(StaleIndexEpochError, StaleVersionError)
    assert issubclass(StaleVersionError, RuntimeError)


def test_policy_store_is_versioned_store():
    assert issubclass(PolicyStore, VersionedStore)
    assert issubclass(IndexEpochStore, VersionedStore)


def test_index_epoch_store_staleness_and_subscribe():
    live = LiveIndex(tiny_index(), staleness_bound=1)
    store = live.store
    v0 = store.version
    seen = []
    unsub = store.subscribe(lambda e: seen.append(e.version))
    live.add_document(rand_doc(np.random.default_rng(0)))
    live.commit()
    live.add_document(rand_doc(np.random.default_rng(1)))
    live.commit()
    # the subscriber sees the head at subscription time, then every
    # publish in order
    assert seen == [v0, v0 + 1, v0 + 2]
    assert store.staleness(v0 + 2) == 0
    with pytest.raises(StaleIndexEpochError):
        store.validate(v0)                  # 2 behind, bound is 1
    unsub()
    live.add_document(rand_doc(np.random.default_rng(2)))
    live.commit()
    assert seen == [v0, v0 + 1, v0 + 2]     # unsubscribed — no delivery


# ----------------------------------------------------------- base segment
def test_base_segment_mmap_roundtrip(tmp_path):
    seg = BaseSegment.from_index(tiny_index(), generation=3)
    assert not seg.mmapped
    seg.save(tmp_path / "gen")
    loaded = BaseSegment.load(tmp_path / "gen")
    assert loaded.mmapped and loaded.generation == 3
    a, b = seg.index, loaded.index
    assert a.n_docs == b.n_docs and a.block_docs == b.block_docs
    np.testing.assert_array_equal(a.static_rank, b.static_rank)
    np.testing.assert_array_equal(a.doc_len, b.doc_len)
    np.testing.assert_array_equal(a.df, b.df)
    for f in range(N_FIELDS):
        np.testing.assert_array_equal(a.indptr[f], b.indptr[f])
        np.testing.assert_array_equal(a.doc_ids[f], b.doc_ids[f])
        for d in (0, 17, a.n_docs - 1):
            np.testing.assert_array_equal(seg.doc_terms(d, f),
                                          loaded.doc_terms(d, f))


# ---------------------------------------------------------- delta segment
def test_delta_append_only_ids():
    base = BaseSegment.from_index(tiny_index(n_docs=64))
    rng = np.random.default_rng(7)
    from repro.index.live.segments import DeltaOp
    ops = [DeltaOp("add", 64, rand_doc(rng)),
           DeltaOp("add", 66, rand_doc(rng))]   # gap: 65 missing
    with pytest.raises(ValueError, match="append-only"):
        DeltaSegment(base, ops)


def test_delta_update_tombstones_and_df():
    base = BaseSegment.from_index(tiny_index(n_docs=64))
    rng = np.random.default_rng(8)
    doc = 5
    new_fields = rand_doc(rng)
    from repro.index.live.segments import DeltaOp
    delta = DeltaSegment(base, [DeltaOp("update", doc, new_fields)])
    assert delta.tombstones.tolist() == [doc]
    # df: the old doc's terms are subtracted, the new ones added.
    for f in range(N_FIELDS):
        expect = base.index.df[:, f].copy()
        expect[base.doc_terms(doc, f)] -= 1
        expect[new_fields[f]] += 1
        np.testing.assert_array_equal(expect, delta.df[:, f])
        # updated doc is served from the delta postings, not the base
        for t in new_fields[f]:
            assert doc in delta.postings(int(t), f)


# ------------------------------------------------------- live index + epochs
def test_live_index_commit_merge_epochs(tmp_path):
    live = LiveIndex(tiny_index(), storage_dir=tmp_path)
    rng = np.random.default_rng(9)
    v0, g0, n0 = live.epoch, live.generation, live.n_docs
    ids = live.add_documents([rand_doc(rng) for _ in range(5)])
    assert ids == list(range(n0, n0 + 5))
    assert live.n_docs == n0             # invisible until commit
    live.commit()
    assert live.epoch == v0 + 1 and live.n_docs == n0 + 5
    assert live.delta_docs == 5
    live.merge()
    assert live.epoch == v0 + 2          # every visible publish bumps
    assert live.generation == g0 + 1     # merge also bumps generation
    assert live.delta_docs == 0 and live.n_docs == n0 + 5
    # merged generation is served from an mmapped on-disk base
    assert live.stats()["base_mmapped"]


def test_live_index_capacity_overflow():
    live = LiveIndex(tiny_index(n_docs=96, block_docs=32),
                     capacity_docs=128)
    rng = np.random.default_rng(10)
    for _ in range(128 - 96):
        live.add_document(rand_doc(rng))
    with pytest.raises(ValueError, match="capacity"):
        live.add_document(rand_doc(rng))


def test_occupancy_shape_fixed_across_epochs():
    live = LiveIndex(tiny_index(), capacity_docs=256)
    view0 = live.store.snapshot().view
    shape0 = view0.query_occupancy([1, 2]).shape
    rng = np.random.default_rng(11)
    live.add_documents([rand_doc(rng) for _ in range(3)])
    live.commit()
    live.merge()
    view1 = live.store.snapshot().view
    # static AOT shapes: occupancy spans CAPACITY at every epoch, so
    # compiled rollouts never retrace across commits or merges
    assert view1.query_occupancy([1, 2]).shape == shape0


def test_merge_daemon_compacts():
    live = LiveIndex(tiny_index())
    rng = np.random.default_rng(12)
    g0 = live.generation
    with MergeDaemon(live, MergeConfig(min_delta_docs=4,
                                       poll_interval_s=0.01)) as daemon:
        live.add_documents([rand_doc(rng) for _ in range(6)])
        live.commit()
        daemon.trigger()
        deadline = 50
        while live.delta_docs and deadline:
            import time
            time.sleep(0.05)
            deadline -= 1
    assert daemon.last_error is None
    assert live.generation > g0 and live.delta_docs == 0
    assert daemon.merges_run >= 1


# ------------------------------------------------------------ parity sweep
def test_parity_across_add_commit_merge(live_sys):
    sys_ = live_sys
    rng = np.random.default_rng(13)
    qids = rng.choice(sys_.log.n_queries, size=6, replace=False)
    store = sys_.index_epoch_store
    out = check_epoch_parity(sys_, store.snapshot(), qids)
    assert out["ok"]
    sys_.add_documents([rand_doc(rng, vocab=256) for _ in range(4)])
    sys_.commit_index()
    out = check_epoch_parity(sys_, store.snapshot(), qids)
    assert out["ok"] and out["n_docs"] >= 516
    sys_.merge_index()
    out = check_epoch_parity(sys_, store.snapshot(), qids)
    assert out["ok"] and out["generation"] >= 1


def test_append_queries_served(live_sys):
    sys_ = live_sys
    rng = np.random.default_rng(14)
    doc = rand_doc(rng, vocab=256)
    [did] = sys_.add_documents([doc])
    terms = np.sort(doc[3][:2]).astype(np.int32)       # title terms
    [qid] = sys_.append_queries([terms], [CAT2],
                                judged_ids=[[did]], judged_gains=[[4]])
    sys_.commit_index()
    assert qid == sys_.log.n_queries - 1
    occ, scores, tp = sys_.batch_inputs([qid])
    assert int(np.asarray(tp).sum()) == len(terms)
    # the fresh doc must be visible in the appended query's occupancy
    view = sys_.index_epoch_store.snapshot().view
    assert did in view.postings(int(terms[0]), 3)


# ---------------------------------------- epoch-keyed serving (regression)
def test_cache_hit_never_survives_epoch_swap(live_sys):
    """A result filled at epoch N must NEVER answer at epoch N+1: the
    swap invalidates exactly the stale entries via (key,
    policy_version, index_epoch) cache keys."""
    from repro.serving import EngineConfig, ServeEngine

    sys_ = live_sys
    store = PolicyStore(staleness_bound=4)
    store.publish(sys_.baseline_policies(),
                  fallbacks=sys_.fallback_policies())
    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=4, max_bucket=8, cache_capacity=256))
    engine.warmup()

    qid = 3
    [r1] = engine.serve([qid])
    e1 = r1.index_epoch
    assert not r1.cached and e1 == sys_.index_epoch
    [r2] = engine.serve([qid])
    assert r2.cached and r2.index_epoch == e1

    rng = np.random.default_rng(15)
    sys_.add_documents([rand_doc(rng, vocab=256)])
    sys_.commit_index()                    # epoch N+1
    [r3] = engine.serve([qid])
    assert r3.index_epoch == e1 + 1
    assert not r3.cached, "epoch-N fill answered at epoch N+1"
    [r4] = engine.serve([qid])             # refilled under the new key
    assert r4.cached and r4.index_epoch == e1 + 1
    assert engine.summary()["index_epoch_swaps"] >= 1


# -------------------------------------------------------------- freshness
def test_freshness_workload(live_sys):
    from repro.data.freshness import FreshnessConfig, FreshnessWorkload

    sys_ = live_sys
    n_docs0, n_q0, e0 = sys_.live.n_docs, sys_.log.n_queries, sys_.index_epoch
    w = FreshnessWorkload(sys_, FreshnessConfig(
        docs_per_tick=4, wave_queries=16, seed=3))
    wave = w.tick()
    assert sys_.live.n_docs == n_docs0 + 4
    assert sys_.log.n_queries == n_q0 + 4
    assert sys_.index_epoch == e0 + 1      # tick commits an epoch
    assert wave.shape == (16,)
    fresh = wave[wave >= n_q0]
    assert fresh.size > 0                  # the wave chases fresh docs
    # chase queries judge the fresh doc relevant
    q = int(fresh[0])
    assert sys_.log.judged_ids[q, 0] >= n_docs0
