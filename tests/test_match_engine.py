"""Match rules, plans, executor and environment semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.environment import EnvConfig, env_reset, env_step, execute_rule
from repro.core.match_plan import make_plan, plan_rollout
from repro.core.match_rules import block_cost, default_rule_library, scan_block
from repro.core.reward import r_agent, step_reward
from repro.index.blocks import unpack_bits
from repro.data.querylog import CAT1, CAT2


# ------------------------------------------------------------- scan_block
def _numpy_scan_block(occ, allowed, required, present):
    """Oracle: per-doc evaluation of ∧_t ∨_f occ bits."""
    T, F, W = occ.shape
    bits = unpack_bits(occ.reshape(T * F, W)).reshape(T, F, W * 32)
    masked = bits & allowed[:, :, None] & present[:, None, None]
    tf_or = masked.any(axis=1)                       # (T, D)
    req = required & present
    if not req.any():
        match = np.zeros(W * 32, bool)
    else:
        match = tf_or[req].all(axis=0)
    v_inc = int(tf_or[present].sum())
    return match, v_inc


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_scan_block_matches_oracle(seed, words):
    rng = np.random.default_rng(seed)
    T, F = 4, 4
    occ = rng.integers(0, 2**32, size=(T, F, words), dtype=np.uint32)
    allowed = rng.random((T, F)) < 0.5
    required = rng.random(T) < 0.7
    present = rng.random(T) < 0.8
    match, v_inc = scan_block(
        jnp.asarray(occ), jnp.asarray(allowed), jnp.asarray(required), jnp.asarray(present)
    )
    exp_match, exp_v = _numpy_scan_block(occ, allowed, required, present)
    got_match = unpack_bits(np.asarray(match))
    assert (got_match == exp_match).all()
    assert int(v_inc) == exp_v


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1))
def test_scan_block_field_monotonicity(seed):
    """Adding allowed fields can only grow the match set."""
    rng = np.random.default_rng(seed)
    occ = rng.integers(0, 2**32, size=(4, 4, 2), dtype=np.uint32)
    allowed = rng.random((4, 4)) < 0.4
    bigger = allowed | (rng.random((4, 4)) < 0.4)
    required = np.ones(4, bool)
    present = np.ones(4, bool)
    m1, _ = scan_block(jnp.asarray(occ), jnp.asarray(allowed), jnp.asarray(required), jnp.asarray(present))
    m2, _ = scan_block(jnp.asarray(occ), jnp.asarray(bigger), jnp.asarray(required), jnp.asarray(present))
    assert int(jnp.sum(m1 & ~m2)) == 0  # m1 ⊆ m2


def test_block_cost_counts_planes():
    allowed = jnp.asarray(np.array([[1, 1, 0, 0], [0, 0, 1, 0], [0, 0, 0, 0], [1, 1, 1, 1]], bool))
    present = jnp.asarray(np.array([1, 1, 0, 1], bool))
    assert int(block_cost(allowed, present)) == 2 + 1 + 0 + 4


# ------------------------------------------------------------ environment
@pytest.fixture(scope="module")
def env_inputs(tiny_system):
    sys_ = tiny_system
    qids = np.where(sys_.log.category == CAT1)[0][:8]
    occ, scores, tp = sys_.batch_inputs(qids)
    return sys_, occ, scores, tp


def test_u_accounting(env_inputs):
    """u equals planes-per-block × blocks scanned for a single rule."""
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    rs = sys_.ruleset
    state = env_reset(cfg)
    a, r = rs.allowed[0], rs.required[0]
    s1 = execute_rule(cfg, occ[0], scores[0], tp[0], state, a, r,
                      jnp.int32(10**9), jnp.int32(10**9))
    planes = int(block_cost(a, tp[0]))
    assert int(s1.u) == planes * cfg.n_blocks          # scanned the whole index
    assert int(s1.block_ptr) == cfg.n_blocks


def test_candidates_unique_sorted(env_inputs):
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    state = env_reset(cfg)
    s1 = execute_rule(cfg, occ[0], scores[0], tp[0], state,
                      sys_.ruleset.allowed[0], sys_.ruleset.required[0],
                      jnp.int32(10**9), jnp.int32(10**9))
    cand = np.asarray(s1.cand)
    got = cand[cand >= 0]
    assert len(np.unique(got)) == len(got)
    assert (np.diff(got) > 0).all()                    # scan order = doc id order
    assert int(s1.cand_cnt) == len(got)


def test_dedup_across_reset(env_inputs):
    """Re-running the same rule after a_reset adds no candidates but costs u."""
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    rs = sys_.ruleset
    state = env_reset(cfg)
    step = lambda st, a: env_step(cfg, rs, occ[0], scores[0], tp[0], st, jnp.int32(a))
    s1 = step(state, 1)
    s2 = step(s1, cfg.a_reset)
    assert int(s2.block_ptr) == 0
    s3 = step(s2, 1)
    assert int(s3.cand_cnt) == int(s1.cand_cnt)
    assert int(s3.u) > int(s1.u)


def test_stop_is_terminal_and_frozen(env_inputs):
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    step = lambda st, a: env_step(cfg, sys_.ruleset, occ[0], scores[0], tp[0], st, jnp.int32(a))
    s1 = step(env_reset(cfg), 0)
    s2 = step(s1, cfg.a_stop)
    assert bool(s2.done)
    s3 = step(s2, 0)  # further rules are no-ops
    assert int(s3.u) == int(s2.u) and int(s3.cand_cnt) == int(s2.cand_cnt)


def test_plan_executor_trajectory(env_inputs):
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    plan = sys_.plans["CAT1"]
    final, traj = plan_rollout(cfg, sys_.ruleset, plan, occ, scores, tp)
    u = np.asarray(traj["u"])
    assert u.shape == (occ.shape[0], plan.length)
    assert (np.diff(u, axis=1) >= 0).all()             # u is cumulative
    assert (np.asarray(final.u) == u[:, -1]).all()


# ----------------------------------------------------------------- reward
def test_reward_no_progress_penalty(env_inputs):
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    s0 = env_reset(cfg)
    s1 = env_step(cfg, sys_.ruleset, occ[0], scores[0], tp[0], s0, jnp.int32(cfg.a_reset))
    r = step_reward(cfg, s0, s1, jnp.float32(0.0))
    assert float(r) == pytest.approx(-cfg.no_progress_penalty)


def test_r_agent_form(env_inputs):
    sys_, occ, scores, tp = env_inputs
    cfg = sys_.env_cfg
    s0 = env_reset(cfg)
    s1 = env_step(cfg, sys_.ruleset, occ[0], scores[0], tp[0], s0, jnp.int32(0))
    ra = float(r_agent(cfg, s1))
    assert np.isfinite(ra) and ra >= 0.0
    # manual recompute
    topn = np.asarray(s1.topn)
    m = min(max(int(s1.v), 1), cfg.n_top)
    expect = np.where(np.isfinite(topn[:m]), topn[:m], 0).sum() / (m * max(int(s1.u), 1))
    assert ra == pytest.approx(float(expect), rel=1e-5)
