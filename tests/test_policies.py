"""Unified Policy API: parity with the legacy rollout loops, the
PolicyStore contract, and serving integration.

The legacy loops (pre-refactor ``run_plan`` / ``greedy_rollout``,
removed after their deprecation cycle) are reimplemented verbatim here
as oracles, so the parity claims hold against the original semantics.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.environment import env_reset, env_step, execute_rule
from repro.core.rollout import unified_rollout
from repro.core.state_bins import bin_index
from repro.data.querylog import CAT1, CAT2
from repro.policies import (
    EpsilonGreedy, PolicySnapshot, PolicyStore, StalePolicyError,
    StaticPlanPolicy, TabularQPolicy,
)
from repro.serving import (
    EngineConfig, ServeEngine, available_backends, register_rollout_backend,
)
from repro.serving.executor import ShardedExecutor


# ----------------------------------------------------------- legacy oracles
def _legacy_run_plan(cfg, ruleset, plan, occ, scores, tp):
    """Verbatim pre-refactor match_plan.run_plan (single query)."""
    state = env_reset(cfg)

    def step(state, entry):
        rule_idx, reset_before, du_q, dv_q = entry
        bp = jnp.where(reset_before, 0, state.block_ptr)
        state = dataclasses.replace(state, block_ptr=bp)
        allowed, required, _, _ = ruleset.gather(rule_idx)
        state = execute_rule(cfg, occ, scores, tp, state, allowed, required,
                             du_q, dv_q)
        traj = {
            "u": state.u,
            "v": state.v,
            "topn_sum": jnp.sum(jnp.where(jnp.isfinite(state.topn),
                                          state.topn, 0.0)),
            "cand_cnt": state.cand_cnt,
        }
        return state, traj

    entries = (plan.rule_idx, plan.reset_before, plan.du_quota, plan.dv_quota)
    return lax.scan(step, state, entries)


def _legacy_greedy_rollout(cfg, qcfg, ruleset, bins, q, occ, scores, tp):
    """Verbatim pre-refactor qlearning.greedy_rollout (batched)."""
    batch = occ.shape[0]
    state0 = jax.vmap(lambda _: env_reset(cfg))(jnp.arange(batch))

    def step(state, _):
        s_bin = bin_index(bins, state.u, state.v)
        action = jnp.argmax(q[s_bin], axis=-1).astype(jnp.int32)
        new_state = jax.vmap(partial(env_step, cfg, ruleset))(
            occ, scores, tp, state, action)
        return new_state, action

    return lax.scan(step, state0, jnp.arange(qcfg.t_max))


STATE_FIELDS = ("u", "v", "cand", "cand_cnt", "topn", "matched", "block_ptr")


def _assert_states_equal(a, b, fields=STATE_FIELDS):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)


@pytest.fixture(scope="module")
def inputs(tiny_system):
    qids = np.where(tiny_system.log.category == CAT1)[0][:6]
    return tiny_system, tiny_system.batch_inputs(qids)


@pytest.fixture(scope="module")
def trained_q(tiny_system):
    return tiny_system.train_policy(CAT2, iters=8, batch=16)[0]


# --------------------------------------------------- static-plan parity
@pytest.mark.parametrize("plan_name", ["CAT1", "CAT2"])
def test_static_plan_policy_bitforbit(inputs, plan_name):
    """StaticPlanPolicy through unified_rollout reproduces the legacy
    plan executor bit-for-bit — trajectory and final state (the CAT1
    plan includes a reset-before entry; CAT2 a double pass)."""
    sys_, (occ, scores, tp) = inputs
    plan = sys_.plans[plan_name]

    leg_fin, leg_traj = jax.vmap(
        lambda o, s, t: _legacy_run_plan(sys_.env_cfg, sys_.ruleset, plan,
                                         o, s, t))(occ, scores, tp)

    policy = StaticPlanPolicy(plan, sys_.env_cfg.n_actions)
    res = unified_rollout(sys_.env_cfg, sys_.ruleset, None, policy,
                          plan.length, occ, scores, tp)
    traj = {k: np.asarray(jnp.moveaxis(v, 0, 1))
            for k, v in res.trajectory.items()}   # (B, L) like the oracle

    for k in leg_traj:
        np.testing.assert_array_equal(np.asarray(leg_traj[k]), traj[k],
                                      err_msg=k)
    _assert_states_equal(leg_fin, res.final_state)


def test_static_plan_policy_stops_past_horizon(inputs):
    """Under t_max > plan.length the policy emits a_stop; the state is
    frozen at the end-of-plan state."""
    sys_, (occ, scores, tp) = inputs
    plan = sys_.plans["CAT1"]
    policy = StaticPlanPolicy(plan, sys_.env_cfg.n_actions)
    short = unified_rollout(sys_.env_cfg, sys_.ruleset, None, policy,
                            plan.length, occ, scores, tp)
    long = unified_rollout(sys_.env_cfg, sys_.ruleset, None, policy,
                           plan.length + 3, occ, scores, tp)
    _assert_states_equal(short.final_state, long.final_state,
                         fields=("u", "v", "cand", "cand_cnt", "topn"))
    assert np.asarray(long.final_state.done).all()


# --------------------------------------------------- greedy / ε parity
def test_tabular_q_policy_matches_legacy_greedy(inputs, trained_q):
    sys_, (occ, scores, tp) = inputs
    leg_fin, leg_actions = _legacy_greedy_rollout(
        sys_.env_cfg, sys_.qcfg, sys_.ruleset, sys_.bins, trained_q,
        occ, scores, tp)
    res = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                          TabularQPolicy(trained_q), sys_.qcfg.t_max,
                          occ, scores, tp)
    np.testing.assert_array_equal(np.asarray(leg_actions),
                                  np.asarray(res.transitions["a"]))
    _assert_states_equal(leg_fin, res.final_state,
                         fields=STATE_FIELDS + ("done",))


def test_epsilon_zero_equals_greedy(inputs, trained_q):
    sys_, (occ, scores, tp) = inputs
    greedy = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                             TabularQPolicy(trained_q), sys_.qcfg.t_max,
                             occ, scores, tp)
    eps0 = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                           EpsilonGreedy(TabularQPolicy(trained_q),
                                         jnp.float32(0.0)),
                           sys_.qcfg.t_max, occ, scores, tp,
                           None, jax.random.key(42))
    np.testing.assert_array_equal(np.asarray(greedy.transitions["a"]),
                                  np.asarray(eps0.transitions["a"]))
    _assert_states_equal(greedy.final_state, eps0.final_state)


def test_epsilon_one_explores(inputs, trained_q):
    """ε=1 is uniform-random — the action stream must leave the greedy
    trajectory (and ε is a traced leaf: same compiled fn both calls)."""
    sys_, (occ, scores, tp) = inputs
    pol = EpsilonGreedy(TabularQPolicy(trained_q), jnp.float32(1.0))
    r1 = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins, pol,
                         sys_.qcfg.t_max, occ, scores, tp,
                         None, jax.random.key(0))
    greedy = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                             TabularQPolicy(trained_q), sys_.qcfg.t_max,
                             occ, scores, tp)
    assert (np.asarray(r1.transitions["a"])
            != np.asarray(greedy.transitions["a"])).any()


def test_unified_rollout_returns_both_products(inputs, trained_q):
    sys_, (occ, scores, tp) = inputs
    res = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                          TabularQPolicy(trained_q), sys_.qcfg.t_max,
                          occ, scores, tp)
    t, b = sys_.qcfg.t_max, occ.shape[0]
    for k in ("s", "a", "r", "s2", "done", "valid"):
        assert res.transitions[k].shape == (t, b), k
    for k in ("u", "v", "topn_sum", "cand_cnt"):
        assert res.trajectory[k].shape == (t, b), k


# --------------------------------------------------- removed legacy shims
def test_deprecated_shims_are_gone():
    """The one-release deprecation cycle is over: the legacy loop names
    must no longer exist (their verbatim oracles live in this file)."""
    from repro.core import match_plan, qlearning

    for mod, name in ((match_plan, "run_plan"),
                      (match_plan, "batched_run_plan"),
                      (qlearning, "rollout"),
                      (qlearning, "greedy_rollout")):
        assert not hasattr(mod, name), f"{mod.__name__}.{name} still exists"


# ------------------------------------------------------------- PolicyStore
def test_store_version_monotonicity(trained_q):
    store = PolicyStore(staleness_bound=2)
    pol = TabularQPolicy(trained_q)
    versions = [store.publish({CAT1: pol}) for _ in range(5)]
    assert versions == [1, 2, 3, 4, 5]
    assert store.version == 5
    snap = store.snapshot()
    assert isinstance(snap, PolicySnapshot) and snap.version == 5


def test_store_staleness_bound_rejection(trained_q):
    store = PolicyStore(staleness_bound=1)
    pol = TabularQPolicy(trained_q)
    v1 = store.publish({CAT1: pol})
    store.publish({CAT1: pol})
    assert store.validate(v1) == 1          # exactly at the bound: ok
    store.publish({CAT1: pol})
    with pytest.raises(StalePolicyError):
        store.validate(v1)                  # 2 behind, bound 1: rejected
    assert store.validate(store.version) == 0


def test_store_rejects_raw_arrays_and_empty(trained_q):
    store = PolicyStore()
    with pytest.raises(TypeError, match="TabularQPolicy"):
        store.publish({CAT1: np.asarray(trained_q)})
    with pytest.raises(TypeError):
        store.publish({})
    with pytest.raises(LookupError):
        store.snapshot()


def test_store_subscribe(trained_q):
    store = PolicyStore()
    pol = TabularQPolicy(trained_q)
    store.publish({CAT1: pol})
    seen = []
    unsubscribe = store.subscribe(lambda snap: seen.append(snap.version))
    assert seen == [1]                      # replay current snapshot
    store.publish({CAT1: pol})
    assert seen == [1, 2]
    unsubscribe()
    store.publish({CAT1: pol})
    assert seen == [1, 2]


def test_snapshot_policies_read_only(trained_q):
    store = PolicyStore()
    store.publish({CAT1: TabularQPolicy(trained_q)})
    with pytest.raises(TypeError):
        store.snapshot().policies[CAT2] = TabularQPolicy(trained_q)


def test_store_fallbacks_travel_with_snapshots(tiny_system, trained_q):
    """Fallback policies publish in the same snapshot as the live set
    (atomic hot-swap), carry forward when a publish omits them, and are
    validated like any other policy."""
    store = PolicyStore(staleness_bound=2)
    pol = TabularQPolicy(trained_q)
    fb = tiny_system.fallback_policies((CAT1,))
    store.publish({CAT1: pol}, fallbacks=fb)
    snap = store.snapshot()
    assert set(snap.fallbacks) == {CAT1}
    assert snap.fallbacks[CAT1].horizon == 2
    # omitted fallbacks carry forward — live + fallback stay paired
    store.publish({CAT1: pol})
    assert store.snapshot().fallbacks[CAT1] is snap.fallbacks[CAT1]
    # explicit replacement (and explicit clearing) both take
    store.publish({CAT1: pol}, fallbacks=dict(fb))
    store.publish({CAT1: pol}, fallbacks={})
    assert not store.snapshot().fallbacks
    with pytest.raises(TypeError, match="fallbacks"):
        store.publish({CAT1: pol},
                      fallbacks={CAT1: np.asarray(trained_q)})
    with pytest.raises(TypeError):
        store.snapshot().fallbacks[CAT1] = pol          # read-only


def test_store_subscribe_under_concurrent_publish_stress():
    """Threaded stress: publishers racing subscribers.  Every subscriber
    must observe (a) strictly increasing versions — a callback
    registered mid-publish sees the old or the new version first, never
    out of order or twice — and (b) never a torn snapshot: both
    categories of a snapshot always come from the same publish."""
    import threading

    store = PolicyStore(staleness_bound=10**9)
    n_publishers, n_pubs, n_subscribers = 3, 25, 8
    tag_by_version = {}                      # version -> publish tag
    tag_lock = threading.Lock()
    observed = [[] for _ in range(n_subscribers)]   # (version, tag0, tag1)

    def snap_tags(snap):
        return (float(np.asarray(snap.policies[CAT1].q)[0, 0]),
                float(np.asarray(snap.policies[CAT2].q)[0, 0]))

    def publisher(pid):
        for i in range(n_pubs):
            tag = float(pid * 1000 + i)
            q = jnp.full((2, 3), tag, jnp.float32)
            pols = {CAT1: TabularQPolicy(q), CAT2: TabularQPolicy(q)}
            with tag_lock:
                # publish inside the tag lock so version -> tag is exact
                version = store.publish(pols)
                tag_by_version[version] = tag
        return None

    def subscriber(sid):
        def cb(snap):
            observed[sid].append((snap.version, *snap_tags(snap)))
        store.subscribe(cb)

    threads = [threading.Thread(target=publisher, args=(p,))
               for p in range(n_publishers)]
    threads += [threading.Thread(target=subscriber, args=(s,))
                for s in range(n_subscribers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.version == n_publishers * n_pubs
    for sid, seq in enumerate(observed):
        versions = [v for v, _, _ in seq]
        assert versions == sorted(set(versions)), \
            f"subscriber {sid}: out-of-order/duplicate delivery {versions}"
        for v, t0, t1 in seq:
            assert t0 == t1, f"torn snapshot at v{v}: {t0} != {t1}"
            assert tag_by_version[v] == t0, \
                f"v{v} delivered tag {t0}, published {tag_by_version[v]}"


# ------------------------------------------------------ serving integration
def test_engine_rejects_raw_ndarray(tiny_system, trained_q):
    with pytest.raises(TypeError, match="TabularQPolicy"):
        ServeEngine(tiny_system, {CAT1: np.asarray(trained_q),
                                  CAT2: np.asarray(trained_q)})
    with pytest.raises(TypeError, match="PolicyStore"):
        ServeEngine(tiny_system, np.asarray(trained_q))


def test_engine_serves_static_plan_policy(tiny_system):
    """The hand-tuned baseline is just another policy behind the same
    engine: served u matches the direct baseline run."""
    sys_ = tiny_system
    engine = ServeEngine(sys_, sys_.baseline_policies(), EngineConfig(
        min_bucket=4, max_bucket=4, cache_capacity=0))
    qids = np.where(sys_.log.category == CAT1)[0][:4]
    responses = engine.serve(qids)
    base_final, _, _ = sys_.run_baseline(qids, CAT1)
    for lane, r in enumerate(responses):
        assert r.u == int(np.asarray(base_final.u)[lane])


def test_engine_hot_swap_and_cache_flush(tiny_system, trained_q):
    """Publishing a new snapshot hot-swaps serving and flushes the
    result cache (cached responses embody the previous policy)."""
    sys_ = tiny_system
    pol = TabularQPolicy(trained_q)
    store = PolicyStore(staleness_bound=1)
    store.publish({CAT1: pol, CAT2: pol})
    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=4, max_bucket=4, cache_capacity=64))
    qid = int(np.where(sys_.log.category == CAT1)[0][0])
    (first,) = engine.serve([qid])
    (hit,) = engine.serve([qid])
    assert not first.cached and hit.cached
    assert engine.policy_version == 1

    store.publish({CAT1: sys_.plan_policy(CAT1), CAT2: sys_.plan_policy(CAT2)})
    (swapped,) = engine.serve([qid])
    assert engine.policy_version == 2
    assert not swapped.cached               # cache flushed on version change
    base_final, _, _ = sys_.run_baseline([qid], CAT1)
    assert swapped.u == int(np.asarray(base_final.u)[0])


# ---------------------------------------------------------------- backends
def test_backend_registry(tiny_system):
    assert "xla" in available_backends()
    assert "pallas_block_scan" in available_backends()
    with pytest.raises(ValueError, match="available"):
        ShardedExecutor(tiny_system, backend="no_such_backend")


def test_pallas_backend_serves_end_to_end(tiny_system, trained_q):
    """`pallas_block_scan` is a real serving backend now: same responses
    as the xla executor, bit-for-bit (interpret mode on CPU)."""
    pol = TabularQPolicy(trained_q)
    exe_x = ShardedExecutor(tiny_system, backend="xla")
    exe_p = ShardedExecutor(tiny_system, backend="pallas_block_scan")
    qids = np.arange(4)
    occ, scores, tp = tiny_system.batch_inputs(qids)
    out_x = exe_x.execute(pol, occ, scores, tp)
    out_p = exe_p.execute(pol, occ, scores, tp)
    for a, b, name in zip(out_x, out_p, ("ids", "scores", "u", "cand_cnt")):
        np.testing.assert_array_equal(a, b, err_msg=name)


def test_pinned_engine_refuses_stale_cache_hits(tiny_system, trained_q):
    """An engine pinned past the staleness bound (auto_refresh=False)
    must refuse to serve even from its result cache."""
    sys_ = tiny_system
    pol = TabularQPolicy(trained_q)
    store = PolicyStore(staleness_bound=0)
    store.publish({CAT1: pol, CAT2: pol})
    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=4, max_bucket=4, cache_capacity=64, auto_refresh=False))
    qid = int(np.where(sys_.log.category == CAT1)[0][0])
    engine.serve([qid])                      # fills the cache at v1
    store.publish({CAT1: pol, CAT2: pol})    # head moves to v2, bound 0
    with pytest.raises(StalePolicyError):
        engine.submit(qid)                   # would have been a cache hit
    assert engine.refresh_policies()
    (hit,) = engine.serve([qid])             # refreshed: cache was flushed
    assert not hit.cached and engine.policy_version == 2


def test_failed_batch_requeues_requests(tiny_system, trained_q):
    """A batch that fails mid-drain (here: a purpose-built failing
    serving backend) must not lose admitted requests — they go back in
    the queue."""

    from repro.serving import executor as executor_mod

    @register_rollout_backend("_test_boom")
    def _boom(cfg, ruleset, bins, policy, t_max, occ, scores, tp):
        raise RuntimeError("backend boom")

    try:
        pol = TabularQPolicy(trained_q)
        engine = ServeEngine(tiny_system, {CAT1: pol, CAT2: pol}, EngineConfig(
            min_bucket=4, max_bucket=4, cache_capacity=0,
            backend="_test_boom"))
        rid = engine.submit(0)
        with pytest.raises(RuntimeError, match="backend boom"):
            engine.flush()
        assert engine.batcher.pending() == 1  # request survived the failure
        assert engine.take_response(rid) is None
    finally:
        executor_mod.ROLLOUT_BACKENDS.pop("_test_boom", None)
