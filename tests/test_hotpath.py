"""Batched hot-path data plane: slab/per-ticket bit parity (engine,
admission, cluster), the array-backed cache, ring batch transfer
edges, block codecs, bounded parent-side tables, telemetry batching,
and the bench-diff regression gate."""
import threading
import time

import numpy as np
import pytest

from repro.data.querylog import CAT1, CAT2
from repro.policies import PolicyStore, TabularQPolicy
from repro.serving import (
    AdmissionError, ArrayResultCache, CacheOnlyMiss, EngineConfig,
    LRUResultCache, SLAB_ADMISSION_REJECT, SLAB_CACHED_ONLY_MISS,
    ServeEngine, ServiceLevel, TicketSlab,
)
from repro.serving.array_cache import CacheEntry


@pytest.fixture(scope="module")
def trained(tiny_system):
    policies = {cat: TabularQPolicy(tiny_system.train_policy(
        cat, iters=10, batch=16)[0]) for cat in (CAT1, CAT2)}
    return tiny_system, policies


def _entry(seed: int, keep: int = 8) -> CacheEntry:
    rng = np.random.default_rng(seed)
    return CacheEntry(doc_ids=rng.integers(0, 1000, keep).astype(np.int32),
                      scores=rng.random(keep).astype(np.float32),
                      u=int(seed) * 3 + 1, cand_cnt=int(seed) + 10,
                      level=ServiceLevel.FULL)


# ------------------------------------------------------- array cache unit
class TestArrayResultCache:
    def test_get_put_peek_touch(self):
        c = ArrayResultCache(capacity=16, keep=8)
        e = _entry(1)
        c.put(("k", 1, 0), e)
        assert c.contains(("k", 1, 0))
        got = c.peek(("k", 1, 0))            # no side effects
        assert c.hits == 0 and c.misses == 0
        np.testing.assert_array_equal(got.doc_ids, e.doc_ids)
        np.testing.assert_array_equal(got.scores, e.scores)
        assert (got.u, got.cand_cnt, got.level) == (e.u, e.cand_cnt, e.level)
        assert isinstance(got.level, ServiceLevel)
        got2 = c.get(("k", 1, 0))
        assert c.hits == 1
        np.testing.assert_array_equal(got2.doc_ids, e.doc_ids)
        assert c.get(("absent", 1, 0)) is None
        assert c.misses == 1
        c.touch(("k", 1, 0))                  # ref bit only, no counters
        assert c.hits == 1 and c.misses == 1
        assert len(c) == 1

    def test_returned_arrays_are_copies(self):
        c = ArrayResultCache(capacity=4, keep=4)
        c.put("a", _entry(2, keep=4))
        got = c.get("a")
        got.doc_ids[:] = -7
        np.testing.assert_array_equal(
            c.get("a").doc_ids, _entry(2, keep=4).doc_ids)

    def test_update_in_place(self):
        c = ArrayResultCache(capacity=4, keep=4)
        c.put("a", _entry(3, keep=4))
        c.put("a", _entry(4, keep=4))
        assert len(c) == 1
        assert c.peek("a").u == _entry(4).u

    def test_clock_eviction_bounded(self):
        c = ArrayResultCache(capacity=8, keep=4)
        for i in range(50):
            c.put(("k", i), _entry(i, keep=4))
        assert len(c) == 8
        assert c.evictions == 42
        # recently-referenced entries get a second chance
        c2 = ArrayResultCache(capacity=4, keep=4)
        for i in range(4):
            c2.put(("k", i), _entry(i, keep=4))
        assert c2.get(("k", 3)) is not None   # ref bit set
        c2.put(("k", 99), _entry(99, keep=4))
        assert c2.contains(("k", 99))
        assert len(c2) == 4

    def test_tombstone_rebuild_keeps_serving(self):
        c = ArrayResultCache(capacity=8, keep=4)
        for wave in range(40):                # forces rebuilds via churn
            for i in range(8):
                c.put(("w", wave, i), _entry(i, keep=4))
        live = [k for k in [("w", 39, i) for i in range(8)]
                if c.contains(k)]
        assert len(live) == 8                 # the newest wave survived
        for k in live:
            assert c.peek(k) is not None

    def test_keep_growth(self):
        c = ArrayResultCache(capacity=4, keep=2)
        c.put("small", _entry(1, keep=2))
        c.put("big", _entry(2, keep=16))      # wider than allocated
        np.testing.assert_array_equal(
            c.peek("big").doc_ids, _entry(2, keep=16).doc_ids)
        np.testing.assert_array_equal(
            c.peek("small").doc_ids, _entry(1, keep=2).doc_ids)

    def test_clear_keeps_counters(self):
        c = ArrayResultCache(capacity=4, keep=4)
        c.put("a", _entry(1, keep=4))
        c.get("a")
        c.get("b")
        c.clear()
        assert len(c) == 0 and not c.contains("a")
        assert c.hits == 1 and c.misses == 1
        c.put("a", _entry(5, keep=4))         # still usable
        assert c.peek("a").u == _entry(5).u

    def test_stats_protocol_matches_lru(self):
        a = ArrayResultCache(capacity=8, keep=4)
        l = LRUResultCache(capacity=8)
        for cache in (a, l):
            cache.put("x", _entry(1, keep=4))
            cache.get("x")
            cache.get("missing")
            cache.record_miss()
            cache.add_stats(hits=3, misses=2)
        assert a.stats() == l.stats()
        assert a.hit_rate == l.hit_rate

    def test_lru_vs_array_trace_parity(self):
        """Same access trace, capacity large enough that no eviction
        happens: hit/miss accounting and every returned entry match."""
        rng = np.random.default_rng(0)
        a = ArrayResultCache(capacity=256, keep=4)
        l = LRUResultCache(capacity=256)
        keys = [("k", int(i)) for i in range(64)]
        for op in rng.integers(0, 3, size=800):
            k = keys[int(rng.integers(0, len(keys)))]
            if op == 0:
                ea, el = a.get(k), l.get(k)
            elif op == 1:
                ea, el = a.peek(k), l.peek(k)
            else:
                e = _entry(int(rng.integers(0, 100)), keep=4)
                a.put(k, e)
                l.put(k, e)
                continue
            assert (ea is None) == (el is None)
            if ea is not None:
                np.testing.assert_array_equal(ea.doc_ids, el.doc_ids)
                assert ea.u == el.u
        assert a.stats()["hits"] == l.stats()["hits"]
        assert a.stats()["misses"] == l.stats()["misses"]


# ------------------------------------------------------------ ticket slab
def test_ticket_slab_build(tiny_system):
    log = tiny_system.log
    slab = TicketSlab.build(log, [3, 5, 8], level=1, epoch=2)
    assert len(slab) == 3
    np.testing.assert_array_equal(slab.qids, [3, 5, 8])
    np.testing.assert_array_equal(
        slab.categories, np.asarray(log.category)[[3, 5, 8]])
    assert (slab.levels == 1).all() and slab.epoch == 2
    with pytest.raises(ValueError):
        TicketSlab.build(log, [1, 2], levels=[0])      # size mismatch


def test_query_key_cache(tiny_system):
    from repro.serving.cache import canonical_query_key
    from repro.serving.slab import QueryKeyCache

    kc = QueryKeyCache(tiny_system.log, capacity=4)
    for qid in (0, 1, 2, 0, 1):
        cat = int(tiny_system.log.category[qid])
        assert kc.key(qid) == canonical_query_key(
            tiny_system.log.terms[qid], cat)
    for qid in range(10):                     # overflow wholesale-clears
        kc.key(qid)
    assert kc.key(0) == canonical_query_key(
        tiny_system.log.terms[0], int(tiny_system.log.category[0]))


# ----------------------------------------------------- engine slab parity
def test_engine_slab_vs_loop_bit_parity(trained):
    """submit_slab == a loop of submit() on identical fresh engines:
    every response field, both cold (miss) and hot (hit) rounds."""
    sys_, policies = trained
    cfg = EngineConfig(min_bucket=8, max_bucket=16, cache_capacity=64)
    e_slab = ServeEngine(sys_, policies, cfg)
    e_loop = ServeEngine(sys_, policies,
                         EngineConfig(min_bucket=8, max_bucket=16,
                                      cache_capacity=64, cache_impl="lru"))
    qids = list(range(24)) + list(range(12))  # repeats inside one slab
    for _round in range(2):
        rs = e_slab.serve_many(qids)
        rl = e_loop.serve(qids)
        for a, b in zip(rs, rl):
            assert a.qid == b.qid and a.cached == b.cached
            assert a.level == b.level and a.u == b.u
            assert a.cand_cnt == b.cand_cnt
            assert a.policy_version == b.policy_version
            assert a.index_epoch == b.index_epoch
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(a.scores, b.scores)
    assert e_slab.cache.stats()["hits"] == e_loop.cache.stats()["hits"]
    assert e_slab.cache.stats()["misses"] == e_loop.cache.stats()["misses"]
    s, l = e_slab.summary(), e_loop.summary()
    for k in ("n_requests", "cache_hit_rate", "mean_u", "p99_u"):
        assert s[k] == pytest.approx(l[k]), k


def test_engine_slab_statuses(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=64, admission_limit=4))
    rids, statuses = engine.submit_slab(list(range(10)))
    assert (statuses[:4] == 0).all()
    assert (statuses[4:] == SLAB_ADMISSION_REJECT).all()
    engine.flush()
    for r in rids[:4]:
        assert engine.take_response(int(r)) is not None
    for r in rids[4:]:
        assert engine.take_response(int(r)) is None
    # CACHED_ONLY misses report, hits serve
    rids2, st2 = engine.submit_slab([0, 1, 8, 9],
                                    level=ServiceLevel.CACHED_ONLY)
    assert (st2[:2] == 0).all()               # served above, still cached
    assert (st2[2:] == SLAB_CACHED_ONLY_MISS).all()
    for r in rids2[:2]:
        assert engine.take_response(int(r)).cached
    with pytest.raises(AdmissionError):
        engine.submit_many(list(range(10, 22)))
    with pytest.raises(CacheOnlyMiss):
        engine.submit_many([8, 9], level=ServiceLevel.CACHED_ONLY)


def test_engine_cache_impl_validation(trained):
    sys_, policies = trained
    assert isinstance(
        ServeEngine(sys_, policies, EngineConfig()).cache, ArrayResultCache)
    assert isinstance(
        ServeEngine(sys_, policies, EngineConfig(cache_impl="lru")).cache,
        LRUResultCache)
    with pytest.raises(ValueError):
        ServeEngine(sys_, policies, EngineConfig(cache_impl="nope"))


# ------------------------------------------------------- admission parity
def test_decide_many_matches_decide(tiny_system):
    from repro.cluster.admission import AdmissionController, UCostEstimator

    est1, est2 = UCostEstimator(tiny_system), UCostEstimator(tiny_system)
    rng = np.random.default_rng(5)
    for q in range(96):
        u = float(rng.integers(20, 400))
        est1.observe(q, u)
        est2.observe(q, u)
    a1 = AdmissionController(est1, u_inflight_budget=900.0)
    a2 = AdmissionController(est2, u_inflight_budget=900.0)
    qids = list(rng.integers(0, tiny_system.log.n_queries, size=64))
    cache_av = [bool(rng.random() < 0.3) for _ in qids]
    shal_av = [bool(rng.random() < 0.7) for _ in qids]
    levels, reserves, est_full = a2.decide_many(
        qids, cache_available=cache_av, shallow_available=shal_av)
    saw = set()
    for i, q in enumerate(qids):
        adm = a1.decide(int(q), cache_available=cache_av[i],
                        shallow_available=shal_av[i])
        assert int(levels[i]) == int(adm.level)
        assert reserves[i] == adm.reserved_u   # bitwise float equality
        assert est_full[i] == adm.est_u
        saw.add(int(levels[i]))
    assert len(saw) > 1                        # the ladder actually walked
    assert a1.reserved_u == a2.reserved_u
    assert a1.level_counts == a2.level_counts
    assert (a1.admitted, a1.shed) == (a2.admitted, a2.shed)


# ------------------------------------------------------- cluster parity
def _serve_rounds(sys_, policies, backend, many, rounds=2, n=32,
                  n_replicas=2):
    from repro.cluster import ClusterConfig, ReplicaSet

    store = PolicyStore()
    store.publish(policies)
    cluster = ReplicaSet(sys_, store, ClusterConfig(
        n_replicas=n_replicas, backend=backend),
        EngineConfig(min_bucket=8, max_bucket=16, cache_capacity=256))
    out = []
    with cluster:
        if backend == "process":
            cluster.warmup()
        for _ in range(rounds):
            qids = list(range(n))
            out.append(cluster.serve_many(qids, timeout_s=300.0)
                       if many else cluster.serve(qids, timeout_s=300.0))
    return out


def test_cluster_thread_slab_parity(trained):
    """serve_many == serve on the thread backend: response content is
    replica-independent, so doc ids / scores / u / cand_cnt must match
    lane for lane (placement and cached flags may differ)."""
    from repro.cluster.admission import Shed

    sys_, policies = trained
    many = _serve_rounds(sys_, policies, "thread", True)
    loop = _serve_rounds(sys_, policies, "thread", False)
    for rm, rl in zip(many, loop):
        assert len(rm) == len(rl)
        for a, b in zip(rm, rl):
            assert not isinstance(a, Shed) and not isinstance(b, Shed)
            assert a.qid == b.qid and a.u == b.u
            assert a.cand_cnt == b.cand_cnt
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(a.scores, b.scores)


def test_cluster_process_slab_parity(trained):
    """The slab front door through worker processes: same strong
    fields as the thread oracle, zero sheds, hot round served from
    worker caches."""
    from repro.cluster.admission import Shed

    sys_, policies = trained
    proc = _serve_rounds(sys_, policies, "process", True, n=24)
    loop = _serve_rounds(sys_, policies, "thread", False, n=24)
    for rm, rl in zip(proc, loop):
        for a, b in zip(rm, rl):
            assert not isinstance(a, Shed)
            assert a.qid == b.qid and a.u == b.u
            assert a.cand_cnt == b.cand_cnt
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_array_equal(a.scores, b.scores)
    assert all(r.cached for r in proc[1])      # second round is hot


# ----------------------------------------------------------- ring batches
class TestRingBatch:
    def test_roundtrip_and_wraparound_mid_batch(self):
        from repro.cluster.proc.ring import ShmRing

        ring = ShmRing.create(8, 64)
        recs = np.arange(5 * 32, dtype=np.uint8).reshape(5, 32)
        assert ring.try_push_records(recs) == 5
        np.testing.assert_array_equal(ring.try_pop_records(16, 32), recs)
        # head=tail=5: a 5-record batch must split at the lap boundary
        # (3 slots to the wrap), never tear a record across it.
        k = ring.try_push_records(recs)
        assert k == 3
        got = ring.try_pop_records(16, 32)
        np.testing.assert_array_equal(got, recs[:3])
        k2 = ring.try_push_records(recs[3:])
        assert k2 == 2
        np.testing.assert_array_equal(ring.try_pop_records(16, 32), recs[3:])
        ring.close()

    def test_batch_larger_than_free_slots_splits_whole(self):
        from repro.cluster.proc.ring import ShmRing

        ring = ShmRing.create(8, 40)
        big = (np.arange(40, dtype=np.uint8)[None, :]
               + np.arange(30, dtype=np.uint8)[:, None])
        chunks = []

        def consume():
            while sum(c.shape[0] for c in chunks) < 30:
                got = ring.try_pop_records(4, 40)
                if got.shape[0]:
                    chunks.append(got)

        t = threading.Thread(target=consume)
        t.start()
        ring.push_records(big, deadline_s=time.monotonic() + 30.0)
        t.join(timeout=30.0)
        assert not t.is_alive()
        np.testing.assert_array_equal(np.concatenate(chunks), big)
        ring.close()

    def test_oversized_record_in_batch_rejected_cleanly(self):
        from repro.cluster.proc.ring import ShmRing

        ring = ShmRing.create(8, 32)
        with pytest.raises(ValueError):
            ring.try_push_records(np.zeros((2, 100), np.uint8))
        with pytest.raises(ValueError):
            ring.try_push_many([b"ok", b"x" * 100])
        with pytest.raises(ValueError):
            ring.push_many([b"ok", b"x" * 100])
        # the sequence protocol survived: nothing was published
        assert ring.occupancy() == 0
        ring.push(b"alive")
        assert ring.pop(timeout_s=1.0) == b"alive"
        ring.close()

    def test_variable_length_batch_pop(self):
        from repro.cluster.proc.ring import ShmRing

        ring = ShmRing.create(8, 32)
        ring.push_many([b"a", b"bb" * 8, b"c" * 3])
        assert ring.try_pop_batch() == [b"a", b"bb" * 8, b"c" * 3]
        # fixed-size pop refuses mixed lengths instead of mis-slicing
        ring.push_many([b"a" * 8, b"b" * 16])
        with pytest.raises(ValueError):
            ring.try_pop_records(8, 8)
        ring.close()

    def test_batched_park_wake_accounting(self):
        from repro.cluster.proc.ring import ShmRing

        ring = ShmRing.create(16, 32)
        recs = np.zeros((8, 32), np.uint8)
        got = []

        def consume():
            got.extend(ring.pop_batch(limit=16, timeout_s=30.0))

        t = threading.Thread(target=consume)
        t.start()
        time.sleep(0.15)                      # force the consumer to park
        ring.push_records(recs)
        t.join(timeout=30.0)
        stats = ring.park_stats()
        assert len(got) == 8
        # ONE park episode and ONE wake for the whole batch — not 8.
        assert stats["consumer_parks"] == 1
        assert stats["wakes"] == 1
        ring.close()


# ----------------------------------------------------------- block codec
def test_request_block_codec_parity():
    from repro.cluster.proc.messages import (
        REQUEST_BYTES, decode_request, decode_request_block,
        encode_request, encode_request_block)

    tids = [7, 8, 9]
    qids = [100, -1, 3]
    levels = [0, 1, 2]
    cats = [1, 2, 1]
    roots = [0, 0xDEAD, 0]
    block = encode_request_block(tids, qids, levels, cats, roots)
    assert block.shape == (3, REQUEST_BYTES)
    for i in range(3):
        scalar = encode_request(tids[i], qids[i], ServiceLevel(levels[i]),
                                cats[i], roots[i])
        assert bytes(block[i]) == scalar      # byte-for-byte the struct
        assert decode_request(bytes(block[i])) == (
            tids[i], qids[i], ServiceLevel(levels[i]), cats[i], roots[i])
    recs = decode_request_block(block)
    np.testing.assert_array_equal(recs["ticket"], tids)
    np.testing.assert_array_equal(recs["qid"], qids)
    np.testing.assert_array_equal(recs["level"], levels)
    np.testing.assert_array_equal(recs["category"], cats)
    np.testing.assert_array_equal(recs["trace_root"], roots)


# ------------------------------------------------------ bounded tables
def test_process_replica_mirror_bounded():
    from repro.cluster.proc.replica import ProcessReplica

    r = ProcessReplica(0, spec_factory=None, keep=8,
                       cache_mirror_capacity=16)
    for i in range(100):
        with r._mu:
            r._mirror_record(("key", i), policy_version=1, index_epoch=0)
    assert len(r._cache_mirror) == 16
    # LRU: the newest keys survive
    assert ("key", 99) in r._cache_mirror
    assert ("key", 0) not in r._cache_mirror
    with r._mu:
        r._policy_version, r._index_epoch = 1, 0
    assert r.cache_has(("key", 99))
    assert not r.cache_has(("key", 0))


def test_cluster_key_owner_bounded_and_fallback(trained):
    from repro.cluster import ClusterConfig, ReplicaSet

    sys_, policies = trained
    store = PolicyStore()
    store.publish(policies)
    cluster = ReplicaSet(sys_, store, ClusterConfig(
        n_replicas=2, backend="thread", affinity_table=8),
        EngineConfig(min_bucket=8, max_bucket=16, cache_capacity=256))
    with cluster:
        cluster.serve_many(list(range(32)))
        assert len(cluster._key_owner) <= 8
        # Routing fallback: an owner whose cache no longer holds the
        # key must NOT capture the request — wipe replica caches and
        # re-serve; every ticket still completes.
        for r in cluster.replicas:
            r.engine.cache.clear()
        res = cluster.serve_many(list(range(32)))
        assert len(res) == 32
        assert not any(getattr(x, "cached", False) for x in res)


# ------------------------------------------------- telemetry batch paths
def test_histogram_record_many_parity():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    h1 = reg.histogram("a", (1.0, 5.0, 25.0))
    h2 = reg.histogram("b", (1.0, 5.0, 25.0))
    rng = np.random.default_rng(0)
    vals = rng.random(500) * 50.0
    for v in vals:
        h1.record(float(v))
    h2.record_many(vals)
    s1, s2 = h1.snapshot(), h2.snapshot()
    assert s1["counts"] == s2["counts"]
    assert (s1["min"], s1["max"]) == (s2["min"], s2["max"])
    assert s1["count"] == s2["count"]
    assert s1["sum"] == pytest.approx(s2["sum"])


def test_summary_memoized(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=16))
    calls = []
    orig = engine.telemetry._compute_summary

    def counting(compile_count=0):
        calls.append(1)
        return orig(compile_count)

    engine.telemetry._compute_summary = counting
    engine.serve(list(range(4)))
    engine.summary()
    n = len(calls)
    assert n >= 1
    engine.summary()                          # clean → cached
    engine.summary()
    assert len(calls) == n
    engine.serve([50])                        # dirty → recompute
    engine.summary()
    assert len(calls) == n + 1
    # a different compile_count must not serve the stale row
    s = engine.telemetry.summary(compile_count=123)
    assert s["compile_count"] == 123


# ------------------------------------------------------------ bench gate
class TestBenchCompare:
    def _row(self, ratio=1.5, retraces=0):
        return {
            "hotpath_bench": {
                "name": "hotpath_bench", "metrics": {
                    "engine_qps_ratio_b64": ratio,
                    "thread_qps_ratio_b64": ratio,
                    "process_qps_ratio_b32": ratio,
                }},
            "serve_bench": {
                "name": "serve_bench", "metrics": {
                    "engine_steady_state_retraces": retraces,
                    "speedup": 3.0,
                    "obs": {"qps_penalty_frac": 0.01},
                    "proc_obs": {"qps_penalty_frac": 0.02},
                }},
        }

    def test_clean_rows_pass(self):
        from tools.bench_compare import compare_row

        rows = self._row()
        for name, row in rows.items():
            assert compare_row(name, row, row) == []

    def test_injected_regression_fails(self):
        from tools.bench_compare import compare_row

        bad = self._row(ratio=0.6)["hotpath_bench"]
        errs = compare_row("hotpath_bench", bad, None)
        assert any("thread_qps_ratio_b64" in e for e in errs)
        bad2 = self._row(retraces=4)["serve_bench"]
        errs2 = compare_row("serve_bench", bad2, None)
        assert any("steady_state_retraces" in e for e in errs2)

    def test_missing_local_row_skips(self):
        from tools.bench_compare import compare_row

        assert compare_row("hotpath_bench", None,
                           self._row()["hotpath_bench"]) == []

    def test_schema_drift_detected(self):
        from tools.bench_compare import compare_row

        cur = self._row()["serve_bench"]
        base = self._row()["serve_bench"]
        base["metrics"]["extra_metric"] = 1.0
        errs = compare_row("serve_bench", cur, base)
        assert any("extra_metric" in e for e in errs)

    def test_cli_end_to_end(self, tmp_path):
        import json

        from tools.bench_compare import main

        results = tmp_path / "results"
        baselines = results / "baselines"
        results.mkdir()
        baselines.mkdir()
        rows = self._row()
        for name, row in rows.items():
            (results / f"{name}.json").write_text(json.dumps(row))
            (baselines / f"{name}.json").write_text(json.dumps(row))
        argv = ["--results", str(results), "--baselines", str(baselines)]
        assert main(argv) == 0
        bad = self._row(ratio=0.5)["hotpath_bench"]
        (results / "hotpath_bench.json").write_text(json.dumps(bad))
        assert main(argv) == 1
