"""Per-architecture smoke tests: REDUCED config of the same family, one
real step on CPU, asserting output shapes and no NaNs.  Exercises the
exact step builders the dry-run lowers (launch/steps.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs
from repro.launch.steps import build_cell

ALL_CELLS = [
    (a.arch_id, s) for a in list_archs().values() for s in a.shapes
]


def _materialize(abstract, rng):
    """Turn ShapeDtypeStructs into small concrete arrays."""
    def mk(x):
        if hasattr(x, "dtype") and hasattr(x, "shape") and not isinstance(x, jnp.ndarray):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return jnp.asarray(rng.integers(0, 2, size=x.shape), x.dtype)
            if x.dtype == jnp.bool_:
                return jnp.asarray(rng.random(x.shape) < 0.7)
            if jnp.issubdtype(x.dtype, jnp.floating):
                # non-negative: second Adam moments must satisfy nu >= 0
                return jnp.asarray(np.abs(rng.normal(0, 0.02, size=x.shape)), x.dtype)
            # typed PRNG key
            return jax.random.key(0)
        return x
    return jax.tree_util.tree_map(mk, abstract)


def _no_nans(tree) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating) and bool(jnp.isnan(leaf).any()):
            return False
    return True


@pytest.mark.parametrize("arch_id,shape", ALL_CELLS)
def test_arch_smoke(arch_id, shape):
    cell = build_cell(arch_id, shape, mesh=None, reduced=True)
    rng = np.random.default_rng(hash((arch_id, shape)) % 2**31)
    args = _materialize(cell.args, rng)
    out = jax.jit(cell.fn)(*args)
    shapes_abs = jax.eval_shape(cell.fn, *cell.args)
    got = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), out)
    want = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), shapes_abs)
    assert got == want
    assert _no_nans(out), f"NaNs in {arch_id}/{shape}"
