"""Hypothesis property tests on system-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.environment import env_reset, execute_rule
from repro.core.match_rules import default_rule_library, block_cost
from repro.core.reward import r_agent
from repro.core.state_bins import bin_index, fit_bins
from repro.models.moe import MoEConfig, moe_ffn, moe_init
from repro.kernels.embedding_bag.ops import embedding_bag


# ------------------------------------------------------------ match engine
@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(8, 64))
def test_u_monotone_in_quota(seed, small_quota, big_quota_mult):
    """Scanning with a larger Δu quota never reads fewer blocks."""
    from repro.index.builder import query_occupancy, build_index
    from repro.index.corpus import CorpusConfig, generate_corpus

    rng = np.random.default_rng(seed)
    corpus = generate_corpus(CorpusConfig(n_docs=512, vocab_size=256, seed=seed % 97))
    index = build_index(corpus, block_docs=128)
    occ = jnp.asarray(query_occupancy(index, rng.integers(0, 256, 2).tolist()))
    scores = jnp.asarray(rng.random(index.padded_docs).astype(np.float32))
    tp = jnp.asarray(np.array([1, 1, 0, 0], bool))

    from repro.core.environment import EnvConfig
    cfg = EnvConfig(n_blocks=index.n_blocks, block_docs=128, k_rules=6,
                    max_candidates=64, u_budget=10**6)
    rs = default_rule_library()
    a, r = rs.allowed[0], rs.required[0]
    big_quota = small_quota * big_quota_mult

    s_small = execute_rule(cfg, occ, scores, tp, env_reset(cfg), a, r,
                           jnp.int32(small_quota), jnp.int32(10**9))
    s_big = execute_rule(cfg, occ, scores, tp, env_reset(cfg), a, r,
                         jnp.int32(big_quota), jnp.int32(10**9))
    assert int(s_big.u) >= int(s_small.u)
    assert int(s_big.cand_cnt) >= int(s_small.cand_cnt)
    assert int(s_big.v) >= int(s_small.v)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1))
def test_reward_decreases_in_u(seed):
    """Eq. 3: same relevance discovered at higher cost ⇒ lower reward."""
    from repro.core.environment import EnvConfig, EnvState
    rng = np.random.default_rng(seed)
    cfg = EnvConfig(n_blocks=8, block_docs=128, k_rules=6, max_candidates=16,
                    n_top=5)
    topn = jnp.asarray(np.sort(rng.random(5).astype(np.float32))[::-1])

    def state(u):
        return EnvState(
            block_ptr=jnp.int32(0), u=jnp.int32(u), v=jnp.int32(10),
            matched=jnp.zeros((32,), jnp.uint32),
            cand=jnp.zeros((16,), jnp.int32), cand_cnt=jnp.int32(5),
            topn=topn, done=jnp.bool_(False),
        )

    u1 = int(rng.integers(1, 100))
    u2 = u1 + int(rng.integers(1, 100))
    assert float(r_agent(cfg, state(u1))) > float(r_agent(cfg, state(u2)))


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_block_cost_bounded(seed):
    rng = np.random.default_rng(seed)
    allowed = jnp.asarray(rng.random((4, 4)) < 0.5)
    present = jnp.asarray(rng.random(4) < 0.8)
    c = int(block_cost(allowed, present))
    assert 0 <= c <= 16


# ---------------------------------------------------------------- binning
@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_bins_total_order_consistency(seed):
    """Fitting points always map inside [0, p); monotone u keeps or
    raises the stratum."""
    rng = np.random.default_rng(seed)
    u = np.cumsum(rng.exponential(10, 500))
    v = np.cumsum(rng.exponential(30, 500))
    bins = fit_bins(u, v, p=64)
    idx = np.asarray(bin_index(bins, jnp.asarray(u), jnp.asarray(v)))
    assert idx.min() >= 0 and idx.max() < bins.p
    strata = idx // bins.pv
    assert (np.diff(strata) >= 0).all()


# -------------------------------------------------------------------- MoE
@settings(deadline=None, max_examples=6)
@given(st.integers(0, 2**31 - 1))
def test_moe_zero_input_zero_output(seed):
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32, capacity_factor=4.0)
    params = moe_init(jax.random.key(seed % 100), cfg)
    out, _ = moe_ffn(params, jnp.zeros((8, 16)), cfg)
    assert float(jnp.abs(out).max()) == 0.0  # SwiGLU(0) = 0


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 2**31 - 1))
def test_moe_capacity_drop_is_graceful(seed):
    """With capacity 0 every token is dropped -> output only from the
    (absent) shared expert = 0, never NaN."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32)
    params = moe_init(jax.random.key(seed % 100), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    out, _ = moe_ffn(params, x, cfg, capacity=8)
    assert not bool(jnp.isnan(out).any())


# ---------------------------------------------------------- embedding bag
def test_embedding_bag_empty_bag_is_zero():
    table = jnp.ones((16, 4))
    idx = jnp.full((2, 3), -1, jnp.int32)
    out = embedding_bag(table, idx, mode="sum")
    assert float(jnp.abs(out).max()) == 0.0


# ----------------------------------------------------------- checkpoints
@settings(deadline=None, max_examples=5)
@given(st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_random_trees(seed):
    import tempfile

    from repro.distributed.checkpoint import restore, save
    rng = np.random.default_rng(seed)
    d = tempfile.mkdtemp(prefix=f"ck{seed % 1000}_")
    tree = {
        "a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
        "b": [jnp.asarray(rng.integers(0, 10, 4).astype(np.int32)),
              {"c": jnp.asarray(rng.normal(size=(2,)), jnp.bfloat16)}],
    }
    save(d, 0, tree)
    got = restore(d, 0, tree)
    for x, y in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))
