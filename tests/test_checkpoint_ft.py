"""Checkpointing, fault tolerance, elastic resharding, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager, latest_step, restore, save
from repro.distributed.collectives import compress_with_feedback, zeros_like_residual
from repro.distributed.elastic import plan_mesh, plan_mesh_shape, validate_specs
from repro.distributed.fault_tolerance import (
    FailureInjector, FaultToleranceConfig, run_resilient_loop,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
        "layers": {"b": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16),
                   "count": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 3, t)
    assert latest_step(tmp_path) == 3
    got = restore(tmp_path, 3, t)
    for a, b in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    save(tmp_path, 2, t)
    # simulate a torn write: dir exists but COMMIT is missing
    (tmp_path / "step_000000002.COMMIT").unlink()
    assert latest_step(tmp_path) == 1


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    steps = sorted(int(m.stem.split("_")[1]) for m in tmp_path.glob("step_*.COMMIT"))
    assert steps == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    t = _tree()
    mgr.save(5, t)
    mgr.wait()
    assert mgr.latest() == 5


def test_resilient_loop_survives_failures(tmp_path):
    """Training survives two injected node failures and converges to the
    exact same state as a failure-free run (seeded-by-step contract)."""
    def step_fn(state, step):
        return {"x": state["x"] + jnp.float32(step), "step": jnp.int32(step)}

    ft = FaultToleranceConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=3,
                              async_save=False)
    res = run_resilient_loop({"x": jnp.float32(0), "step": jnp.int32(-1)},
                             step_fn, 20, ft,
                             injector=FailureInjector(fail_at=(7, 15)))
    assert res["restarts"] == 2
    assert res["steps_replayed"] > 0

    ft2 = FaultToleranceConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=3,
                               async_save=False)
    clean = run_resilient_loop({"x": jnp.float32(0), "step": jnp.int32(-1)},
                               step_fn, 20, ft2)
    assert float(res["state"]["x"]) == float(clean["state"]["x"])


def test_resume_from_existing_checkpoints(tmp_path):
    def step_fn(state, step):
        return {"x": state["x"] + 1.0}

    ft = FaultToleranceConfig(ckpt_dir=str(tmp_path), ckpt_every=2, async_save=False)
    r1 = run_resilient_loop({"x": jnp.float32(0)}, step_fn, 5, ft)
    # second invocation resumes from the last commit, not from scratch
    r2 = run_resilient_loop({"x": jnp.float32(0)}, step_fn, 10, ft)
    assert float(r2["state"]["x"]) == 10.0


# --------------------------------------------------------------- elastic
def test_plan_mesh_factorizations():
    assert plan_mesh_shape(8) == (1, 8)
    assert plan_mesh_shape(48, prefer_model=16) == (3, 16)
    assert plan_mesh_shape(7, prefer_model=16) == (1, 7)


def test_validate_specs_catches_bad_divisibility():
    from jax.sharding import PartitionSpec as P
    mesh = plan_mesh(1)  # data=1, model=1 — anything divides
    t = {"w": jnp.zeros((6, 10))}
    assert validate_specs(t, {"w": P("model", None)}, mesh) == []


# ------------------------------------------------------------ compression
def test_error_feedback_unbiased_over_steps():
    """Accumulated compressed updates converge to the true sum — the
    residual carries what bf16 drops."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 1e-3)
    res = zeros_like_residual({"g": g})
    total = jnp.zeros_like(g)
    for _ in range(64):
        payload, res = compress_with_feedback({"g": g}, res)
        total = total + payload["g"].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(total), np.asarray(g) * 64, rtol=2e-3, atol=1e-5)


def test_compression_halves_payload():
    g = {"g": jnp.zeros((1024,), jnp.float32)}
    payload, _ = compress_with_feedback(g, zeros_like_residual(g))
    assert payload["g"].dtype == jnp.bfloat16
