"""Per-kernel correctness: Pallas (interpret mode on CPU) vs pure-jnp
oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.block_scan.ops import block_scan, block_scan_reference
from repro.kernels.decode_attention.ops import (
    decode_attention,
    decode_attention_reference,
    merge_partials,
)
from repro.kernels.embedding_bag.ops import embedding_bag, embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention, flash_attention_reference


# -------------------------------------------------------------- block_scan
@pytest.mark.parametrize("nb,w,bb", [(4, 16, 2), (16, 128, 8), (5, 32, 4), (1, 8, 8)])
def test_block_scan_shapes(nb, w, bb):
    rng = np.random.default_rng(nb * 100 + w)
    occ = jnp.asarray(rng.integers(0, 2**32, size=(nb, 4, 4, w), dtype=np.uint32))
    allowed = jnp.asarray(rng.random((4, 4)) < 0.5)
    required = jnp.asarray(rng.random(4) < 0.7)
    present = jnp.asarray(np.array([1, 1, 1, 0], bool))
    m1, v1, c1 = block_scan(occ, allowed, required, present, block_bb=bb)
    m2, v2, c2 = block_scan_reference(occ, allowed, required, present)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(v1) == np.asarray(v2)).all()
    assert (np.asarray(c1) == np.asarray(c2)).all()


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1))
def test_block_scan_property(seed):
    rng = np.random.default_rng(seed)
    nb = int(rng.integers(1, 9))
    occ = jnp.asarray(rng.integers(0, 2**32, size=(nb, 4, 4, 8), dtype=np.uint32))
    allowed = jnp.asarray(rng.random((4, 4)) < 0.6)
    required = jnp.asarray(rng.random(4) < 0.6)
    present = jnp.asarray(rng.random(4) < 0.8)
    m1, v1, c1 = block_scan(occ, allowed, required, present, block_bb=4)
    m2, v2, c2 = block_scan_reference(occ, allowed, required, present)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(v1) == np.asarray(v2)).all()


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,dtype",
    [
        (1, 4, 4, 128, 128, 64, True, jnp.float32),
        (2, 8, 2, 256, 256, 64, True, jnp.float32),    # GQA 4:1
        (1, 6, 2, 128, 128, 128, True, jnp.bfloat16),  # GQA 3:1, bf16
        (1, 2, 2, 128, 384, 64, False, jnp.float32),   # cross/bidir, Skv > Sq
        (1, 4, 1, 100, 200, 64, True, jnp.float32),    # ragged -> padding path
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, sq, skv, d, causal, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_reference(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_size_invariance():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    o1 = flash_attention(q, k, v, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, block_q=128, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)


# --------------------------------------------------------- decode attention
@pytest.mark.parametrize(
    "b,hq,hkv,s,d,dtype",
    [
        (2, 8, 8, 512, 64, jnp.float32),       # MHA
        (2, 8, 2, 1024, 64, jnp.float32),      # GQA 4:1
        (1, 48, 8, 640, 128, jnp.bfloat16),    # grok-like 6:1, ragged S
        (1, 16, 16, 300, 64, jnp.float32),     # MLA-ish wide, pad path
    ],
)
def test_decode_attention_vs_ref(b, hq, hkv, s, d, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    out, m, l = decode_attention(q, k, v, block_k=256)
    ref, mr, lr = decode_attention_reference(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_decode_partial_merge_equals_full():
    """Sequence-sharded decode: LSE-merged shard partials == full attention.
    This is the long_500k KV-sequence-sharding correctness argument."""
    rng = np.random.default_rng(3)
    b, h, s, d, shards = 2, 4, 512, 64, 4
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    accs, ms, ls = [], [], []
    for i in range(shards):
        sl = slice(i * s // shards, (i + 1) * s // shards)
        a, m, l = decode_attention(q, k[:, :, sl], v[:, :, sl], block_k=64, return_partial=True)
        accs.append(a.astype(jnp.float32)); ms.append(m); ls.append(l)
    merged = merge_partials(accs, ms, ls)
    full, _, _ = decode_attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full), atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ embedding bag
@pytest.mark.parametrize(
    "v,e,b,l,mode,dtype",
    [
        (64, 8, 4, 6, "sum", jnp.float32),
        (128, 16, 8, 3, "mean", jnp.float32),
        (1000, 32, 16, 10, "sum", jnp.float32),
        (64, 128, 4, 4, "mean", jnp.bfloat16),
    ],
)
def test_embedding_bag_kernel_vs_ref(v, e, b, l, mode, dtype):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(v, e)), dtype)
    idx = rng.integers(-1, v, size=(b, l)).astype(np.int32)  # includes padding
    out = embedding_bag_kernel(table, jnp.asarray(idx), mode=mode)
    ref = embedding_bag_ref(table, jnp.asarray(idx), mode=mode)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_embedding_bag_weighted():
    rng = np.random.default_rng(5)
    table = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, size=(4, 5)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32))
    out = embedding_bag_kernel(table, idx, w, mode="sum")
    ref = embedding_bag_ref(table, idx, w, mode="sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_embedding_bag_property(seed):
    """Permuting items within a bag leaves the sum unchanged."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
    idx = rng.integers(0, 50, size=(3, 8)).astype(np.int32)
    perm = np.stack([r[rng.permutation(8)] for r in idx])
    o1 = embedding_bag(table, jnp.asarray(idx), mode="sum")
    o2 = embedding_bag(table, jnp.asarray(perm), mode="sum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)


# ------------------------------------------------- plane-pruned block_scan
@pytest.mark.parametrize("n_terms,fields", [(2, (1, 3)), (3, (0, 1, 2, 3)), (4, (2,))])
def test_block_scan_pruned_vs_ref(n_terms, fields):
    """§Perf hillclimb #3: the pruned kernel streams only active planes
    and must match the full-scan oracle bit-exactly."""
    from repro.kernels.block_scan.block_scan_pruned import block_scan_pruned_pallas

    rng = np.random.default_rng(n_terms * 10 + len(fields))
    occ = jnp.asarray(rng.integers(0, 2**32, (8, 4, 4, 16), dtype=np.uint32))
    allowed = np.zeros((4, 4), bool)
    for f in fields:
        allowed[:, f] = True
    required = np.zeros(4, bool); required[:n_terms] = True
    present = np.zeros(4, bool); present[:n_terms] = True
    m1, v1, c1 = block_scan_pruned_pallas(occ, allowed, required, present)
    m2, v2, c2 = block_scan_reference(
        occ, jnp.asarray(allowed), jnp.asarray(required), jnp.asarray(present))
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(v1) == np.asarray(v2)).all()
    assert (np.asarray(c1) == np.asarray(c2)).all()


@pytest.mark.parametrize(
    "allowed_rows,required,present",
    [
        # zero ACTIVE planes: the padding grid step must not leak plane
        # (0, 0)'s occupancy into tf (v_inc = 0, match = 0)
        ((), (True, False, False, False), (True, True, True, True)),
        # allowed planes but term_present all false — also zero active
        ((0, 1, 2, 3), (True, True, True, True), (False,) * 4),
        # zero REQUIRED terms: match empties (any_req) but v_inc still
        # counts term hits among the planes the rule paid u to inspect
        ((0, 1), (False, False, False, False), (True, True, True, True)),
    ],
)
def test_block_scan_pruned_degenerate_rules_match_reference(
        allowed_rows, required, present):
    """Degenerate-rule semantics are pinned against block_scan_reference
    (intended: v follows u — inspected planes count term hits whether or
    not the conjunction can match; zero inspected planes count nothing)."""
    from repro.kernels.block_scan.block_scan_pruned import block_scan_pruned_pallas

    rng = np.random.default_rng(3)
    occ = jnp.asarray(rng.integers(0, 2**32, (6, 4, 4, 16), dtype=np.uint32))
    allowed = np.zeros((4, 4), bool)
    for t in allowed_rows:
        allowed[t, :] = True
    required = np.asarray(required)
    present = np.asarray(present)
    m1, v1, c1 = block_scan_pruned_pallas(occ, allowed, required, present)
    m2, v2, c2 = block_scan_reference(
        occ, jnp.asarray(allowed), jnp.asarray(required), jnp.asarray(present))
    assert (np.asarray(m1) == np.asarray(m2)).all()
    assert (np.asarray(v1) == np.asarray(v2)).all()
    assert (np.asarray(c1) == np.asarray(c2)).all()


def test_block_scan_pruned_chunk_vs_ref():
    """The chunked traced-rule kernel (serving backend path) against the
    full-scan oracle: per-lane rules, block-start offsets, end-of-index
    clamping."""
    from repro.kernels.block_scan.block_scan_pruned import (
        block_scan_pruned_chunk, build_rule_meta,
    )

    rng = np.random.default_rng(9)
    b, nb, t, f, w, chunk = 3, 8, 4, 4, 16, 4
    occ = jnp.asarray(rng.integers(0, 2**32, (b, nb, t, f, w), dtype=np.uint32))
    allowed = jnp.asarray(rng.random((b, t, f)) < 0.5)
    required = jnp.asarray(rng.random((b, t)) < 0.6)
    present = jnp.asarray(rng.random((b, t)) < 0.8)
    allowed = allowed.at[2].set(False)            # zero-active lane
    bp = jnp.asarray([0, 3, 6], jnp.int32)        # lane 2 runs off the end

    meta = build_rule_meta(allowed, required, present, bp)
    m, v, c = block_scan_pruned_chunk(
        occ.reshape(b, nb, t * f, w), meta, chunk=chunk, n_terms=t)
    for lane in range(b):
        for j in range(chunk):
            blk = min(int(bp[lane]) + j, nb - 1)
            mr, vr, cr = block_scan_reference(
                occ[lane, blk][None], allowed[lane], required[lane],
                present[lane])
            assert (np.asarray(m[lane, j]) == np.asarray(mr[0])).all()
            assert int(v[lane, j]) == int(vr[0])
            assert int(c[lane, j]) == int(cr[0])
