"""State bins, TD updates, and policy training behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qlearning import QConfig, init_q, td_update, train_batch
from repro.core.rollout import unified_rollout
from repro.core.state_bins import bin_index, fit_bins
from repro.data.querylog import CAT1, CAT2
from repro.policies import TabularQPolicy


# ------------------------------------------------------------- state bins
@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_bins_cover_and_equal_mass(seed):
    rng = np.random.default_rng(seed)
    u = rng.exponential(100, size=4000)
    v = u * 3 + rng.exponential(50, size=4000)       # correlated like real scans
    bins = fit_bins(u, v, p=64)
    idx = np.asarray(bin_index(bins, jnp.asarray(u), jnp.asarray(v)))
    assert idx.min() >= 0 and idx.max() < bins.p
    counts = np.bincount(idx, minlength=bins.p)
    # equal-mass: no bin should be grossly overloaded
    assert counts.max() <= 8 * (4000 // bins.p)


def test_bin_index_monotone_in_u():
    bins = fit_bins(np.arange(1000.0), np.arange(1000.0), p=16)
    i1 = int(bin_index(bins, jnp.float32(10.0), jnp.float32(10.0)))
    i2 = int(bin_index(bins, jnp.float32(900.0), jnp.float32(900.0)))
    assert i2 > i1


# -------------------------------------------------------------- td_update
def test_td_update_moves_toward_target():
    qcfg = QConfig(p=4, n_actions=3, alpha=0.5, gamma=0.9)
    q = jnp.zeros((4, 3))
    trans = {
        "s": jnp.array([[0]]), "a": jnp.array([[1]]), "r": jnp.array([[1.0]]),
        "s2": jnp.array([[2]]), "done": jnp.array([[True]]), "valid": jnp.array([[True]]),
    }
    q2 = td_update(qcfg, q, trans)
    assert float(q2[0, 1]) == pytest.approx(0.5)     # α·(r − 0)
    assert float(jnp.abs(q2).sum()) == pytest.approx(0.5)  # nothing else touched


def test_td_update_scatter_mean_deterministic():
    """Two transitions into the same cell average, not race."""
    qcfg = QConfig(p=2, n_actions=2, alpha=1.0, gamma=0.0)
    q = jnp.zeros((2, 2))
    trans = {
        "s": jnp.array([[0, 0]]), "a": jnp.array([[0, 0]]),
        "r": jnp.array([[1.0, 3.0]]), "s2": jnp.array([[1, 1]]),
        "done": jnp.array([[True, True]]), "valid": jnp.array([[True, True]]),
    }
    q2 = td_update(qcfg, q, trans)
    assert float(q2[0, 0]) == pytest.approx(2.0)


def test_td_update_ignores_invalid():
    qcfg = QConfig(p=2, n_actions=2, alpha=1.0, gamma=0.0)
    q = jnp.zeros((2, 2))
    trans = {
        "s": jnp.array([[0]]), "a": jnp.array([[0]]), "r": jnp.array([[5.0]]),
        "s2": jnp.array([[1]]), "done": jnp.array([[True]]), "valid": jnp.array([[False]]),
    }
    q2 = td_update(qcfg, q, trans)
    assert float(jnp.abs(q2).sum()) == 0.0


# ---------------------------------------------------------- training E2E
def test_training_reduces_blocks_accessed(tiny_system):
    """The paper's headline claim, at toy scale: learned policy cuts u
    without collapsing NCG."""
    sys_ = tiny_system
    q, _ = sys_.train_policy(CAT2, iters=80, batch=32, seed=1,
                             eps_start=0.6, eps_end=0.1)
    qids = np.where(sys_.log.category == CAT2)[0][:64]
    res = sys_.evaluate(q, qids, CAT2)
    assert res["policy_u"].mean() < res["baseline_u"].mean()
    assert res["policy_ncg"].mean() > 0.5 * res["baseline_ncg"].mean()


def test_greedy_rollout_deterministic(tiny_system):
    sys_ = tiny_system
    q = init_q(sys_.qcfg)
    qids = np.where(sys_.log.category == CAT1)[0][:8]
    occ, scores, tp = sys_.batch_inputs(qids)

    def greedy():
        res = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                              TabularQPolicy(q), sys_.qcfg.t_max,
                              occ, scores, tp)
        return res.final_state, res.transitions["a"]

    f1, a1 = greedy()
    f2, a2 = greedy()
    assert (np.asarray(a1) == np.asarray(a2)).all()
    assert (np.asarray(f1.u) == np.asarray(f2.u)).all()
