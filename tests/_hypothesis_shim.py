"""Minimal stand-in for `hypothesis` used when the real package is not
installed (offline CI images).  It supports exactly the subset this test
suite uses — ``@settings(deadline=None, max_examples=N)`` stacked on
``@given(st.integers(lo, hi), ...)`` — by expanding each property test
into a deterministic loop over pseudo-random examples.

The shim is installed into ``sys.modules`` by ``tests/conftest.py`` only
when ``import hypothesis`` fails, so environments with the real package
keep full shrinking/replay behaviour.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10
_SEED = 0x5EED


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    # Endpoints are the classic boundary bugs — visit them first, like
    # real hypothesis's shrink targets, then sample the interior.
    edges = iter((min_value, max_value))
    def sample(rng):
        for e in edges:
            return float(e)
        return float(rng.uniform(min_value, max_value))
    return _Strategy(sample)


def settings(deadline=None, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        # Like real hypothesis, positional strategies fill the TRAILING
        # parameters; anything before them (pytest fixtures) arrives via
        # kwargs, so strategy values must be bound by name.
        names = [p.name
                 for p in inspect.signature(fn).parameters.values()
                 ][-len(strategies):]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                vals = {nm: s._sample(rng)
                        for nm, s in zip(names, strategies)}
                fn(*args, **kwargs, **vals)
        # Hide the generated parameters from pytest's fixture resolution:
        # only the leading (fixture) params of the original signature remain.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[: -len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco


def install() -> None:
    """Register the shim as the `hypothesis` package (idempotent)."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__is_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
