"""Serving engine: bucket sizing/padding invariants, cache parity,
end-to-end parity vs. direct rollout, shards, admission, telemetry."""
import threading
import time

import numpy as np
import pytest

from repro.core.rollout import unified_rollout
from repro.core.telescope import l1_prune
from repro.data.querylog import CAT1, CAT2
from repro.policies import PolicyStore, TabularQPolicy
from repro.serving import (
    AdmissionError, BucketConfig, CacheOnlyMiss, EngineConfig, ServeEngine,
    ServiceLevel, bucket_size_for,
)
from repro.serving.cache import canonical_query_key


# -------------------------------------------------------------- bucketing
def test_bucket_size_for():
    cfg = BucketConfig(min_bucket=8, max_bucket=64)
    assert bucket_size_for(1, cfg) == 8
    assert bucket_size_for(8, cfg) == 8
    assert bucket_size_for(9, cfg) == 16
    assert bucket_size_for(33, cfg) == 64
    assert bucket_size_for(500, cfg) == 64          # clamped to max
    assert cfg.buckets() == [8, 16, 32, 64]
    with pytest.raises(ValueError):
        BucketConfig(min_bucket=6, max_bucket=64)   # not a power of two
    with pytest.raises(ValueError):
        BucketConfig(min_bucket=32, max_bucket=8)


# ---------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def trained(tiny_system):
    """tiny_system + quickly-trained per-category policies (quality is
    irrelevant here; parity and shape behaviour are what's under test)."""
    policies = {cat: TabularQPolicy(tiny_system.train_policy(cat, iters=10,
                                                             batch=16)[0])
                for cat in (CAT1, CAT2)}
    return tiny_system, policies


def _direct(sys_, policies, qids):
    """Reference path: unified_rollout + l1_prune, one category at a time."""
    qids = np.asarray(qids)
    ids = np.zeros((len(qids), 100), np.int32)
    sc = np.zeros((len(qids), 100), np.float32)
    u = np.zeros(len(qids), np.int64)
    for cat in (CAT1, CAT2):
        m = sys_.log.category[qids] == cat
        if not m.any():
            continue
        occ, scores, tp = sys_.batch_inputs(qids[m])
        fin = unified_rollout(sys_.env_cfg, sys_.ruleset, sys_.bins,
                              policies[cat], sys_.qcfg.t_max,
                              occ, scores, tp).final_state
        i_, s_ = l1_prune(scores, fin.cand, keep=100)
        ids[m], sc[m], u[m] = np.asarray(i_), np.asarray(s_), np.asarray(fin.u)
    return ids, sc, u


# ------------------------------------------------------ padding invariants
def test_padding_lanes_never_contribute(trained):
    """3 real queries padded up to a bucket of 8: responses exist only
    for the real lanes and are identical to an unpadded direct rollout."""
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=0))
    qids = np.where(sys_.log.category == CAT1)[0][:3]
    responses = engine.serve(qids)
    assert len(responses) == 3
    assert engine.take_response(999) is None         # nothing extra completed
    ids, sc, u = _direct(sys_, policies, qids)
    for lane, r in enumerate(responses):
        assert not r.cached
        np.testing.assert_array_equal(r.doc_ids, ids[lane])
        np.testing.assert_allclose(r.scores, sc[lane], rtol=1e-6)
        assert r.u == u[lane]
    # the batch really was padded
    assert engine.telemetry.batches[0]["bucket"] == 8
    assert engine.telemetry.batches[0]["n_padded"] == 5


# ---------------------------------------------------------- cache behaviour
def test_cache_hit_parity(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=16, cache_capacity=64))
    qid = int(np.where(sys_.log.category == CAT2)[0][0])
    (fresh,) = engine.serve([qid])
    (hit,) = engine.serve([qid])
    assert not fresh.cached and hit.cached
    np.testing.assert_array_equal(fresh.doc_ids, hit.doc_ids)
    np.testing.assert_allclose(fresh.scores, hit.scores, rtol=0)
    assert fresh.u == hit.u
    assert engine.cache.hits >= 1
    # a cache hit never runs a new micro-batch
    assert len(engine.telemetry.batches) == 1


def test_cache_canonicalization(trained):
    """Two distinct qids with the same term set share one cache entry."""
    sys_, policies = trained
    log = sys_.log
    dup = None
    seen = {}
    for q in range(log.n_queries):
        key = (int(log.category[q]),
               tuple(sorted(t for t in log.terms[q] if t >= 0)))
        if key in seen:
            dup = (seen[key], q)
            break
        seen[key] = q
    if dup is None:
        pytest.skip("query log has no duplicate term sets")
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=16, cache_capacity=64))
    engine.serve([dup[0]])
    (second,) = engine.serve([dup[1]])
    assert second.cached


# ------------------------------------------------------- end-to-end parity
def test_engine_matches_direct_rollout(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=16, cache_capacity=0, n_shards=1))
    rng = np.random.default_rng(3)
    qids = rng.integers(0, sys_.log.n_queries, size=24)
    responses = engine.serve(qids)
    ids, sc, u = _direct(sys_, policies, qids)
    for lane, r in enumerate(responses):
        assert r.qid == qids[lane]
        np.testing.assert_array_equal(r.doc_ids, ids[lane])
        np.testing.assert_allclose(r.scores, sc[lane], rtol=1e-6)
        assert r.u == u[lane]


# ------------------------------------------------------------------ shards
def test_multishard_candidates_valid(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=0, n_shards=2))
    qids = np.arange(8)
    responses = engine.serve(qids)
    n_docs_total = sys_.env_cfg.n_blocks * sys_.env_cfg.block_docs
    for r in responses:
        valid = r.doc_ids[r.doc_ids >= 0]
        assert len(np.unique(valid)) == len(valid)      # no dup across shards
        assert (valid < n_docs_total).all()
        assert r.u > 0


def test_bad_shard_count_rejected(trained):
    sys_, policies = trained
    with pytest.raises(ValueError):
        ServeEngine(sys_, policies, EngineConfig(n_shards=3))  # 8 blocks % 3


# -------------------------------------------------------- service levels
def _ladder_engine(sys_, policies, **cfg_kw):
    store = PolicyStore(staleness_bound=0)
    store.publish(dict(policies), fallbacks=sys_.fallback_policies())
    return ServeEngine(sys_, store, EngineConfig(**cfg_kw))


def test_shallow_level_serves_fallback_plan(trained):
    """SHALLOW responses are bit-identical to a direct rollout of the
    snapshot's truncated-plan fallback, with the promised u bound."""
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=8,
                            cache_capacity=0)
    qids = np.where(sys_.log.category == CAT1)[0][:5]
    responses = engine.serve(qids, level=ServiceLevel.SHALLOW)
    ids, sc, u = _direct(sys_, sys_.fallback_policies(), qids)
    cap = sys_.shallow_u_cap(CAT1)
    for lane, r in enumerate(responses):
        assert r.level == ServiceLevel.SHALLOW and not r.cached
        np.testing.assert_array_equal(r.doc_ids, ids[lane])
        np.testing.assert_allclose(r.scores, sc[lane], rtol=1e-6)
        assert r.u == u[lane]
        assert 0 < r.u <= cap
    assert engine.summary()["level_counts"] == {int(ServiceLevel.SHALLOW): 5}


def test_full_and_shallow_never_share_a_micro_batch(trained):
    """Interleaved FULL/SHALLOW submissions of one category drain into
    separate micro-batches (different policies, different executables),
    and each response is identical to its unmixed reference."""
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=8,
                            cache_capacity=0)
    qids = np.where(sys_.log.category == CAT2)[0][:6]
    rids = {}
    for i, q in enumerate(qids):
        level = ServiceLevel.SHALLOW if i % 2 else ServiceLevel.FULL
        rids[engine.submit(int(q), level)] = (int(q), level)
    engine.flush()
    full_ids, _, full_u = _direct(sys_, policies, qids)
    sh_ids, _, sh_u = _direct(sys_, sys_.fallback_policies(), qids)
    for rid, (q, level) in rids.items():
        r = engine.take_response(rid)
        lane = int(np.where(qids == q)[0][0])
        assert r.level == level
        if level == ServiceLevel.FULL:
            np.testing.assert_array_equal(r.doc_ids, full_ids[lane])
            assert r.u == full_u[lane]
        else:
            np.testing.assert_array_equal(r.doc_ids, sh_ids[lane])
            assert r.u == sh_u[lane]


def test_shallow_fill_never_answers_full_request(trained):
    """Cache-level compatibility: a SHALLOW fill answers SHALLOW and
    CACHED_ONLY requests but never a FULL one; a FULL fill answers
    everyone and upgrades the entry."""
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=8,
                            cache_capacity=64)
    qid = int(np.where(sys_.log.category == CAT1)[0][0])
    (sh,) = engine.serve([qid], level=ServiceLevel.SHALLOW)
    assert not sh.cached and sh.level == ServiceLevel.SHALLOW
    (sh2,) = engine.serve([qid], level=ServiceLevel.SHALLOW)
    assert sh2.cached and sh2.level == ServiceLevel.SHALLOW
    (full,) = engine.serve([qid])                  # degraded entry: miss
    assert not full.cached and full.level == ServiceLevel.FULL
    (full2,) = engine.serve([qid])                 # FULL fill won the entry
    assert full2.cached and full2.level == ServiceLevel.FULL
    np.testing.assert_array_equal(full2.doc_ids, full.doc_ids)
    # ...and now answers degraded requests too (quality upgrade is fine)
    (sh3,) = engine.serve([qid], level=ServiceLevel.SHALLOW)
    assert sh3.cached and sh3.level == ServiceLevel.FULL
    # accounting: the level-incompatible lookup counted as a MISS and
    # did not promote the rejected entry
    assert engine.cache.hits == 3 and engine.cache.misses == 2


def test_cached_only_level(trained):
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=8,
                            cache_capacity=64)
    qid = int(np.where(sys_.log.category == CAT2)[0][0])
    with pytest.raises(CacheOnlyMiss):
        engine.submit(qid, ServiceLevel.CACHED_ONLY)
    (full,) = engine.serve([qid])
    (hit,) = engine.serve([qid], level=ServiceLevel.CACHED_ONLY)
    assert hit.cached and hit.level == ServiceLevel.FULL
    np.testing.assert_array_equal(hit.doc_ids, full.doc_ids)
    with pytest.raises(ValueError):
        engine.submit(qid, ServiceLevel.SHED)


def test_shallow_batch_upgrades_to_full_when_fallbacks_cleared(trained):
    """A publish that clears the fallbacks while SHALLOW requests sit
    queued must not poison the batch: it executes at FULL instead."""
    sys_, policies = trained
    store = PolicyStore(staleness_bound=2)
    store.publish(dict(policies), fallbacks=sys_.fallback_policies())
    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=0))
    qids = np.where(sys_.log.category == CAT1)[0][:3]
    rids = [engine.submit(int(q), ServiceLevel.SHALLOW) for q in qids]
    store.publish(dict(policies), fallbacks={})      # fallbacks gone
    engine.flush()
    ids, _, u = _direct(sys_, policies, qids)
    for lane, rid in enumerate(rids):
        r = engine.take_response(rid)
        assert r is not None and r.level == ServiceLevel.FULL
        np.testing.assert_array_equal(r.doc_ids, ids[lane])
        assert r.u == u[lane]


def test_cache_hit_served_when_queue_full(trained):
    """admission_limit caps the PENDING queue only: a cache hit
    completes inline and must be served even at the cap (the ladder's
    CACHED_ONLY rung depends on exactly this under saturation)."""
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=8,
                            cache_capacity=64, admission_limit=1)
    cat1 = np.where(sys_.log.category == CAT1)[0]
    # three qids with pairwise-distinct canonical keys (the log can
    # contain duplicate term sets, which would hit instead of queueing)
    key_of = lambda q: canonical_query_key(sys_.log.terms[q], CAT1)
    hot, miss1, miss2 = None, None, None
    seen = {}
    for q in cat1:
        k = key_of(int(q))
        if k not in seen:
            seen[k] = int(q)
            if len(seen) == 3:
                hot, miss1, miss2 = seen.values()
                break
    (filled,) = engine.serve([hot])                   # fill the cache
    assert not filled.cached
    engine.submit(miss1)                              # miss: queue at cap
    rid = engine.submit(hot)                          # hit: inline, no queue
    hit = engine.take_response(rid)
    assert hit is not None and hit.cached
    with pytest.raises(AdmissionError):
        engine.submit(miss2)                          # miss at cap: shed
    engine.flush()                                    # queued work completes


def test_warmup_covers_fallbacks_and_level_splits_compile_key(trained):
    sys_, policies = trained
    engine = _ladder_engine(sys_, policies, min_bucket=8, max_bucket=16,
                            cache_capacity=0)
    buckets = engine.bucket_cfg.buckets()
    # one tabular structure at FULL + one static-plan structure per
    # distinct fallback plan length at SHALLOW
    n_fallback_structs = len({p.plan.length
                              for p in sys_.fallback_policies().values()})
    assert engine.warmup() == len(buckets) * (1 + n_fallback_structs)
    # an identical policy structure still compiles separately per level
    before = engine.executor.compile_count
    engine.executor.compiled_for(8, policies[CAT1],
                                 level=int(ServiceLevel.SHALLOW))
    assert engine.executor.compile_count == before + 1


# ------------------------------------------------- steady-state compilation
def test_zero_steady_state_retraces(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=16, cache_capacity=0))
    assert engine.warmup() == len(engine.bucket_cfg.buckets())
    rng = np.random.default_rng(5)
    for _ in range(4):                      # mixed CAT1/CAT2 stream
        engine.serve(rng.integers(0, sys_.log.n_queries, size=13))
    assert engine.compile_count == len(engine.bucket_cfg.buckets())


# -------------------------------------------------------------- admission
def test_admission_load_shedding(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=0, admission_limit=2))
    engine.submit(0)
    engine.submit(1)
    with pytest.raises(AdmissionError):
        engine.submit(2)
    assert engine.telemetry.rejected == 1
    engine.flush()                           # queued work still completes
    assert engine.take_response(0) is not None


# -------------------------------------------------------------- telemetry
def test_summary_shape(trained):
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=16))
    engine.serve([0, 1, 2, 0])
    s = engine.summary()
    for k in ("n_requests", "qps", "latency_p50_ms", "latency_p99_ms",
              "mean_u", "p99_u", "cache_hit_rate", "compile_count",
              "padding_overhead", "queue_depth", "inflight",
              "peak_queue_depth", "peak_inflight"):
        assert k in s
    assert s["n_requests"] == 4
    assert s["mean_u"] > 0


def test_queue_depth_and_inflight_gauges(trained):
    """The router's load signals: queue_depth counts admitted-not-yet-
    drained requests, inflight the executing micro-batch's real lanes;
    peaks survive in the summary."""
    sys_, policies = trained
    engine = ServeEngine(sys_, policies, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=0))
    qids = np.where(sys_.log.category == CAT1)[0][:5]
    for q in qids:
        engine.submit(int(q))
    assert engine.queue_depth == 5 and engine.inflight == 0
    engine.flush()
    assert engine.queue_depth == 0 and engine.inflight == 0
    s = engine.summary()
    assert s["peak_queue_depth"] == 5
    assert s["peak_inflight"] == 5          # observed mid-execution
    assert s["queue_depth"] == 0 and s["inflight"] == 0


# ------------------------------------------------ concurrent hot swap
def test_cache_flush_on_hot_swap_under_concurrent_submit(trained):
    """A publisher thread hot-swaps snapshots while the engine thread
    keeps serving a hot query set.  Every cached response must have
    been produced by a fill at the SAME policy version — a stale entry
    surviving a version change would surface as a hit at a version
    with no prior fill, or with different doc ids."""
    sys_, policies = trained
    store = PolicyStore(staleness_bound=10**9)
    store.publish(dict(policies))
    engine = ServeEngine(sys_, store, EngineConfig(
        min_bucket=8, max_bucket=8, cache_capacity=256))
    hot = np.where(sys_.log.category == CAT2)[0][:8]
    stop = threading.Event()
    published = [1]

    def publisher():
        for _ in range(5):
            time.sleep(0.05)
            published.append(store.publish(dict(policies)))
        stop.set()

    fills = {}                       # (cache_key, version) -> doc_ids
    hit_versions = set()

    def record_wave():
        for r in engine.serve(hot):
            key = (canonical_query_key(sys_.log.terms[r.qid],
                                       r.category), r.policy_version)
            if r.cached:
                assert key in fills, \
                    f"cache hit at v{r.policy_version} without a fill"
                np.testing.assert_array_equal(r.doc_ids, fills[key])
                hit_versions.add(r.policy_version)
            else:
                fills[key] = r.doc_ids

    thread = threading.Thread(target=publisher)
    thread.start()
    try:
        while not stop.is_set():
            record_wave()
    finally:
        thread.join()
    record_wave()                    # fill (or hit) at the final version
    record_wave()                    # guaranteed hits at the final version
    assert published[-1] == 6
    # the loop really exercised post-swap cache hits, not just v1
    assert len({v for _, v in fills}) >= 2
    assert max(hit_versions, default=1) >= 2
